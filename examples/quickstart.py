"""Quickstart: the paper's H-FA attention, three ways.

  1. bit-accurate FIX16 LNS emulation vs exact attention,
  2. the Pallas H-FA kernel (interpret mode on CPU),
  3. H-FA as the attention layer of a small transformer.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import hfa, lns, reference
from repro.kernels import hfa as hfa_kernel
from repro.models.model import build_model

rng = np.random.default_rng(0)
B, H, LQ, LKV, D = 1, 2, 8, 256, 64
q = jnp.asarray(rng.standard_normal((B, H, LQ, D)), jnp.bfloat16)
k = jnp.asarray(rng.standard_normal((B, H, LKV, D)), jnp.bfloat16)
v = jnp.asarray(rng.standard_normal((B, H, LKV, D)), jnp.bfloat16)

# 1 -- datapath-faithful H-FA (Alg. 2 + Eq. 14, FIX16 log domain)
exact = reference.exact_attention(q, k, v)
out = hfa.hfa_attention(q, k, v).astype(jnp.float32)
print("H-FA emulation vs exact:  mean|err| =",
      float(jnp.abs(out - exact).mean()))

# ... and with each approximation disabled (Table III ablation):
out_exact_cfg = hfa.hfa_attention(q, k, v, cfg=lns.EXACT).astype(jnp.float32)
print("H-FA with exact ops:      mean|err| =",
      float(jnp.abs(out_exact_cfg - exact).mean()))

# 2 -- the MXU-compatible Pallas kernel (quantized exp, LogDiv reciprocal)
# (the ops wrapper handles GQA + padding to the 128-aligned MXU blocks)
from repro.kernels import ops as kops
out_k = kops.multihead_attention(
    jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2),
    impl="hfa_pallas", causal=False)
out_k = jnp.swapaxes(out_k, 1, 2).astype(jnp.float32)
print("H-FA Pallas kernel:       mean|err| =",
      float(jnp.abs(out_k - exact).mean()))

# 3 -- a transformer with H-FA attention end to end
import dataclasses
cfg = dataclasses.replace(get_config("hfa-paper-mini").reduced(), n_layers=2)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)))
loss, metrics = model.loss(params, {"tokens": tokens})
print(f"hfa-paper-mini (reduced, attn_impl={cfg.attn_impl}): "
      f"loss = {float(loss):.4f}")
