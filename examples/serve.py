"""Serving driver: batched prefill + decode with the H-FA decode path.

Loads the checkpoint written by examples/train_lm.py (or initializes fresh
weights) and serves a batch of prompts: one prefill, then greedy decode,
reporting per-token latency.  With --kv-split N it also demonstrates the
paper's multi-KV-block decode: the cache is split into N spans, partial
FAU triplets are merged with the log-domain ACC rule (Eq. 16).

Run:  PYTHONPATH=src python examples/serve.py [--tokens 32] [--kv-split 4]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.data import DataPipeline
from repro.kernels import decode as dk
from repro.models.model import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--kv-split", type=int, default=4)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="train-lm-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=4, d_head=64, d_ff=2048, vocab_size=16384,
        vocab_pad_multiple=128, attn_impl="hfa_pallas", max_seq=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    try:
        mgr = CheckpointManager(args.ckpt)
        carry = {"params": params}
        restored, step = mgr.restore_latest(
            {"params": params}, None)
        params = restored["params"]
        print(f"restored checkpoint at step {step}")
    except Exception:
        print("no checkpoint found - serving random weights")

    pipe = DataPipeline.for_config(cfg, 64, args.batch, seed=123)
    prompts = jnp.asarray(pipe.batch(0)["tokens"][:, :48])

    decode_step = jax.jit(model.decode_step)
    cache = model.init_cache(params, args.batch, max_seq=128)
    t0 = time.perf_counter()
    logits, cache = jax.jit(model.prefill)(params, cache, prompts)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]

    out_tokens = []
    t0 = time.perf_counter()
    for _ in range(args.tokens):
        out_tokens.append(np.asarray(tok))
        logits, cache = decode_step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    jax.block_until_ready(logits)
    dt = (time.perf_counter() - t0) / args.tokens
    gen = np.concatenate(out_tokens, axis=1)
    print(f"prefill({prompts.shape[1]} toks x {args.batch} seqs): "
          f"{t_prefill*1e3:.1f} ms")
    print(f"decode: {dt*1e3:.2f} ms/token (batch {args.batch})")
    print("generated ids (first seq):", gen[0][:16], "...")

    # --- paper Fig. 2 demo: KV split + log-domain ACC merge -------------
    rng = np.random.default_rng(0)
    g, s, d = 8, 1024, 64
    q = jnp.asarray(rng.standard_normal((2, g, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((2, s, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((2, s, d)), jnp.bfloat16)
    span = s // args.kv_split
    parts = [dk.decode_partial_pallas(q, k[:, i*span:(i+1)*span],
                                      v[:, i*span:(i+1)*span], use_hfa=True)
             for i in range(args.kv_split)]
    om, mm, lm = dk.merge_partials(
        jnp.stack([p[0] for p in parts]), jnp.stack([p[1] for p in parts]),
        jnp.stack([p[2] for p in parts]), use_hfa=True)
    merged = dk.finalize_decode(om, lm, use_hfa=True)
    from repro.core import reference
    gold = reference.exact_attention(q, k, v)
    print(f"KV split x{args.kv_split} + H-FA ACC merge vs exact: "
          f"max|err| = {float(jnp.abs(merged - gold).max()):.4f}")


if __name__ == "__main__":
    main()
