"""End-to-end driver: train a ~100M-param LM for a few hundred steps.

Uses the full production stack: data pipeline, AdamW, remat+scan layers,
fault-tolerant trainer with async checkpoints.  The model uses the H-FA
Pallas attention kernel - the paper's contribution in the training path.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--fa2]
"""
import argparse
import dataclasses

from repro.configs.base import ModelConfig, register
from repro.models.model import build_model
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fa2", action="store_true",
                    help="use the float FA-2 path instead of H-FA")
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: 12L x 768 with a 16k vocab.
    cfg = ModelConfig(
        name="train-lm-100m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=4,
        d_head=64,
        d_ff=2048,
        vocab_size=16384,
        vocab_pad_multiple=128,
        attn_impl="fa2" if args.fa2 else "hfa_pallas",
        max_seq=256,
    )
    model = build_model(cfg)
    print(f"params ~= {cfg.param_count()/1e6:.1f}M  attn={cfg.attn_impl}")

    tcfg = TrainerConfig(
        steps=args.steps, ckpt_every=50, ckpt_dir=args.ckpt,
        peak_lr=6e-4, warmup=20, seq_len=256, global_batch=8)
    trainer = Trainer(model, tcfg)
    res = trainer.run()
    losses = [m["loss"] for m in res["metrics"]]
    n = max(len(losses) // 10, 1)
    for i in range(0, len(losses), n):
        chunk = losses[i:i + n]
        print(f"steps {i:4d}-{i+len(chunk)-1:4d}: "
              f"loss {sum(chunk)/len(chunk):.4f}")
    print("events:", res["events"] or "none")
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
