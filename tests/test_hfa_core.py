"""H-FA emulation vs float references; block-merge algebra properties."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import hfa, lns, reference


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), jnp.bfloat16)


def test_fa2_reference_matches_exact():
    q, k, v = _rand((2, 3, 9, 32), 1), _rand((2, 3, 33, 32), 2), _rand((2, 3, 33, 32), 3)
    for causal in (False, True):
        a = np.asarray(reference.fa2_attention(q, k, v, causal=causal, block=8))
        b = np.asarray(reference.exact_attention(q, k, v, causal=causal))
        np.testing.assert_allclose(a, b, atol=2e-5)


def test_lazy_reference_matches_exact():
    q, k, v = _rand((2, 8, 16), 1), _rand((2, 24, 16), 2), _rand((2, 24, 16), 3)
    a = np.asarray(reference.lazy_attention(q, k, v, causal=True))
    b = np.asarray(reference.exact_attention(q, k, v, causal=True))
    np.testing.assert_allclose(a, b, atol=2e-5)


@pytest.mark.parametrize("nblocks", [2, 4, 8])
def test_blockparallel_matches_exact(nblocks):
    q, k, v = _rand((1, 2, 8, 16), 4), _rand((1, 2, 64, 16), 5), _rand((1, 2, 64, 16), 6)
    for causal in (False, True):
        a = np.asarray(reference.blockparallel_attention(
            q, k, v, num_blocks=nblocks, causal=causal))
        b = np.asarray(reference.exact_attention(q, k, v, causal=causal))
        np.testing.assert_allclose(a, b, atol=2e-5)


def test_hfa_exact_ablation_close_to_float():
    """With all three approximations disabled the pipeline is float-exact-ish."""
    q, k, v = _rand((1, 2, 4, 16), 7), _rand((1, 2, 48, 16), 8), _rand((1, 2, 48, 16), 9)
    out = np.asarray(hfa.hfa_attention(q, k, v, cfg=lns.EXACT).astype(jnp.float32))
    ref = np.asarray(reference.exact_attention(q, k, v))
    assert np.abs(out - ref).max() < 5e-3


def test_hfa_default_bounded_error():
    """Full H-FA attention error stays within the paper's regime."""
    q, k, v = _rand((2, 2, 8, 32), 10), _rand((2, 2, 256, 32), 11), _rand((2, 2, 256, 32), 12)
    out = np.asarray(hfa.hfa_attention(q, k, v).astype(jnp.float32))
    ref = np.asarray(reference.exact_attention(q, k, v))
    assert np.isfinite(out).all()
    assert np.abs(out - ref).max() < 0.5      # absolute bound, random data
    # with concentrated (realistic) softmax the error collapses:
    outc = np.asarray(hfa.hfa_attention(q, k, v, scale=1.0).astype(jnp.float32))
    refc = np.asarray(reference.exact_attention(q, k, v, scale=1.0))
    rel = np.abs(outc - refc).mean() / (np.abs(refc).mean() + 1e-9)
    assert rel < 0.15


def test_hfa_causal():
    q, k, v = _rand((1, 2, 16, 16), 13), _rand((1, 2, 16, 16), 14), _rand((1, 2, 16, 16), 15)
    out = np.asarray(hfa.hfa_attention(q, k, v, causal=True).astype(jnp.float32))
    ref = np.asarray(reference.exact_attention(q, k, v, causal=True))
    assert np.isfinite(out).all()
    assert np.abs(out - ref).max() < 0.6


@pytest.mark.parametrize("split", [(1, 1), (1, 3), (2, 2)])
def test_acc_merge_equivalent_to_stream(split):
    """Streaming a KV span == streaming its parts + log-domain ACC merge.

    Not bit-identical (different add order) but within the Mitchell regime.
    """
    a_len, b_len = 32 * split[0], 32 * split[1]
    q = _rand((2, 4, 16), 20)
    k = _rand((2, a_len + b_len, 16), 21)
    v = _rand((2, a_len + b_len, 16), 22)
    full = hfa.hfa_partial(q, k, v)
    pa = hfa.hfa_partial(q, k[:, :a_len], v[:, :a_len])
    pb = hfa.hfa_partial(q, k[:, a_len:], v[:, a_len:])
    merged = hfa.acc_merge(pa, pb)
    np.testing.assert_allclose(np.asarray(merged.m), np.asarray(full.m),
                               atol=1e-6)
    out_full = np.asarray(hfa.logdiv(full).astype(jnp.float32))
    out_merge = np.asarray(hfa.logdiv(merged).astype(jnp.float32))
    assert np.abs(out_full - out_merge).max() < 0.35


def test_acc_merge_empty_block_is_identity():
    q = _rand((1, 4, 16), 30)
    k = _rand((1, 32, 16), 31)
    v = _rand((1, 32, 16), 32)
    full = hfa.hfa_partial(q, k, v)
    empty = hfa.HFAPartial(
        m=jnp.full(full.m.shape, hfa.NEG_INF, jnp.float32),
        sign=jnp.zeros_like(full.sign),
        raw=jnp.full(full.raw.shape, float(lns.LOG_ZERO), jnp.float32),
    )
    merged = hfa.acc_merge(full, empty)
    assert bool(jnp.all(merged.raw == full.raw))
    merged2 = hfa.acc_merge(empty, full)
    assert bool(jnp.all(merged2.raw == full.raw))


@given(st.integers(1, 4))
@settings(max_examples=8, deadline=None)
def test_blockparallel_hfa_any_split(p):
    q = _rand((1, 1, 4, 16), 40)
    k = _rand((1, 1, 16 * p, 16), 41)
    v = _rand((1, 1, 16 * p, 16), 42)
    out = np.asarray(hfa.hfa_blockparallel(q, k, v, num_blocks=p)
                     .astype(jnp.float32))
    ref = np.asarray(reference.exact_attention(q, k, v))
    assert np.isfinite(out).all()
    assert np.abs(out - ref).max() < 0.6
