"""HTTP/SSE transport tests: HttpServer over AsyncFrontend, driven
through real loopback sockets with the module's own stdlib client.

Covers, per the serving-transport spec:
  * request_from_json validation (unknown fields, bad types, class and
    ceiling checks -> HttpError 400);
  * SSE token streams are token-identical to ServingEngine.run - greedy
    and seeded-sampled - and the terminal ``event: done`` carries the
    full FinishedRequest payload ("stream": false returns it as one
    JSON response; sequence groups include completions);
  * admission control: a latency class at its queue cap answers 429
    without touching in-flight streams; engine down (frontend closed)
    answers 503; misuse over the wire (contradictory knobs, over-
    ceiling prompts) answers 400;
  * per-tenant fairness: waiting requests of one class round-robin
    across ``x-tenant`` values instead of strict FCFS;
  * disconnect-driven cancellation: an abruptly closed socket cancels
    the request and the paged pool comes back refcount-clean;
  * slow-reader backpressure: a client that stops reading trips the
    frontend's bounded stream queue (cancel-on-overflow) instead of
    buffering without limit.
"""
import asyncio
import contextlib
import json
import socket

import numpy as np
import pytest

import jax

from repro.serving import (AsyncFrontend, Request, SamplingParams,
                           ServingEngine)
from repro.serving.http import (HttpError, HttpServer, http_json,
                                request_from_json, stream_generate)


@pytest.fixture(scope="module")
def qwen_smoke():
    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seq", 64)
    return ServingEngine(model, params, **kw)


def _prompt(cfg, seed, n):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, n).tolist()


def _pool_clean(engine):
    engine.cache.check_invariants()
    assert engine.cache.available_page_count == engine.cache.num_pages
    assert not engine.sched.has_work


# --------------------------------------------------- request validation
def test_request_from_json_validation():
    ok = request_from_json({"prompt": [1, 2], "max_new_tokens": 4},
                           rid=7, tenant="alice")
    assert ok.rid == 7 and ok.tenant == "alice"
    assert ok.sampling is None           # no sampling fields -> greedy
    sp = request_from_json({"prompt": [1], "temperature": 0.5, "seed": 3},
                           rid=0)
    assert sp.sampling is not None and sp.sampling.seed == 3
    for bad in ([1, 2],                          # not an object
                {"prompt": []},
                {"prompt": "hi"},
                {"prompt": [1, -2]},
                {"prompt": [1, True]},
                {"prompt": [1], "latency_class": "warp"},
                {"prompt": [1], "max_new_tokens": 0},
                {"prompt": [1], "frobnicate": 1},
                {"prompt": [1], "temperature": "hot"},
                {"prompt": [1], "top_k": -1},
                {"prompt": [1], "logprobs": 1}):
        with pytest.raises(HttpError) as ei:
            request_from_json(bad, rid=0)
        assert ei.value.status == 400


# ------------------------------------------------------ streaming parity
def test_sse_stream_parity_with_engine_run(qwen_smoke):
    """Tokens streamed over the socket == the synchronous batch loop's,
    request by request, with per-event indices and the FinishedRequest
    payload on the terminal event."""
    cfg, model, params = qwen_smoke
    reqs = [Request(rid=i, prompt=_prompt(cfg, 20 + i, 3 + i),
                    max_new_tokens=6 + i) for i in range(3)]
    gold = {f.rid: f.tokens for f in _engine(model, params).run(
        [(0, r) for r in reqs])}

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        server = await HttpServer(fe).start()
        out = {}

        async def client(i, req):
            toks, done = [], None
            async for kind, data in stream_generate(
                    server.host, server.port,
                    {"prompt": req.prompt,
                     "max_new_tokens": req.max_new_tokens, "id": i}):
                if kind == "token":
                    assert data["index"] == len(toks)
                    toks.append(data["token"])
                else:
                    assert kind == "done"
                    done = data
            out[i] = (toks, done)

        await asyncio.gather(*(client(r.rid, r) for r in reqs))
        await server.stop()
        await fe.close()
        return fe, out

    fe, out = asyncio.run(main())
    for r in reqs:
        toks, done = out[r.rid]
        assert toks == gold[r.rid]
        assert done["tokens"] == toks
        assert done["id"] == r.rid
        assert done["reason"] in ("stop", "length")
        assert done["ttft"] is not None
    _pool_clean(fe.engine)


def test_sse_sampled_parity(qwen_smoke):
    """A seeded-sampled stream over the wire matches the engine's."""
    cfg, model, params = qwen_smoke
    req = Request(rid=0, prompt=_prompt(cfg, 33, 5), max_new_tokens=6,
                  sampling=SamplingParams(temperature=0.8, top_k=8,
                                          seed=11))
    gold = _engine(model, params).run([(0, req)])[0].tokens

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        server = await HttpServer(fe).start()
        toks, done = [], None
        async for kind, data in stream_generate(
                server.host, server.port,
                {"prompt": req.prompt, "max_new_tokens": 6,
                 "temperature": 0.8, "top_k": 8, "seed": 11}):
            if kind == "token":
                toks.append(data["token"])
            else:
                done = data
        await server.stop()
        await fe.close()
        return fe, toks, done

    fe, toks, done = asyncio.run(main())
    assert toks == gold
    assert done["tokens"] == gold
    _pool_clean(fe.engine)


def test_stream_false_single_json(qwen_smoke):
    cfg, model, params = qwen_smoke
    req = Request(rid=0, prompt=_prompt(cfg, 25, 4), max_new_tokens=5)
    gold = _engine(model, params).run([(0, req)])[0].tokens

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        server = await HttpServer(fe).start()
        events = [ev async for ev in stream_generate(
            server.host, server.port,
            {"prompt": req.prompt, "max_new_tokens": 5,
             "stream": False})]
        await server.stop()
        await fe.close()
        return fe, events

    fe, events = asyncio.run(main())
    (kind, data), = events
    assert kind == "done"
    assert data["tokens"] == gold
    _pool_clean(fe.engine)


def test_group_request_completions_payload(qwen_smoke):
    """n > 1 over the wire: the done payload carries every completion,
    tokens == the primary completion's."""
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params, max_batch=6))
        server = await HttpServer(fe).start()
        done = None
        async for kind, data in stream_generate(
                server.host, server.port,
                {"prompt": _prompt(cfg, 31, 5), "max_new_tokens": 5,
                 "temperature": 0.8, "top_k": 8, "seed": 7, "n": 3}):
            if kind == "done":
                done = data
        await server.stop()
        await fe.close()
        return fe, done

    fe, done = asyncio.run(main())
    assert len(done["completions"]) == 3
    assert done["tokens"] == done["completions"][0]["tokens"]
    _pool_clean(fe.engine)


# ------------------------------------------------- endpoints / plumbing
def test_healthz_stats_and_404(qwen_smoke):
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        server = await HttpServer(fe).start()
        host, port = server.host, server.port
        status, health = await http_json(host, port, "GET", "/healthz")
        assert status == 200 and health["status"] == "ok"
        status, _ = await http_json(host, port, "GET", "/nope")
        assert status == 404
        async for _ in stream_generate(host, port,
                                       {"prompt": _prompt(cfg, 26, 3),
                                        "max_new_tokens": 2}):
            pass
        status, st = await http_json(host, port, "GET", "/stats")
        assert status == 200
        assert st["engine"]["steps"] > 0
        assert st["http"]["streams"] == 1
        assert set(st["queues"]) == set(st["caps"])
        assert st["pool"]["free_pages"] == st["pool"]["num_pages"]
        await server.stop()
        await fe.close()
        return fe

    fe = asyncio.run(main())
    _pool_clean(fe.engine)


# ----------------------------------------------------- admission control
def test_429_without_killing_in_flight(qwen_smoke):
    """A class at its queue cap answers 429; the running stream and the
    already-waiting one complete untouched."""
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params, max_batch=1))
        server = await HttpServer(fe,
                                  queue_caps={"standard": 1}).start()

        async def run_client(tag, ntok):
            toks, done = [], None
            async for kind, data in stream_generate(
                    server.host, server.port,
                    {"prompt": _prompt(cfg, 100 + tag, 4),
                     "max_new_tokens": ntok, "id": tag}):
                if kind == "token":
                    toks.append(data["token"])
                elif kind == "done":
                    done = data
            return toks, done

        a = asyncio.ensure_future(run_client(0, 24))
        while not fe.engine.sched.running:      # A holds the one slot
            await asyncio.sleep(0.005)
        b = asyncio.ensure_future(run_client(1, 4))
        while fe.queue_depth("standard") < 1:   # B parked in waiting
            await asyncio.sleep(0.005)
        events = [ev async for ev in stream_generate(
            server.host, server.port,
            {"prompt": _prompt(cfg, 102, 4), "max_new_tokens": 4})]
        (kind, data), = events
        assert kind == "error" and data["status"] == 429
        assert data["body"]["class"] == "standard"
        toks_a, done_a = await a
        toks_b, done_b = await b
        assert done_a is not None and done_a["tokens"] == toks_a
        assert done_b is not None and done_b["tokens"] == toks_b
        assert toks_a and toks_b
        assert server.http_stats["rejected_429"] == 1
        await server.stop()
        await fe.close()
        return fe

    fe = asyncio.run(main())
    _pool_clean(fe.engine)


def test_503_when_engine_down(qwen_smoke):
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        server = await HttpServer(fe).start()
        await fe.close()
        status, health = await http_json(server.host, server.port,
                                         "GET", "/healthz")
        events = [ev async for ev in stream_generate(
            server.host, server.port,
            {"prompt": [1, 2], "max_new_tokens": 2})]
        await server.stop()
        return server, status, health, events

    server, status, health, events = asyncio.run(main())
    assert status == 503 and health["status"] == "closed"
    (kind, data), = events
    assert kind == "error" and data["status"] == 503
    assert server.http_stats["unavailable_503"] == 1


def test_400_over_the_wire(qwen_smoke):
    """Misuse maps to 400 whether caught at the door (unknown field,
    over-ceiling prompt) or by the engine (contradictory knobs raising
    InvalidRequestError before the first token)."""
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        server = await HttpServer(fe).start()
        statuses = []
        for payload in ({"prompt": [1], "bogus": 1},
                        {"prompt": _prompt(cfg, 27, 4),
                         "max_new_tokens": 4096},
                        {"prompt": _prompt(cfg, 28, 4),
                         "max_new_tokens": 4, "n": 4, "best_of": 2}):
            events = [ev async for ev in stream_generate(
                server.host, server.port, payload)]
            (kind, data), = events
            statuses.append((kind, data["status"]))
        assert server.http_stats["bad_request_400"] == 3
        await server.stop()
        await fe.close()
        return fe, statuses

    fe, statuses = asyncio.run(main())
    assert statuses == [("error", 400)] * 3
    _pool_clean(fe.engine)


# ------------------------------------------------------ tenant fairness
def test_tenant_fairness_within_class(qwen_smoke):
    """Three waiting requests from tenant alice and one from tenant bob
    (same class, one slot): bob's goes next after the running one, not
    last - round-robin across tenants, FCFS within one."""
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params, max_batch=1))
        server = await HttpServer(fe).start()
        order = []

        async def run_client(tag, tenant, ntok):
            done = None
            async for kind, data in stream_generate(
                    server.host, server.port,
                    {"prompt": _prompt(cfg, 120 + ntok, 4),
                     "max_new_tokens": ntok, "id": tag},
                    tenant=tenant):
                if kind == "done":
                    done = data
            assert done is not None and done["id"] == tag
            order.append(tag)

        tasks = [asyncio.ensure_future(run_client("A1", "alice", 24))]
        while not fe.engine.sched.running:      # A1 admitted first
            await asyncio.sleep(0.005)
        for depth, tag in enumerate(("A2", "A3"), start=1):
            tasks.append(asyncio.ensure_future(
                run_client(tag, "alice", 6)))
            while fe.queue_depth("standard") < depth:
                await asyncio.sleep(0.005)
        tasks.append(asyncio.ensure_future(run_client("B1", "bob", 6)))
        while fe.queue_depth("standard") < 3:
            await asyncio.sleep(0.005)
        await asyncio.gather(*tasks)
        await server.stop()
        await fe.close()
        return fe, order

    fe, order = asyncio.run(main())
    assert order == ["A1", "B1", "A2", "A3"]
    _pool_clean(fe.engine)


# ------------------------------------------------ disconnect / slow read
def test_disconnect_cancels_and_frees(qwen_smoke):
    """Abruptly closing the socket mid-stream cancels the request;
    slot and pages come back refcount-clean."""
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        server = await HttpServer(fe).start()
        gen = stream_generate(server.host, server.port,
                              {"prompt": _prompt(cfg, 130, 5),
                               "max_new_tokens": 48})
        got = 0
        async for kind, _data in gen:
            if kind == "token":
                got += 1
                if got >= 2:
                    break
        await gen.aclose()                # socket closed mid-stream
        for _ in range(1000):
            if fe.engine.stats["cancelled"] >= 1:
                break
            await asyncio.sleep(0.005)
        await fe.drain()
        assert server.http_stats["disconnects"] >= 1
        await server.stop()
        await fe.close()
        return fe

    fe = asyncio.run(main())
    assert fe.engine.stats["cancelled"] == 1
    fr = fe.result(0)
    assert fr is not None and fr.reason == "cancelled"
    _pool_clean(fe.engine)


def test_slow_reader_backpressure_cancels(qwen_smoke):
    """A client that sends its request and then never reads: SSE
    padding + a tiny server send buffer make TCP fill at test scale,
    the pump's drain() blocks, the frontend's bounded stream queue
    overflows, and the request is cancelled instead of buffering
    forever."""
    cfg, model, params = qwen_smoke

    async def main():
        eng = _engine(model, params, max_seq=128)
        fe = AsyncFrontend(eng, stream_buffer=4)
        server = await HttpServer(fe, event_pad=2048, sndbuf=4608,
                                  drain_timeout=1.0).start()
        sock = socket.socket()
        # A small receive window on the client side makes the server's
        # writes back up after a handful of padded events.
        sock.setsockopt(socket.SOL_SOCKET, socket.SO_RCVBUF, 4096)
        sock.setblocking(False)
        await asyncio.get_running_loop().sock_connect(
            sock, (server.host, server.port))
        reader, writer = await asyncio.open_connection(sock=sock)
        body = json.dumps({"prompt": _prompt(cfg, 140, 4),
                           "max_new_tokens": 96}).encode()
        writer.write((f"POST /v1/generate HTTP/1.1\r\nhost: t\r\n"
                      f"content-length: {len(body)}\r\n\r\n").encode()
                     + body)
        await writer.drain()
        # ... and never read a byte of the response.
        for _ in range(2000):
            if eng.stats["stream_overflows"] >= 1:
                break
            await asyncio.sleep(0.005)
        assert eng.stats["stream_overflows"] >= 1
        writer.close()
        with contextlib.suppress(Exception):
            await writer.wait_closed()
        await fe.drain()
        await server.stop()
        await fe.close()
        return fe

    fe = asyncio.run(main())
    assert fe.engine.stats["cancelled"] >= 1
    fr = fe.result(0)
    assert fr is not None and fr.reason == "cancelled"
    assert len(fr.tokens) < 96
    _pool_clean(fe.engine)
