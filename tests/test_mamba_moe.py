"""Mamba2 SSD + MoE layer correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import mamba2, moe


def _cfg():
    return get_config("mamba2-2.7b").reduced()


def test_ssd_chunked_equals_recurrence():
    """Chunked SSD scan == token-by-token recurrent decode, incl. state."""
    cfg = _cfg()
    p, _ = mamba2.mamba_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    y_chunk, st_chunk = mamba2.mamba_apply(p, x, cfg, chunk=16)
    state = mamba2.mamba_init_state(cfg, 2)
    ys = []
    for t in range(32):
        yt, state = mamba2.mamba_apply(p, x[:, t:t + 1], cfg, state=state)
        ys.append(yt)
    y_rec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_rec),
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st_chunk["ssm"]),
                               np.asarray(state["ssm"]), atol=2e-4)


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunk_size_invariance(chunk):
    cfg = _cfg()
    p, _ = mamba2.mamba_init(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((1, 32, cfg.d_model)), jnp.float32)
    y_ref, _ = mamba2.mamba_apply(p, x, cfg, chunk=32)
    y, _ = mamba2.mamba_apply(p, x, cfg, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=2e-4)


def test_ssd_causality():
    """Future tokens must not influence earlier outputs."""
    cfg = _cfg()
    p, _ = mamba2.mamba_init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((1, 24, cfg.d_model)), jnp.float32)
    y1, _ = mamba2.mamba_apply(p, x, cfg, chunk=8)
    x2 = x.at[:, 16:].set(rng.standard_normal((1, 8, cfg.d_model)))
    y2, _ = mamba2.mamba_apply(p, x2, cfg, chunk=8)
    np.testing.assert_allclose(np.asarray(y1[:, :16]),
                               np.asarray(y2[:, :16]), atol=1e-5)


def _moe_cfg(**kw):
    base = get_config("granite-moe-1b-a400m").reduced()
    return dataclasses.replace(base, **kw)


def test_moe_output_finite_and_weighted():
    cfg = _moe_cfg()
    p, _ = moe.moe_init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    out, aux = moe.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(aux["load_balance"]) > 0


def test_moe_capacity_drops_tokens():
    """With tiny capacity factor most tokens overflow -> output shrinks."""
    cfg_small = _moe_cfg(capacity_factor=0.05)
    cfg_big = _moe_cfg(capacity_factor=16.0)
    p, _ = moe.moe_init(jax.random.PRNGKey(1), cfg_big)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg_big.d_model)), jnp.float32)
    out_small, _ = moe.moe_apply(p, x, cfg_small)
    out_big, _ = moe.moe_apply(p, x, cfg_big)
    assert float(jnp.abs(out_small).mean()) < float(jnp.abs(out_big).mean())


def test_moe_high_capacity_is_exact_topk():
    """cf -> inf: every token reaches its experts; compare to dense compute."""
    cfg = _moe_cfg(capacity_factor=32.0)
    p, _ = moe.moe_init(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(2)
    b, s = 1, 8
    x = jnp.asarray(rng.standard_normal((b, s, cfg.d_model)), jnp.float32)
    out, _ = moe.moe_apply(p, x, cfg)

    # dense reference: run all experts, combine top-k weights
    xt = np.asarray(x).reshape(s, cfg.d_model)
    logits = xt @ np.asarray(p["router"])
    probs = np.exp(logits - logits.max(-1, keepdims=True))
    probs = probs / probs.sum(-1, keepdims=True)
    topk = np.argsort(-probs, axis=-1)[:, :cfg.moe_top_k]
    ref = np.zeros_like(xt)
    for t in range(s):
        w = probs[t, topk[t]]
        w = w / w.sum()
        for j, e in enumerate(topk[t]):
            g = xt[t] @ np.asarray(p["wg"][e])
            u = xt[t] @ np.asarray(p["wu"][e])
            act = (g / (1 + np.exp(-g))) * u
            ref[t] += w[j] * (act @ np.asarray(p["wd"][e]))
    np.testing.assert_allclose(np.asarray(out).reshape(s, -1), ref,
                               atol=1e-3)
