"""Async streaming frontend tests (AsyncFrontend over ServingEngine).

Covers, per the streaming/SLA subsystem spec:
  * streamed tokens == ServingEngine.run's batch tokens (greedy parity);
  * sequence groups burst the primary completion at retirement and the
    full FinishedRequest (completions, scores) lands in result();
  * abandoning a stream cancels the request wherever it is - before
    admission, mid-prefill (chunked), mid-decode - and leaves the paged
    pool fully free with check_invariants clean (no leaked refcounts);
  * drain()/close(drain=False) semantics, per-request resource
    rejection and loud InvalidRequestError propagation;
  * long-running-server regressions: bounded results LRU with claiming
    result(), crashed drive task failing loudly (streams raise, submits
    reject) instead of silent restart, group-cancel snapshotting the
    primary branch's tokens, bounded stream queues cancelling a stalled
    reader;
  * launch-layer CLI plumbing: merge_xla_flags preserves/raises a
    pre-existing XLA_FLAGS (the ensure_host_devices bugfix) and
    parse_prefill_budget accepts none/int/adaptive.
"""
import asyncio
import contextlib

import numpy as np
import pytest

import jax

from repro.serving import AsyncFrontend, InvalidRequestError, Request
from repro.serving import SamplingParams, ServingEngine


@pytest.fixture(scope="module")
def qwen_smoke():
    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 4)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seq", 64)
    return ServingEngine(model, params, **kw)


def _prompt(cfg, seed, n):
    rng = np.random.default_rng(seed)
    return rng.integers(1, cfg.vocab_size, n).tolist()


def _pool_clean(engine):
    """Every page back in the allocator, bookkeeping consistent."""
    engine.cache.check_invariants()
    assert engine.cache.available_page_count == engine.cache.num_pages
    assert not engine.sched.has_work


# ------------------------------------------------------ streaming parity
def test_stream_parity_with_engine_run(qwen_smoke):
    """Tokens streamed by the frontend == the synchronous batch loop's,
    request by request, and the FinishedRequest carries a TTFT."""
    cfg, model, params = qwen_smoke
    reqs = [Request(rid=i, prompt=_prompt(cfg, 20 + i, 3 + i),
                    max_new_tokens=6 + i) for i in range(3)]
    gold = {f.rid: f.tokens for f in _engine(model, params).run(
        [(0, r) for r in reqs])}

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        streams = {r.rid: fe.submit(r) for r in reqs}

        async def consume(rid, gen):
            return rid, [tok async for tok in gen]

        got = dict(await asyncio.gather(
            *(consume(rid, g) for rid, g in streams.items())))
        await fe.close()
        return fe, got

    fe, got = asyncio.run(main())
    assert got == gold
    for r in reqs:
        fr = fe.result(r.rid)
        assert fr.tokens == gold[r.rid]
        assert fr.reason in ("stop", "length")
        assert fr.ttft is not None and fr.ttft >= 0.0
    _pool_clean(fe.engine)


def test_group_request_bursts_at_retirement(qwen_smoke):
    """A parallel-sampling group streams its primary completion in one
    burst when the group retires; result() has every completion."""
    cfg, model, params = qwen_smoke
    req = Request(rid=0, prompt=_prompt(cfg, 31, 5), max_new_tokens=5,
                  sampling=SamplingParams(temperature=0.8, top_k=8,
                                          seed=7), n=3)

    async def main():
        fe = AsyncFrontend(_engine(model, params, max_batch=6))
        toks = [tok async for tok in fe.submit(req)]
        await fe.close()
        return fe, toks

    fe, toks = asyncio.run(main())
    fr = fe.result(0)
    assert toks == fr.tokens
    assert len(fr.completions) == 3
    assert fr.tokens == fr.completions[0].tokens
    _pool_clean(fe.engine)


# -------------------------------------------------------- cancellation
async def _abandon(gen):
    """Abandon a live stream the way a disconnecting client does: the
    task awaiting the next token gets cancelled, which runs the
    generator's finally block (an unstarted generator's aclose() would
    skip it)."""
    nxt = asyncio.ensure_future(gen.__anext__())
    await asyncio.sleep(0)        # let the stream body start
    nxt.cancel()
    with contextlib.suppress(asyncio.CancelledError, StopAsyncIteration):
        await nxt
    await gen.aclose()


def test_cancel_at_first_step_frees_everything(qwen_smoke):
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        gen = fe.submit(Request(rid=0, prompt=_prompt(cfg, 40, 6),
                                max_new_tokens=40))
        await _abandon(gen)       # dropped before/at the first step
        await fe.close()
        return fe

    fe = asyncio.run(main())
    fr = fe.result(0)
    assert fr.reason == "cancelled"
    assert len(fr.tokens) < 40
    _pool_clean(fe.engine)


def test_cancel_mid_prefill_frees_pages(qwen_smoke):
    """Abandon a chunked prefill after >= 1 chunk ran but before the
    first token: partially-materialized KV pages must come back."""
    cfg, model, params = qwen_smoke

    async def main():
        # 24-token prompt at budget 4 -> 6 prefill steps before any
        # token, so waiting for the first chunk lands us mid-prefill.
        eng = _engine(model, params, prefill_budget=4)
        fe = AsyncFrontend(eng)
        gen = fe.submit(Request(rid=0, prompt=_prompt(cfg, 41, 24),
                                max_new_tokens=8))
        nxt = asyncio.ensure_future(gen.__anext__())
        while eng.stats["prefill_chunks"] == 0:
            await asyncio.sleep(0.001)
        nxt.cancel()              # client disconnects mid-prefill
        with contextlib.suppress(asyncio.CancelledError,
                                 StopAsyncIteration):
            await nxt
        await gen.aclose()
        await fe.close()
        return fe

    fe = asyncio.run(main())
    fr = fe.result(0)
    assert fr.reason == "cancelled"
    assert fe.engine.stats["cancelled"] == 1
    # mid-prefill: the engine ran chunks but never emitted a token
    assert fe.engine.stats["prefill_chunks"] >= 1
    _pool_clean(fe.engine)


def test_cancel_mid_decode_frees_pages(qwen_smoke):
    """Break out of the token stream mid-decode: slot + pages freed,
    refcounts clean, snapshot of generated-so-far in the result."""
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        gen = fe.submit(Request(rid=0, prompt=_prompt(cfg, 42, 6),
                                max_new_tokens=48))
        got = []
        async for tok in gen:
            got.append(tok)
            if len(got) == 3:
                break
        await gen.aclose()
        await fe.close()
        return fe, got

    fe, got = asyncio.run(main())
    fr = fe.result(0)
    assert fr.reason == "cancelled"
    assert len(got) == 3
    # the cancel snapshot holds everything generated up to the cancel -
    # at least what the client saw, possibly a step more
    assert fr.tokens[:3] == got
    assert fe.engine.stats["cancelled"] == 1
    _pool_clean(fe.engine)


def test_close_without_drain_cancels_live_streams(qwen_smoke):
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        gens = [fe.submit(Request(rid=i, prompt=_prompt(cfg, 50 + i, 4),
                                  max_new_tokens=40)) for i in range(3)]
        [await g.__anext__() for g in gens]      # all three decoding
        await fe.close(drain=False)
        for g in gens:
            await g.aclose()
        return fe

    fe = asyncio.run(main())
    assert sorted(fe.results) == [0, 1, 2]
    assert all(fr.reason == "cancelled" for fr in fe.results.values())
    _pool_clean(fe.engine)


# ------------------------------------------------- rejection / misuse
def test_resource_rejection_and_invalid_request(qwen_smoke):
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params))
        # prompt + budget over the per-sequence ceiling: rejected, not
        # raised (mirrors ServingEngine.run)
        toks = [t async for t in fe.submit(
            Request(rid=0, prompt=_prompt(cfg, 60, 8),
                    max_new_tokens=4096))]
        assert toks == []
        # contradictory knobs: raised out of the client's generator
        with pytest.raises(InvalidRequestError):
            async for _ in fe.submit(Request(rid=1,
                                             prompt=_prompt(cfg, 61, 4),
                                             max_new_tokens=4,
                                             n=4, best_of=2)):
                pass
        # the frontend survives both and still serves
        good = [t async for t in fe.submit(
            Request(rid=2, prompt=_prompt(cfg, 62, 4),
                    max_new_tokens=3))]
        await fe.close()
        return fe, good

    fe, good = asyncio.run(main())
    assert fe.result(0).reason == "rejected"
    assert fe.engine.stats["rejected"] == 1
    assert len(good) == 3 or fe.result(2).reason == "stop"
    _pool_clean(fe.engine)


# ------------------------------------- long-running-server regressions
def test_results_bounded_lru_and_claim(qwen_smoke):
    """``results`` used to grow without bound on a long-running server.
    Now result() claims (removes) its entry and unclaimed entries age
    out oldest-first past ``max_results``, counted in engine.stats."""
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params), max_results=2)
        for i in range(3):
            async for _ in fe.submit(Request(rid=i,
                                             prompt=_prompt(cfg, 70 + i, 4),
                                             max_new_tokens=3)):
                pass
        await fe.close()
        return fe

    fe = asyncio.run(main())
    assert fe.engine.stats["results_evicted"] == 1
    assert fe.result(0) is None          # oldest entry aged out
    fr = fe.result(1)
    assert fr is not None and fr.rid == 1
    assert fe.result(1) is None          # claimed: removed on first read
    assert fe.result(2) is not None
    _pool_clean(fe.engine)


def test_drive_crash_fails_loudly(qwen_smoke):
    """A crashed drive task used to be silently restarted by the next
    submit, discarding the exception and hammering a broken engine.
    Now the failure raises out of every live stream and later submits
    reject with the original failure chained."""
    cfg, model, params = qwen_smoke

    async def main():
        eng = _engine(model, params)
        fe = AsyncFrontend(eng)

        def bad_step():
            raise RuntimeError("device fell over")

        eng.step = bad_step
        gen = fe.submit(Request(rid=0, prompt=_prompt(cfg, 80, 4),
                                max_new_tokens=8))
        with pytest.raises(RuntimeError, match="device fell over"):
            async for _ in gen:
                pass
        assert fe.failed
        with pytest.raises(RuntimeError, match="frontend failed"):
            fe.submit(Request(rid=1, prompt=_prompt(cfg, 81, 4),
                              max_new_tokens=4))
        await fe.close()          # still clean to close
        return fe

    asyncio.run(main())


def test_group_cancel_snapshots_primary_tokens(qwen_smoke):
    """Cancelling a fanned-out group mid-decode used to record
    tokens=[] (the snapshot only looked at plain requests).  Now the
    primary live branch's generated-so-far rides the cancel result."""
    cfg, model, params = qwen_smoke

    async def main():
        eng = _engine(model, params, max_batch=6)
        fe = AsyncFrontend(eng)
        gen = fe.submit(Request(
            rid=0, prompt=_prompt(cfg, 90, 5), max_new_tokens=40,
            sampling=SamplingParams(temperature=0.8, top_k=8, seed=3),
            n=3))
        nxt = asyncio.ensure_future(gen.__anext__())
        # A group streams nothing until retirement: wait until some
        # branch has generated, then disconnect.
        while not any(r.generated
                      for r in eng.sched.running.values()):
            await asyncio.sleep(0.001)
        nxt.cancel()
        with contextlib.suppress(asyncio.CancelledError,
                                 StopAsyncIteration):
            await nxt
        await gen.aclose()
        await fe.close()
        return fe

    fe = asyncio.run(main())
    fr = fe.result(0)
    assert fr.reason == "cancelled"
    assert len(fr.tokens) > 0            # the regression: was []
    _pool_clean(fe.engine)


def test_stream_overflow_cancels_stalled_reader(qwen_smoke):
    """Per-stream queues used to be unbounded: a reader that never
    drained its stream buffered every token forever while holding its
    slot and pages.  Now a full queue cancels the request (the reader
    is presumed disconnected) and the full token list still rides the
    FinishedRequest."""
    cfg, model, params = qwen_smoke

    async def main():
        fe = AsyncFrontend(_engine(model, params), stream_buffer=2)
        fe.submit(Request(rid=0, prompt=_prompt(cfg, 95, 4),
                          max_new_tokens=40))   # generator never read
        await fe.drain()
        await fe.close()
        return fe

    fe = asyncio.run(main())
    fr = fe.result(0)
    assert fr.reason == "cancelled"
    assert fe.engine.stats["stream_overflows"] >= 1
    assert len(fr.tokens) >= 2           # snapshot kept generated-so-far
    assert len(fr.tokens) < 40           # and it really was cut short
    _pool_clean(fe.engine)


# ------------------------------------------- launch-layer CLI plumbing
def test_merge_xla_flags_preserves_existing():
    from repro.launch.serve import merge_xla_flags
    # no prior flags: appended
    assert merge_xla_flags("", 4) == \
        "--xla_force_host_platform_device_count=4"
    # other flags preserved, count appended
    out = merge_xla_flags("--xla_cpu_foo=1 --xla_bar=baz", 2)
    assert out.split() == ["--xla_cpu_foo=1", "--xla_bar=baz",
                           "--xla_force_host_platform_device_count=2"]
    # pre-existing lower count raised (the CI env-block bug), order and
    # neighbors intact
    out = merge_xla_flags(
        "--xla_cpu_foo=1 --xla_force_host_platform_device_count=2 "
        "--xla_bar=baz", 4)
    assert out.split() == ["--xla_cpu_foo=1",
                           "--xla_force_host_platform_device_count=4",
                           "--xla_bar=baz"]
    # pre-existing higher count respected verbatim
    flags = "--xla_force_host_platform_device_count=8"
    assert merge_xla_flags(flags, 2) == flags


def test_parse_prefill_budget():
    import argparse
    from repro.launch.serve import parse_prefill_budget
    assert parse_prefill_budget("none") is None
    assert parse_prefill_budget("") is None
    assert parse_prefill_budget("adaptive") == "adaptive"
    assert parse_prefill_budget("Adaptive") == "adaptive"
    assert parse_prefill_budget("8") == 8
    with pytest.raises(argparse.ArgumentTypeError):
        parse_prefill_budget("fast")
