"""Fault-tolerant trainer: restart, determinism, straggler log, compression."""
import shutil

import numpy as np
import pytest

from repro.configs import get_config
from repro.models.model import build_model
from repro.runtime.trainer import Trainer, TrainerConfig


def _trainer(tmp_path, arch="qwen3-1.7b", **kw):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    tcfg = TrainerConfig(steps=10, ckpt_every=4, ckpt_dir=str(tmp_path),
                         seq_len=32, global_batch=4, warmup=2, **kw)
    return Trainer(model, tcfg)


def test_training_reduces_loss(tmp_path):
    tr = _trainer(tmp_path)
    tr.tcfg.steps = 30
    res = tr.run()
    losses = [m["loss"] for m in res["metrics"]]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_failure_injection_restarts_from_checkpoint(tmp_path):
    tr = _trainer(tmp_path)
    res = tr.run(fail_at={6: RuntimeError("injected node failure")})
    assert res["final_step"] == 10
    assert res["restarts"] == 1
    assert any("restarted from step 4" in e for e in res["events"])


def test_replayed_steps_are_deterministic(tmp_path):
    tr = _trainer(tmp_path)
    res = tr.run(fail_at={6: RuntimeError("boom")})
    by_step = {}
    for m in res["metrics"]:
        by_step.setdefault(m["step"], []).append(m["loss"])
    replayed = {k: v for k, v in by_step.items() if len(v) > 1}
    assert replayed, "failure should force replay of steps 4..5"
    for step, losses in replayed.items():
        assert abs(losses[0] - losses[1]) < 1e-4, step


def test_too_many_failures_raises(tmp_path):
    tr = _trainer(tmp_path)
    tr.tcfg.max_restarts = 1
    with pytest.raises(RuntimeError):
        tr.run(fail_at={2: RuntimeError("a"), 3: RuntimeError("b"),
                        5: RuntimeError("c")})


def test_resume_from_existing_checkpoints(tmp_path):
    tr = _trainer(tmp_path)
    tr.tcfg.steps = 8
    tr.run()
    tr2 = _trainer(tmp_path)
    tr2.tcfg.steps = 10
    res = tr2.run()
    assert any("resumed from step 8" in e for e in res["events"])
    assert res["final_step"] == 10
    assert len(res["metrics"]) == 2  # only steps 8, 9 executed


def test_grad_compression_trains(tmp_path):
    tr = _trainer(tmp_path, grad_compression=True)
    tr.tcfg.steps = 12
    res = tr.run()
    losses = [m["loss"] for m in res["metrics"]]
    assert np.isfinite(losses).all()
