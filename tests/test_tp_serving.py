"""Tensor-parallel paged serving: ACC-merge algebra + engine parity.

Two layers of coverage:

  * single-process merge algebra - ``merge_partials`` (Eq. 16) over
    arbitrary splits of the paged decode triplets (2/4-way page splits,
    head splits padded with the neutral element, fp and ``use_hfa``)
    must reproduce the unsplit paged decode;
  * subprocess tests on a simulated 2-device mesh (the device count must
    be fixed before jax initializes, so these shell out like
    ``test_distributed.py``) - the shard_map op path and the full
    ``ServingEngine`` must be token-exact against single-shard serving,
    with the per-shard pool cut in half.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels import decode as dk  # noqa: E402
from repro.kernels import ops  # noqa: E402
from repro.kernels import paged_decode as paged_k  # noqa: E402

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, devices: int = 2) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = REPO_SRC
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def _pool_setup(seed=0, b=3, hkv=2, g=2, d=64, page=8, pages_per_seq=4):
    """Random pools + page tables with ragged lengths (slot 0 free)."""
    rng = np.random.default_rng(seed)
    num_pages = b * pages_per_seq
    kp = jnp.asarray(rng.standard_normal((num_pages, page, hkv, d)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((num_pages, page, hkv, d)),
                     jnp.float32)
    pt = jnp.asarray(rng.permutation(num_pages).reshape(b, pages_per_seq)
                     .astype(np.int32))
    kvl = jnp.asarray([0, 27, page * pages_per_seq], jnp.int32)[:b]
    q = jnp.asarray(rng.standard_normal((b, 1, hkv * g, d)), jnp.float32)
    qg = q.reshape(b, hkv, g, d)
    return q, qg, kp, vp, pt, kvl, page, pages_per_seq


@pytest.mark.parametrize("use_hfa", [False, True])
@pytest.mark.parametrize("parts", [2, 4])
def test_merge_partials_page_splits_match_unsplit(use_hfa, parts):
    """Triplets computed over disjoint page ranges, merged with the
    log-domain ACC rule, must match the unsplit paged decode."""
    impl = "hfa" if use_hfa else "fa2"
    q, qg, kp, vp, pt, kvl, page, pps = _pool_setup()
    ref = ops.paged_decode_attention(q, kp, vp, pt, kvl, impl=impl)

    assert pps % parts == 0
    pp = pps // parts
    span = pp * page
    trips = []
    for j in range(parts):
        kvl_j = jnp.clip(kvl - j * span, 0, span)
        trips.append(ops.paged_decode_partials(
            qg, kp, vp, pt[:, j * pp:(j + 1) * pp], kvl_j, impl=impl))
    o = jnp.stack([t[0] for t in trips])
    m = jnp.stack([t[1] for t in trips])
    l = jnp.stack([t[2] for t in trips])
    om, mm, lm = dk.merge_partials(o, m, l, use_hfa=use_hfa)
    got = dk.finalize_decode(om, lm, use_hfa=use_hfa)
    got = got.reshape(ref.shape)
    tol = 0.05 if use_hfa else 2e-5
    err = float(jnp.abs(got - ref).max())
    assert err < tol, (parts, use_hfa, err)


@pytest.mark.parametrize("use_hfa", [False, True])
def test_merge_neutral_head_padding_is_exact(use_hfa):
    """The TP identity: per-head triplets padded with the neutral
    element (o~=0, m=NEG_INF, l=0) and ACC-merged across "shards" must
    be *bit-equal* to the unsplit triplet - this is what makes
    KV-head-sharded serving token-exact, not just close."""
    impl = "hfa" if use_hfa else "fa2"
    q, qg, kp, vp, pt, kvl, _, _ = _pool_setup()
    o, m, l = ops.paged_decode_partials(qg, kp, vp, pt, kvl, impl=impl)
    hkv = o.shape[1]
    o_p, m_p, l_p = [], [], []
    for h in range(hkv):          # one "shard" per kv head
        sel = (jnp.arange(hkv) == h)[None, :, None]
        o_p.append(jnp.where(sel[..., None], o, 0.0))
        m_p.append(jnp.where(sel, m, dk.NEG_INF))
        l_p.append(jnp.where(sel, l, 0.0))
    om, mm, lm = dk.merge_partials(
        jnp.stack(o_p), jnp.stack(m_p), jnp.stack(l_p), use_hfa=use_hfa)
    assert bool(jnp.all(om == o)), "o~ not bit-equal after neutral merge"
    assert bool(jnp.all(lm == l)), "l not bit-equal after neutral merge"
    assert bool(jnp.all(mm == m)), "m not bit-equal after neutral merge"
    got = dk.finalize_decode(om, lm, use_hfa=use_hfa)
    ref = dk.finalize_decode(o, l, use_hfa=use_hfa)
    assert bool(jnp.all(got == ref))


@pytest.mark.parametrize("use_hfa", [False, True])
def test_merge_partials_verify_page_splits(use_hfa):
    """Same split-merge algebra for the K-column verify triplets."""
    impl = "hfa" if use_hfa else "fa2"
    rng = np.random.default_rng(1)
    b, hkv, g, d, page, pps, kw = 2, 2, 2, 64, 8, 4, 3
    num_pages = b * pps
    kp = jnp.asarray(rng.standard_normal((num_pages, page, hkv, d)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((num_pages, page, hkv, d)),
                     jnp.float32)
    pt = jnp.asarray(rng.permutation(num_pages).reshape(b, pps)
                     .astype(np.int32))
    sl = jnp.asarray([9, 20], jnp.int32)
    cl = jnp.asarray([kw, kw], jnp.int32)
    q = jnp.asarray(rng.standard_normal((b, kw, hkv * g, d)), jnp.float32)
    qg = jnp.swapaxes(q, 1, 2).reshape(b, hkv, g, kw, d)
    ref = ops.paged_verify_attention(q, kp, vp, pt, sl, cl, impl=impl)

    # Page-range split expressed through the verify positions: part j
    # sees positions [j*span, (j+1)*span) as its local window.
    span = (pps // 2) * page
    trips = []
    for j in range(2):
        sl_j = jnp.clip(sl - j * span, 0, span)
        cl_j = jnp.clip(sl + cl - j * span, 0, span) - sl_j
        trips.append(ops.paged_verify_partials(
            qg, kp, vp, pt[:, j * (pps // 2):(j + 1) * (pps // 2)],
            sl_j, cl_j, impl=impl))
    om, mm, lm = dk.merge_partials(
        jnp.stack([t[0] for t in trips]),
        jnp.stack([t[1] for t in trips]),
        jnp.stack([t[2] for t in trips]), use_hfa=use_hfa)
    got = dk.finalize_decode(om, lm, use_hfa=use_hfa)
    got = jnp.swapaxes(got.reshape(b, hkv * g, kw, d), 1, 2)
    tol = 0.05 if use_hfa else 2e-5
    err = float(jnp.abs(got - ref).max())
    assert err < tol, (use_hfa, err)


def test_shardmap_paged_decode_matches_single_shard():
    """collectives.shardmap_paged_attention (decode mode) on a 2-device
    mesh == append + paged decode on one device, bit-exact per head."""
    out = _run("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.kernels import ops
from repro.kernels import paged_decode as paged_k
from repro.parallel import collectives
from repro.launch.mesh import make_tp_mesh

mesh = make_tp_mesh(2)
rng = np.random.default_rng(0)
b, hkv, g, d, page, pps = 3, 2, 2, 64, 8, 4
num_pages = b * pps
kp = jnp.asarray(rng.standard_normal((num_pages, page, hkv, d)), jnp.float32)
vp = jnp.asarray(rng.standard_normal((num_pages, page, hkv, d)), jnp.float32)
pt = jnp.asarray(rng.permutation(num_pages).reshape(b, pps).astype(np.int32))
sl = jnp.asarray([0, 13, 31], jnp.int32)
q = jnp.asarray(rng.standard_normal((b, 1, hkv * g, d)), jnp.float32)
kn = jnp.asarray(rng.standard_normal((b, 1, hkv, d)), jnp.float32)
vn = jnp.asarray(rng.standard_normal((b, 1, hkv, d)), jnp.float32)

# single-shard reference: append then attend
kp1, vp1 = paged_k.append_kv(kp, vp, kn, vn, pt, sl)
kv_lens = jnp.where(sl > 0, sl + 1, 0)
ref = ops.paged_decode_attention(q, kp1, vp1, pt, kv_lens, impl="fa2")

sh = NamedSharding(mesh, P(None, None, "model", None))
pools = {"k_pages": jax.device_put(kp, sh), "v_pages": jax.device_put(vp, sh)}
out, pools2 = jax.jit(lambda *a: collectives.shardmap_paged_attention(
    *a, mesh=mesh, mode="decode", impl="fa2"))(
    q, kn, vn, pools, pt, sl, jnp.zeros_like(sl))
err = float(jnp.abs(out - ref).max())
print("ERR", err)
assert err < 1e-6, err
assert bool(jnp.all(jnp.asarray(pools2["k_pages"]) == kp1))
assert bool(jnp.all(jnp.asarray(pools2["v_pages"]) == vp1))
print("OK")
""")
    assert "OK" in out


def test_tp_engine_token_exact_vs_single_shard():
    """Full ServingEngine on a simulated 2-device mesh: greedy, spec-k,
    and seeded-sampling token streams must be identical to the
    single-shard engine, with per-shard pool bytes halved."""
    out = _run("""
import jax, numpy as np
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import Request, SamplingParams, ServingEngine
from repro.launch.mesh import make_tp_mesh

cfg = get_config("qwen3-1.7b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompts = [rng.integers(1, cfg.vocab_size, 12).tolist() for _ in range(5)]

def run(mesh, spec_k, sampling):
    eng = ServingEngine(model, params, max_batch=3, page_size=8,
                        max_seq=64, prefill_budget=16, spec_k=spec_k,
                        mesh=mesh)
    arrivals = [(i, Request(rid=i, prompt=list(p), max_new_tokens=8,
                            sampling=sampling)) for i, p in
                enumerate(prompts)]
    fin = eng.run(arrivals)
    eng.cache.check_invariants()
    return {f.rid: tuple(f.tokens) for f in fin}, eng

mesh = make_tp_mesh(2)
sp = SamplingParams(temperature=0.8, top_k=4, seed=7)
for spec_k, sampling in ((0, None), (2, None), (0, sp)):
    t1, e1 = run(None, spec_k, sampling)
    t2, e2 = run(mesh, spec_k, sampling)
    assert t1 == t2, (spec_k, sampling, t1, t2)
    assert e2.tp == 2
    assert e2.pool_bytes_per_shard() * 2 == e1.pool_bytes_per_shard()
    assert e2.stats["triplet_bytes"] > 0
    for leaf in jax.tree.leaves(e2.layers):
        shards = leaf.addressable_shards
        assert len(shards) == 2
        assert all(s.data.nbytes == leaf.nbytes // 2 for s in shards)
    print("case", spec_k, sampling is not None, "OK")
print("OK")
""")
    assert "OK" in out


def test_tp_engine_rejects_bad_head_split():
    """tp must divide the KV heads - reduced qwen3 has 2, so tp=3 is an
    early, explicit error rather than a wrong-answer shard."""
    out = _run("""
import jax, numpy as np
from jax.sharding import Mesh
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import ServingEngine

cfg = get_config("qwen3-1.7b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
mesh = Mesh(np.asarray(jax.devices()[:2]).reshape(1, 2)[:, :1],
            ("data", "model"))   # model axis size 1: fine (no TP)
eng = ServingEngine(model, params, max_batch=2, page_size=8, max_seq=32,
                    mesh=mesh)
assert eng.tp == 1
bad = Mesh(np.asarray(jax.devices()[:2]).reshape(2, 1), ("data", "model"))
# model axis 1 again - craft a real bad case via monkeypatched heads
import dataclasses
cfg3 = dataclasses.replace(cfg, n_kv_heads=3, n_heads=6)
model3 = build_model(cfg3)
from jax.sharding import Mesh as M
mesh2 = M(np.asarray(jax.devices()[:2]).reshape(1, 2), ("data", "model"))
try:
    ServingEngine(model3, params, max_batch=2, page_size=8, max_seq=32,
                  mesh=mesh2)
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "divide" in str(e), e
print("OK")
""")
    assert "OK" in out


def test_tp_engine_codec_token_parity():
    """Quantized page codecs under 2-way TP: int8/log16 engines on a
    simulated mesh emit the same greedy streams as their single-shard
    counterparts, the scale sidecars shard with the pages (per-shard
    pool bytes halve, every leaf split in two), and bytes_per_token is
    a property of the codec, not of the mesh."""
    out = _run("""
import jax, numpy as np
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import Request, ServingEngine
from repro.launch.mesh import make_tp_mesh

cfg = get_config("qwen3-1.7b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(3)
prompts = [rng.integers(1, cfg.vocab_size, 10).tolist()
           for _ in range(4)]

def run(mesh, codec):
    eng = ServingEngine(model, params, max_batch=3, page_size=8,
                        max_seq=64, mesh=mesh, kv_codec=codec)
    fin = eng.run([(i, Request(rid=i, prompt=list(p),
                               max_new_tokens=6))
                   for i, p in enumerate(prompts)])
    eng.cache.check_invariants()
    return {f.rid: tuple(f.tokens) for f in fin}, eng

mesh = make_tp_mesh(2)
for codec in ("int8", "log16"):
    t1, e1 = run(None, codec)
    t2, e2 = run(mesh, codec)
    assert t1 == t2, (codec, t1, t2)
    assert e2.bytes_per_token() == e1.bytes_per_token()
    assert e2.pool_bytes_per_shard() * 2 == e1.pool_bytes_per_shard()
    for leaf in jax.tree.leaves(e2.layers):
        assert len(leaf.addressable_shards) == 2
        assert all(s.data.nbytes == leaf.nbytes // 2
                   for s in leaf.addressable_shards)
    print(codec, "OK")
print("OK")
""")
    assert "OK" in out


def test_tp_dp_engine_token_exact_vs_tp_only():
    """Composed tp x dp mesh (4 simulated devices, batch sharded over
    the "data" axis) must be *token-identical* to the tp-only engine on
    the same batch - across plain greedy decode, chunked prefill, and
    speculative verify - because every data shard applies the full
    batch's KV scatter and its local partials merge through the same
    neutral-element ACC algebra.  The pool replicates over "data": each
    of the 4 shards holds total/tp bytes."""
    out = _run("""
import jax, numpy as np
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import Request, SamplingParams, ServingEngine
from repro.launch.mesh import make_tp_dp_mesh, make_tp_mesh

cfg = get_config("qwen3-1.7b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(0)
prompts = [rng.integers(1, cfg.vocab_size, 12).tolist() for _ in range(6)]

def run(mesh, spec_k, sampling, budget=16):
    eng = ServingEngine(model, params, max_batch=4, page_size=8,
                        max_seq=64, prefill_budget=budget, spec_k=spec_k,
                        mesh=mesh)
    arrivals = [(i, Request(rid=i, prompt=list(p), max_new_tokens=8,
                            sampling=sampling)) for i, p in
                enumerate(prompts)]
    fin = eng.run(arrivals)
    eng.cache.check_invariants()
    return {f.rid: tuple(f.tokens) for f in fin}, eng

tp = make_tp_mesh(2)
tpdp = make_tp_dp_mesh(2, 2)
sp = SamplingParams(temperature=0.8, top_k=4, seed=7)
for spec_k, sampling in ((0, None), (2, None), (0, sp)):
    t1, e1 = run(tp, spec_k, sampling)
    t2, e2 = run(tpdp, spec_k, sampling)
    assert t1 == t2, (spec_k, sampling, t1, t2)
    assert e2.tp == 2 and e2.dp == 2
    # pool bytes: sharded over tp, REPLICATED over dp
    assert e2.pool_bytes_per_shard() == e1.pool_bytes_per_shard()
    for leaf in jax.tree.leaves(e2.layers):
        shards = leaf.addressable_shards
        assert len(shards) == 4
        assert all(s.data.nbytes == leaf.nbytes // 2 for s in shards)
    print("case", spec_k, sampling is not None, "OK")
print("OK")
""", devices=4)
    assert "OK" in out


def test_dp_engine_rejects_indivisible_batch():
    """dp must divide max_batch (the slot dim is sharded evenly);
    anything else is an early explicit error, not a silent wrong
    shard - and a non-divisible *runtime* batch falls back to the
    replicated compute path rather than failing."""
    out = _run("""
import jax, numpy as np
from repro.configs import get_config
from repro.models.model import build_model
from repro.serving import Request, ServingEngine
from repro.launch.mesh import make_tp_dp_mesh

cfg = get_config("qwen3-1.7b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
mesh = make_tp_dp_mesh(1, 2)
try:
    ServingEngine(model, params, max_batch=3, page_size=8, max_seq=32,
                  mesh=mesh)
    raise SystemExit("expected ValueError")
except ValueError as e:
    assert "dp=2" in str(e) and "divide" in str(e), e
# divisible batch works end to end on a dp-only mesh
eng = ServingEngine(model, params, max_batch=2, page_size=8, max_seq=32,
                    mesh=mesh)
assert eng.tp == 1 and eng.dp == 2
rng = np.random.default_rng(5)
fin = eng.run([(i, Request(rid=i,
                           prompt=rng.integers(1, cfg.vocab_size,
                                               9).tolist(),
                           max_new_tokens=5)) for i in range(3)])
assert sorted(f.rid for f in fin) == [0, 1, 2]
eng.cache.check_invariants()
print("OK")
""")
    assert "OK" in out


def test_make_tp_dp_mesh_validation():
    """Mesh construction errors early and by name when the simulated
    device pool cannot cover dp * tp."""
    out = _run("""
from repro.launch.mesh import make_tp_dp_mesh
mesh = make_tp_dp_mesh(2, 1)
assert dict(mesh.shape) == {"data": 1, "model": 2}, dict(mesh.shape)
try:
    make_tp_dp_mesh(2, 2)           # needs 4, only 2 simulated
    raise SystemExit("expected RuntimeError")
except RuntimeError as e:
    assert "xla_force_host_platform_device_count" in str(e), e
for bad in ((0, 1), (1, 0)):
    try:
        make_tp_dp_mesh(*bad)
        raise SystemExit("expected ValueError")
    except ValueError:
        pass
print("OK")
""")
    assert "OK" in out
