"""Property-based tests for the multi-replica router placement core.

Random traces of admit / finish / replica-down / replica-up /
publish-prefix events flow through :class:`repro.serving.router.
RouterCore`, with the replica chain-hash tables modeled as plain sets
(exactly the ``in``-only surface the live system's ``_hash_page``
dicts expose).  After every event:

  * *no request lost or double-placed*: the placement map covers
    exactly the admitted-minus-finished-minus-lost rids, each on one
    live replica, and per-replica load equals the number of placements
    it carries (zero for dead replicas);
  * *prefix-hit placement*: whenever any live replica's table holds a
    (longest) chain-hash prefix of the request, the chosen replica ties
    that maximum - a request never recomputes KV a live replica
    already holds;
  * *least-loaded fallback bounds*: with no prefix hit anywhere, the
    chosen replica carried the minimum load among live replicas at
    placement time (ties to the lowest index);
  * ``down`` returns exactly the in-flight rids that were placed on
    the dead replica (the caller's re-place set), and is idempotent;
  * placement on an empty live set raises, double-placement raises.

Runs through hypothesis when installed, through a numpy manual-trace
battery otherwise.  Pure host logic, no jax.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # manual traces only
    HAVE_HYPOTHESIS = False

from repro.serving.router import RouterCore

N_REPLICAS = 4
N_CHAINS = 6          # distinct prompt families in a trace
MAX_DEPTH = 5         # chain-hash pages per family

N_OPS = 6


def manual_traces(n_traces, max_len, n_ops, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n_traces):
        length = int(rng.integers(1, max_len + 1))
        yield [(int(rng.integers(0, n_ops)), int(rng.integers(0, 10 ** 6)))
               for _ in range(length)]


def _chain(base: int, depth: int) -> list[tuple[int, int]]:
    """Chain hashes of a prompt family: hash i covers pages 0..i, so a
    table holding ``_chain(b, k)`` holds every shorter prefix too."""
    return [(base, i) for i in range(depth)]


class _Driver:
    """Drives RouterCore the way Router does, with oracle bookkeeping
    (expected placement/load recomputed independently) checked after
    every event."""

    def __init__(self):
        self.tables = [set() for _ in range(N_REPLICAS)]
        self.core = RouterCore(self.tables)
        self.rid = 0
        self.in_flight: dict[int, tuple[int, list]] = {}  # rid -> (rep, h)
        self.finished: set[int] = set()
        self.lost: set[int] = set()

    # ------------------------------------------------------------ checks
    def check(self):
        core = self.core
        assert core.live <= set(range(N_REPLICAS))
        assert set(core.placement) == set(self.in_flight), \
            "placement map lost or kept the wrong rids"
        for rid, (replica, _h) in self.in_flight.items():
            assert core.placement[rid] == replica, "request moved"
        for i in range(N_REPLICAS):
            expect = sum(1 for r in core.placement.values() if r == i)
            if i in core.live:
                assert core.load[i] == expect, (i, core.load, expect)
            else:
                assert core.load[i] == 0, "dead replica carries load"
        # disjoint request lifecycles
        assert not (set(self.in_flight) & self.finished)
        assert not (set(self.in_flight) & self.lost)

    # --------------------------------------------------------------- ops
    def _hashes(self, rng):
        base = int(rng.integers(0, N_CHAINS))
        depth = int(rng.integers(0, MAX_DEPTH + 1))
        return _chain(base, depth)

    def place(self, rng):
        hashes = self._hashes(rng)
        if not self.core.live:
            with pytest.raises(RuntimeError):
                self.core.place(self.rid, hashes)
            return
        # oracle: best (-hits, load, index) over live replicas
        want = min(sorted(self.core.live),
                   key=lambda i: (-self.core.prefix_hits(i, hashes),
                                  self.core.load[i], i))
        want_load = self.core.load[want]
        min_load = min(self.core.load[i] for i in self.core.live)
        got = self.core.place(self.rid, hashes)
        assert got == want, (got, want)
        got_hits = self.core.prefix_hits(got, hashes)
        max_hits = max(self.core.prefix_hits(i, hashes)
                       for i in self.core.live)
        assert got_hits == max_hits, "a better prefix replica was live"
        if max_hits == 0:
            # pure load-balance fallback: minimal load, lowest index tie
            assert want_load == min_load
        self.in_flight[self.rid] = (got, hashes)
        # double-placement is refused
        with pytest.raises(ValueError):
            self.core.place(self.rid, hashes)
        self.rid += 1

    def finish(self, rng):
        if not self.in_flight:
            return
        rids = sorted(self.in_flight)
        rid = rids[int(rng.integers(len(rids)))]
        replica, _ = self.in_flight.pop(rid)
        got = self.core.finish(rid)
        assert got == replica
        self.finished.add(rid)

    def down(self, rng):
        replica = int(rng.integers(0, N_REPLICAS))
        expect = sorted(rid for rid, (r, _) in self.in_flight.items()
                        if r == replica and replica in self.core.live)
        lost = self.core.down(replica)
        assert lost == expect, "down() must return exactly the dead "\
            "replica's in-flight rids"
        for rid in lost:
            del self.in_flight[rid]
            self.lost.add(rid)
        assert self.core.down(replica) == []          # idempotent
        assert replica not in self.core.live

    def up(self, rng):
        replica = int(rng.integers(0, N_REPLICAS))
        self.core.up(replica)
        assert replica in self.core.live
        self.core.up(replica)                          # idempotent

    def publish(self, rng):
        """A replica retires (or imports, via disagg handoff) a prompt
        prefix: its table gains the chain - future placements of that
        family must prefer it."""
        replica = int(rng.integers(0, N_REPLICAS))
        base = int(rng.integers(0, N_CHAINS))
        depth = int(rng.integers(1, MAX_DEPTH + 1))
        self.tables[replica].update(_chain(base, depth))

    def evict(self, rng):
        """LRU aging on a replica: its table shrinks from the *tail* of
        a chain (the head hash ages out last in the real cache only in
        adversarial orders - the router must not assume either)."""
        replica = int(rng.integers(0, N_REPLICAS))
        if self.tables[replica]:
            drop = sorted(self.tables[replica])
            k = int(rng.integers(1, len(drop) + 1))
            for h in drop[:k]:
                self.tables[replica].discard(h)


def _run_trace(ops):
    d = _Driver()
    dispatch = [d.place, d.place, d.finish, d.down, d.up, d.publish]
    assert len(dispatch) == N_OPS
    for code, seed in ops:
        rng = np.random.default_rng(seed)
        dispatch[code](rng)
        if rng.random() < 0.2:
            d.evict(rng)
        d.check()
    # teardown: finish everything in flight; the router is empty
    for rid in sorted(d.in_flight):
        d.core.finish(rid)
    assert not d.core.placement
    for i in d.core.live:
        assert d.core.load[i] == sum(
            1 for r in d.core.placement.values() if r == i) == 0


if HAVE_HYPOTHESIS:
    op_strategy = st.lists(
        st.tuples(st.integers(0, N_OPS - 1), st.integers(0, 10 ** 6)),
        min_size=1, max_size=120)

    @settings(max_examples=80, deadline=None)
    @given(ops=op_strategy)
    def test_router_random_trace(ops):
        _run_trace(ops)


def test_router_trace_manual():
    """No-hypothesis fallback: the same driver over numpy traces."""
    for i in range(5):
        for ops in manual_traces(60, 120, N_OPS, seed=300 + i):
            _run_trace(ops)


# ----------------------------------------------------- directed checks
def test_router_prefers_longest_prefix():
    tables = [set(_chain(0, 1)), set(_chain(0, 3)), set()]
    core = RouterCore(tables)
    assert core.place(0, _chain(0, 4)) == 1        # 3 hits beat 1
    assert core.place(1, _chain(5, 2)) == 0        # no hits: least loaded
    # replica 1 down: the shorter prefix still beats a cold replica
    assert core.down(1) == [0]
    assert core.place(2, _chain(0, 4)) == 0


def test_router_tie_breaks_load_then_index():
    core = RouterCore([set(), set(), set()])
    assert core.place(0, []) == 0
    assert core.place(1, []) == 1
    assert core.place(2, []) == 2
    core.finish(1)
    assert core.place(3, []) == 1                  # least loaded wins
    assert core.place(4, []) == 0                  # tie: lowest index


def test_router_needs_a_replica():
    with pytest.raises(ValueError):
        RouterCore([])
    core = RouterCore([set()])
    core.down(0)
    with pytest.raises(RuntimeError):
        core.place(0, [])
