"""Property-based tests for PagedKVCache sharing semantics.

Drives the block pool through random admit / chunked-prefill / append /
fork / free traces - including prefix claiming and copy-on-write - and
asserts after every op that ``check_invariants`` holds (which includes
refcount conservation: stored per-page refcounts must equal the number
of page-table references across slots) and that pages never leak:
free + cached + owned always partitions the pool.

Pure host logic, no jax.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving import PagedKVCache  # noqa: E402

PAGE = 4
NUM_PAGES = 24
MAX_BATCH = 5
PAGES_PER_SEQ = 6

# A small base sequence: prompts are prefixes of it plus a random tail,
# which makes hash-chain prefix hits (and thus page sharing) common.
BASE = list(range(100, 100 + PAGES_PER_SEQ * PAGE))

op_strategy = st.lists(
    st.tuples(st.integers(0, 5), st.integers(0, 10 ** 6)),
    min_size=1, max_size=80)


class _Driver:
    """Mirrors the engine's use of the cache; tracks the token stream
    backing every slot so register_pages stays content-consistent."""

    def __init__(self):
        self.c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ)
        self.streams: dict[int, list[int]] = {}     # slot -> token stream

    def check(self):
        self.c.check_invariants()
        # drained copies must reference distinct, in-range pages
        for src, dst in self.c.take_pending_copies():
            assert 0 <= src < NUM_PAGES and 0 <= dst < NUM_PAGES
            assert src != dst
        assert self.c.free_page_count + len(self.c._cached) + \
            len({p for ps in self.c._slot_pages.values() for p in ps}) \
            == NUM_PAGES

    # ------------------------------------------------------------- ops
    def admit(self, rng):
        n_shared = int(rng.integers(0, len(BASE)))
        tail_len = int(rng.integers(1, 6))
        toks = BASE[:n_shared] + rng.integers(0, 50, tail_len).tolist()
        toks = toks[:PAGES_PER_SEQ * PAGE - 1]
        shared = self.c.lookup_prefix(toks)
        # claimed prefix tokens must match the stream by construction
        assert len(shared) * PAGE < len(toks)
        if not self.c.can_admit(len(toks), shared):
            return
        # eager alloc would overwrite shared pages: claimed prefixes
        # force the lazy (chunked) path, like the scheduler
        lazy = bool(shared) or bool(rng.integers(0, 2))
        slot = self.c.alloc_slot(len(toks), shared, lazy=lazy)
        self.streams[slot] = toks
        want = len(shared) * PAGE if lazy else len(toks)
        assert int(self.c.seq_lens[slot]) == want

    def prefill_chunk(self, rng):
        slots = [s for s in self.streams
                 if int(self.c.seq_lens[s]) < len(self.streams[s])]
        if not slots:
            return
        slot = slots[int(rng.integers(len(slots)))]
        done = int(self.c.seq_lens[slot])
        remaining = len(self.streams[slot]) - done
        n = int(rng.integers(1, remaining + 1))
        if not self.c.ensure_capacity(slot, done + n):
            # mirror the scheduler: only WRITABLE capacity may be used
            # (a shared page whose COW failed must not be written)
            n = self.c.writable_token_capacity(slot) - done
            if n <= 0:
                return                      # paused in place
        self.c.mark_prefilled(slot, done + n)
        self.c.register_pages(slot, self.streams[slot])

    def append(self, rng):
        if not self.streams:
            return
        slots = list(self.streams)
        slot = slots[int(rng.integers(len(slots)))]
        if int(self.c.seq_lens[slot]) < len(self.streams[slot]):
            return                          # mid-prefill: no decode yet
        if not self.c.ensure_append_capacity(slot):
            return
        self.c.advance(slot)
        self.streams[slot].append(int(rng.integers(0, 50)))
        if int(self.c.seq_lens[slot]) % PAGE == 0:
            self.c.register_pages(slot, self.streams[slot])

    def fork(self, rng):
        if not self.streams or not self.c.free_slot_count:
            return
        slots = list(self.streams)
        slot = slots[int(rng.integers(len(slots)))]
        new = self.c.fork(slot)
        self.streams[new] = list(self.streams[slot])

    def free(self, rng):
        if not self.streams:
            return
        slots = list(self.streams)
        slot = slots[int(rng.integers(len(slots)))]
        del self.streams[slot]
        self.c.free_slot(slot)


@settings(max_examples=60, deadline=None)
@given(ops=op_strategy)
def test_paged_cache_random_share_trace(ops):
    d = _Driver()
    dispatch = [d.admit, d.prefill_chunk, d.append, d.append, d.fork,
                d.free]
    for code, seed in ops:
        dispatch[code](np.random.default_rng(seed))
        d.check()
    # teardown: everything frees cleanly and nothing leaks
    for slot in list(d.streams):
        d.c.free_slot(slot)
    d.c.check_invariants()
    assert d.c.available_page_count == NUM_PAGES
    assert d.c.free_slot_count == MAX_BATCH


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10 ** 6))
def test_refcount_conservation_under_fork_churn(seed):
    """Heavy fork/free/COW churn: sum of refcounts always equals the
    total number of slot page-table references (checked inside
    check_invariants), and COW never splits a page both slots still
    share for reading."""
    rng = np.random.default_rng(seed)
    c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ)
    slots = [c.alloc_slot(int(rng.integers(1, 10)))]
    for _ in range(60):
        op = rng.random()
        if op < 0.35 and c.free_slot_count and slots:
            slots.append(c.fork(slots[int(rng.integers(len(slots)))]))
        elif op < 0.7 and slots:
            s = slots[int(rng.integers(len(slots)))]
            if c.ensure_append_capacity(s):
                c.advance(s)
        elif slots:
            s = slots.pop(int(rng.integers(len(slots))))
            c.free_slot(s)
        c.check_invariants()
        total_refs = sum(len(ps) for ps in c._slot_pages.values())
        assert int(c._refcount.sum()) == total_refs
    for s in slots:
        c.free_slot(s)
    c.check_invariants()
    assert c.available_page_count == NUM_PAGES
