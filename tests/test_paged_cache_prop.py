"""Property-based tests for PagedKVCache sharing semantics.

Drives the block pool through random admit / chunked-prefill / append /
fork / free traces - including prefix claiming, copy-on-write,
speculative commit/rollback, and forks taken *inside* the verify
commit/rollback window - and asserts after every op that
``check_invariants`` holds (which includes refcount conservation:
stored per-page refcounts must equal the number of page-table
references across slots) and that pages never leak: free + cached +
owned always partitions the pool.

The traces run through hypothesis when it is installed and through a
fixed battery of numpy-seeded manual traces otherwise (the CI container
ships hypothesis; the dev container may not) - the driver is identical,
so the invariants are exercised either way.

Pure host logic, no jax.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # manual traces only
    HAVE_HYPOTHESIS = False

from repro.serving import PagedKVCache

PAGE = 4
NUM_PAGES = 24
MAX_BATCH = 5
PAGES_PER_SEQ = 6

# A small base sequence: prompts are prefixes of it plus a random tail,
# which makes hash-chain prefix hits (and thus page sharing) common.
BASE = list(range(100, 100 + PAGES_PER_SEQ * PAGE))

N_OPS = 8          # dispatch table size below

if HAVE_HYPOTHESIS:
    op_strategy = st.lists(
        st.tuples(st.integers(0, N_OPS - 1), st.integers(0, 10 ** 6)),
        min_size=1, max_size=80)


def manual_traces(n_traces, max_len, n_ops, seed=0):
    """Numpy stand-in for the hypothesis op_strategy: n_traces random
    (op, seed) lists."""
    rng = np.random.default_rng(seed)
    for _ in range(n_traces):
        length = int(rng.integers(1, max_len + 1))
        yield [(int(rng.integers(0, n_ops)), int(rng.integers(0, 10 ** 6)))
               for _ in range(length)]


class _Driver:
    """Mirrors the engine's use of the cache; tracks the token stream
    backing every slot so register_pages stays content-consistent."""

    def __init__(self):
        self.c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ)
        self.streams: dict[int, list[int]] = {}     # slot -> token stream

    def check(self):
        self.c.check_invariants()
        # drained copies must reference distinct, in-range pages
        for src, dst in self.c.take_pending_copies():
            assert 0 <= src < NUM_PAGES and 0 <= dst < NUM_PAGES
            assert src != dst
        assert self.c.free_page_count + len(self.c._cached) + \
            len({p for ps in self.c._slot_pages.values() for p in ps}) \
            == NUM_PAGES

    # ------------------------------------------------------------- ops
    def admit(self, rng):
        n_shared = int(rng.integers(0, len(BASE)))
        tail_len = int(rng.integers(1, 6))
        toks = BASE[:n_shared] + rng.integers(0, 50, tail_len).tolist()
        toks = toks[:PAGES_PER_SEQ * PAGE - 1]
        shared = self.c.lookup_prefix(toks)
        # claimed prefix tokens must match the stream by construction
        assert len(shared) * PAGE < len(toks)
        if not self.c.can_admit(len(toks), shared):
            return
        # eager alloc would overwrite shared pages: claimed prefixes
        # force the lazy (chunked) path, like the scheduler
        lazy = bool(shared) or bool(rng.integers(0, 2))
        slot = self.c.alloc_slot(len(toks), shared, lazy=lazy)
        self.streams[slot] = toks
        want = len(shared) * PAGE if lazy else len(toks)
        assert int(self.c.seq_lens[slot]) == want

    def prefill_chunk(self, rng):
        slots = [s for s in self.streams
                 if int(self.c.seq_lens[s]) < len(self.streams[s])]
        if not slots:
            return
        slot = slots[int(rng.integers(len(slots)))]
        done = int(self.c.seq_lens[slot])
        remaining = len(self.streams[slot]) - done
        n = int(rng.integers(1, remaining + 1))
        if not self.c.ensure_capacity(slot, done + n):
            # mirror the scheduler: only WRITABLE capacity may be used
            # (a shared page whose COW failed must not be written)
            n = self.c.writable_token_capacity(slot) - done
            if n <= 0:
                return                      # paused in place
        self.c.mark_prefilled(slot, done + n)
        self.c.register_pages(slot, self.streams[slot])

    def append(self, rng):
        if not self.streams:
            return
        slots = list(self.streams)
        slot = slots[int(rng.integers(len(slots)))]
        if int(self.c.seq_lens[slot]) < len(self.streams[slot]):
            return                          # mid-prefill: no decode yet
        if not self.c.ensure_append_capacity(slot):
            return
        self.c.advance(slot)
        self.streams[slot].append(int(rng.integers(0, 50)))
        if int(self.c.seq_lens[slot]) % PAGE == 0:
            self.c.register_pages(slot, self.streams[slot])

    def fork(self, rng):
        if not self.c.free_slot_count:
            return
        # seq_lens == 0 is the free-slot sentinel: a lazily-admitted
        # slot with nothing materialized yet cannot be forked.
        slots = [s for s in self.streams if int(self.c.seq_lens[s]) >= 1]
        if not slots:
            return
        slot = slots[int(rng.integers(len(slots)))]
        new = self.c.fork(slot)
        self.streams[new] = \
            list(self.streams[slot][:int(self.c.seq_lens[slot])])

    def free(self, rng):
        if not self.streams:
            return
        slots = list(self.streams)
        slot = slots[int(rng.integers(len(slots)))]
        del self.streams[slot]
        self.c.free_slot(slot)

    def spec_verify(self, rng, mid_fork=False):
        """The engine's verify-step shape: commit KV for c speculative
        columns past the materialized stream, accept a random prefix,
        roll the rest back - optionally taking a fork *inside* the
        commit/rollback window, truncated at its own accepted length
        (contract point 5 in repro.serving.paged_cache)."""
        slots = [s for s in self.streams
                 if int(self.c.seq_lens[s]) == len(self.streams[s])]
        if not slots:
            return
        slot = slots[int(rng.integers(len(slots)))]
        sl = int(self.c.seq_lens[slot])
        c = int(rng.integers(1, 5))
        if not self.c.ensure_capacity(slot, sl + c):
            c = max(1, min(c, self.c.writable_token_capacity(slot) - sl))
            if sl + c > self.c.writable_token_capacity(slot) or c < 1:
                return
        drafts = rng.integers(0, 50, c).tolist()
        self.c.mark_prefilled(slot, sl + c)      # commit before acceptance
        fork_slot = None
        if mid_fork and self.c.free_slot_count:
            # Fork inside the window: the fork's accepted length is
            # chosen independently of the parent's (a parallel branch
            # fanning out of the step's accepted prefix).
            a_fork = sl + int(rng.integers(1, c + 1))
            fork_slot = self.c.fork(slot, a_fork)
            self.streams[fork_slot] = \
                self.streams[slot][:sl] + drafts[:a_fork - sl]
            assert int(self.c.seq_lens[fork_slot]) == a_fork
            # truncated fork: shares exactly the pre-rollback pages
            # covering its accepted prefix, nothing past them
            assert self.c.slot_pages(fork_slot) == \
                self.c.slot_pages(slot)[:self.c.pages_for(a_fork)]
            self.c.check_invariants()            # refcount conservation
        a = int(rng.integers(1, c + 1))          # parent's accepted prefix
        self.streams[slot] = self.streams[slot] + drafts[:a]
        if a < c:
            self.c.rollback(slot, sl + a)
        if fork_slot is not None:
            # the rollback dropped only the parent's references: every
            # page the fork reads is still owned
            for p in self.c.slot_pages(fork_slot):
                assert self.c.refcount(p) >= 1
            self.c.register_pages(fork_slot, self.streams[fork_slot])
        self.c.register_pages(slot, self.streams[slot])

    def spec_verify_mid_fork(self, rng):
        self.spec_verify(rng, mid_fork=True)


def _dispatch(d):
    return [d.admit, d.prefill_chunk, d.append, d.append, d.fork,
            d.free, d.spec_verify, d.spec_verify_mid_fork]


def _run_share_trace(ops):
    d = _Driver()
    dispatch = _dispatch(d)
    assert len(dispatch) == N_OPS
    for code, seed in ops:
        dispatch[code](np.random.default_rng(seed))
        d.check()
    # teardown: everything frees cleanly and nothing leaks
    for slot in list(d.streams):
        d.c.free_slot(slot)
    d.c.check_invariants()
    assert d.c.available_page_count == NUM_PAGES
    assert d.c.free_slot_count == MAX_BATCH


if HAVE_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(ops=op_strategy)
    def test_paged_cache_random_share_trace(ops):
        _run_share_trace(ops)


def test_paged_cache_share_trace_manual():
    """No-hypothesis fallback: the same driver over 150 numpy traces."""
    for ops in manual_traces(150, 80, N_OPS, seed=1):
        _run_share_trace(ops)


def _run_fork_churn(seed):
    """Heavy fork/free/COW churn: sum of refcounts always equals the
    total number of slot page-table references (checked inside
    check_invariants), and COW never splits a page both slots still
    share for reading."""
    rng = np.random.default_rng(seed)
    c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ)
    slots = [c.alloc_slot(int(rng.integers(1, 10)))]
    for _ in range(60):
        op = rng.random()
        if op < 0.35 and c.free_slot_count and slots:
            src = slots[int(rng.integers(len(slots)))]
            if rng.random() < 0.5:
                slots.append(c.fork(src))
            else:                       # truncated fork (verify window)
                n = int(rng.integers(1, int(c.seq_lens[src]) + 1))
                nslot = c.fork(src, n)
                assert int(c.seq_lens[nslot]) == n
                slots.append(nslot)
        elif op < 0.7 and slots:
            s = slots[int(rng.integers(len(slots)))]
            if c.ensure_append_capacity(s):
                c.advance(s)
        elif slots:
            s = slots.pop(int(rng.integers(len(slots))))
            c.free_slot(s)
        c.check_invariants()
        total_refs = sum(len(ps) for ps in c._slot_pages.values())
        assert int(c._refcount.sum()) == total_refs
    for s in slots:
        c.free_slot(s)
    c.check_invariants()
    assert c.available_page_count == NUM_PAGES


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(seed=st.integers(0, 10 ** 6))
    def test_refcount_conservation_under_fork_churn(seed):
        _run_fork_churn(seed)


def test_refcount_conservation_under_fork_churn_manual():
    for seed in range(40):
        _run_fork_churn(seed)


# ------------------------------- fork x rollback window regressions
def test_fork_in_verify_window_sees_pre_rollback_pages():
    """ROADMAP sharp edge, pinned: a fork taken between the verify
    step's ``mark_prefilled(sl + c)`` and ``rollback(sl + used)`` must
    (a) share exactly the pre-rollback pages covering its truncated
    length, (b) survive the parent's rollback with refcounts conserved,
    and (c) never inherit references on pages the rollback frees."""
    c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ)
    stream = BASE[:6]                        # 1 full page + partial tail
    slot = c.alloc_slot(len(stream))
    c.register_pages(slot, stream)
    assert c.ensure_capacity(slot, 6 + 4)    # commit 4 draft columns
    c.mark_prefilled(slot, 10)               # seq_lens over-counts: 10
    pre_pages = c.slot_pages(slot)           # 3 pages (pos 8,9 on page 2)
    assert len(pre_pages) == 3
    fork = c.fork(slot, 7)                   # accepted length: sl + 1
    assert int(c.seq_lens[fork]) == 7
    assert c.slot_pages(fork) == pre_pages[:2]
    assert c.refcount(pre_pages[2]) == 1, "fork must not ref junk pages"
    c.check_invariants()                     # refcount conservation
    c.rollback(slot, 7)                      # reject 3 columns
    c.check_invariants()
    # the page the rollback dropped is free again; shared pages survive
    assert c.refcount(pre_pages[2]) == 0
    assert c.refcount(pre_pages[0]) == 2 and c.refcount(pre_pages[1]) == 2
    c.free_slot(slot)
    c.free_slot(fork)
    c.check_invariants()
    assert c.available_page_count == NUM_PAGES


@pytest.mark.parametrize("via", ["rollback", "fork"])
def test_rolled_over_page_is_rehashed_on_register(via):
    """A rollback (or truncated fork) across a page boundary must
    re-trim the hash chain: the rolled-over page's content is later
    overwritten, and register_pages must re-hash it - the NEW prefix
    becomes claimable and the stale one does not."""
    c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ)
    old = [1, 2, 3, 4, 5, 6, 7, 8]           # 2 full pages
    slot = c.alloc_slot(len(old))
    c.register_pages(slot, old)
    assert len(c._slot_chain[slot]) == 2
    if via == "rollback":
        c.rollback(slot, 5)                  # back across page 1's start
        probe = slot
        probe_stream = old[:5]
    else:
        probe = c.fork(slot, 5)              # truncated fork, same point
        probe_stream = old[:5]
        c.free_slot(slot)                    # parent gone; fork owns page
    assert len(c._slot_chain[probe]) == 1, "chain not re-trimmed"
    # overwrite positions 5..7 with different tokens and publish
    new = probe_stream + [90, 91, 92]
    assert c.ensure_capacity(probe, 8)
    c.mark_prefilled(probe, 8)
    assert c.register_pages(probe, new) >= 1, \
        "rolled-over page was never re-hashed"
    c.check_invariants()
    # the NEW prefix is claimable, the stale (pre-rollback) one is not
    assert len(c.lookup_prefix(new + [0])) == 2
    assert len(c.lookup_prefix(old + [0])) == 1
    c.free_slot(probe)
    c.check_invariants()


def test_truncated_fork_rejects_bad_lengths():
    c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ)
    slot = c.alloc_slot(5)
    with pytest.raises(AssertionError):
        c.fork(slot, 0)
    with pytest.raises(AssertionError):
        c.fork(slot, 6)
    c.free_slot(slot)
    c.check_invariants()
