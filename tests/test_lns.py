"""Unit + property tests for the FIX16 LNS datapath (paper Sec. IV-V)."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import lns
from repro.core.numerics import FRAC_ONE, LOG_ZERO

finite_bf16 = st.floats(min_value=-3.0e38, max_value=3.0e38, allow_subnormal=False)


def test_blinn_roundtrip_exact():
    """float -> LNS -> float is EXACT for any finite bf16 (Blinn inverse)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(4096)
                    * 10.0 ** rng.integers(-20, 20, 4096), jnp.bfloat16)
    s, r = lns.blinn_log2(x)
    back = lns.lns_to_bf16(s, r)
    assert bool(jnp.all(back == x))


def test_blinn_zero_maps_to_log_zero():
    s, r = lns.blinn_log2(jnp.bfloat16(0.0))
    assert float(r) <= LOG_ZERO
    assert float(lns.lns_to_bf16(s, r)) == 0.0


@given(st.floats(min_value=1e-30, max_value=1e30))
@settings(max_examples=200, deadline=None)
def test_blinn_log2_mitchell_bound(x):
    """|blinn(x) - log2(x)| <= 0.0861 + quantization (Mitchell's bound)."""
    xb = jnp.bfloat16(x)
    if float(xb) == 0.0 or not np.isfinite(float(xb)):
        return
    _, r = lns.blinn_log2(xb)
    true = np.log2(abs(float(xb)))
    assert abs(float(r) / FRAC_ONE - true) <= 0.0861 + 1.0 / FRAC_ONE


def test_pwl_exp2_max_error():
    """8-segment PWL of 2^-f within 6e-3 of exact on the 7-bit grid."""
    f = jnp.arange(FRAC_ONE, dtype=jnp.float32)
    g = np.asarray(lns.pwl_exp2_frac(f)) / FRAC_ONE
    true = 2.0 ** (-(np.arange(FRAC_ONE) / FRAC_ONE))
    assert np.abs(g - true).max() < 6e-3


def test_pwl_monotone_nonincreasing():
    f = jnp.arange(FRAC_ONE, dtype=jnp.float32)
    g = np.asarray(lns.pwl_exp2_frac(f))
    assert np.all(np.diff(g) <= 0)


@given(st.floats(min_value=0.0, max_value=250.0))
@settings(max_examples=200, deadline=None)
def test_exp2_neg_close(d):
    raw = jnp.float32(round(d * FRAC_ONE))
    got = float(lns.exp2_neg(raw)) / FRAC_ONE
    true = 2.0 ** (-round(d * FRAC_ONE) / FRAC_ONE)
    # 7-bit output rail + PWL error
    assert abs(got - true) <= 2.0 / FRAC_ONE + 6e-3


@given(finite_bf16, finite_bf16)
@settings(max_examples=300, deadline=None)
def test_lns_add_same_sign_relative_error(a, b):
    """Same-sign LNS add within the Mitchell factor 2^0.0861 ~ 6.2% + rail."""
    a, b = abs(a), abs(b)
    ab, bb = jnp.bfloat16(a), jnp.bfloat16(b)
    if not (np.isfinite(float(ab)) and np.isfinite(float(bb))):
        return
    if float(ab) == 0 or float(bb) == 0:
        return
    sa, ra = lns.blinn_log2(ab)
    sb, rb = lns.blinn_log2(bb)
    sc, rc = lns.lns_add(sa, ra, sb, rb)
    got = float(lns.lns_value_hw(sc, rc))
    true = float(ab) + float(bb)
    if (not np.isfinite(true) or not np.isfinite(got) or true == 0
            or true > 1e37 or abs(rc) >= 32767):
        return  # f32 overflow territory / rail saturation
    assert got >= 0 and int(sc) == 0
    # Blinn conversion error composes with the Mitchell add correction:
    # two stacked ~6% approximations bound the result by ~12%.
    assert abs(got - true) / true < 0.12


@given(finite_bf16)
@settings(max_examples=100, deadline=None)
def test_lns_add_zero_identity(a):
    ab = jnp.bfloat16(a)
    if not np.isfinite(float(ab)):
        return
    sa, ra = lns.blinn_log2(ab)
    sz, rz = lns.blinn_log2(jnp.bfloat16(0.0))
    sc, rc = lns.lns_add(sa, ra, sz, rz)
    assert float(lns.lns_value_hw(sc, rc)) == pytest.approx(
        float(lns.lns_value_hw(sa, ra)), rel=1e-6)


def test_lns_add_exact_cancellation():
    s1, r1 = lns.blinn_log2(jnp.bfloat16(1.5))
    s2, r2 = lns.blinn_log2(jnp.bfloat16(-1.5))
    sc, rc = lns.lns_add(s1, r1, s2, r2)
    assert float(rc) <= LOG_ZERO


def test_quant_scorediff_clamps_and_rounds():
    import math
    d = jnp.float32(-20.0)  # below the -15 clamp
    raw = float(lns.quant_scorediff(d))
    assert raw == round(-15.0 * math.log2(math.e) * FRAC_ONE)
    assert float(lns.quant_scorediff(jnp.float32(-jnp.inf))) == raw
    assert float(lns.quant_scorediff(jnp.float32(0.0))) == 0.0


def test_sign_selection_follows_larger_operand():
    sa, ra = lns.blinn_log2(jnp.bfloat16(-8.0))
    sb, rb = lns.blinn_log2(jnp.bfloat16(1.0))
    sc, rc = lns.lns_add(sa, ra, sb, rb)
    assert int(sc) == 1  # negative dominates
    sc, rc = lns.lns_add(sb, rb, sa, ra)
    assert int(sc) == 1


def test_exact_config_is_near_float():
    rng = np.random.default_rng(1)
    a = rng.standard_normal(500).astype(np.float32)
    b = rng.standard_normal(500).astype(np.float32)
    sa, ra = lns.lns_from_bf16(jnp.asarray(a, jnp.bfloat16), lns.EXACT)
    sb, rb = lns.lns_from_bf16(jnp.asarray(b, jnp.bfloat16), lns.EXACT)
    sc, rc = lns.lns_add(sa, ra, sb, rb, lns.EXACT)
    got = np.asarray(lns.lns_value_f32(sc, rc))
    true = a.astype(np.float32) + b.astype(np.float32)
    mask = np.abs(true) > 1e-2
    rel = np.abs(got - true)[mask] / np.abs(true)[mask]
    assert np.median(rel) < 0.01
