"""Sharding rule resolution: divisibility fallback, axis dedupe, pod drop."""
import jax
from jax.sharding import PartitionSpec as P

from repro.parallel import sharding as sh


def _mesh():
    n = len(jax.devices())
    return jax.make_mesh((max(n // 1, 1),), ("data",))


def test_spec_basic():
    spec = sh.spec_for(("batch", None, "vocab"))
    assert spec == P(("pod", "data"), None, "model")


def test_missing_mesh_axes_dropped():
    mesh = jax.make_mesh((1,), ("data",))
    spec = sh.spec_for(("batch", None), (8, 4), None, mesh)
    assert spec == P("data")


def test_divisibility_fallback_replicates():
    mesh = jax.make_mesh((1,), ("data",))
    rules = dict(sh.DEFAULT_RULES, heads="data")
    # 20 heads % 1 == 0 -> kept; with a fake larger axis it must drop
    spec = sh.spec_for(("heads",), (20,), rules, mesh)
    assert spec == P("data")


def test_duplicate_axis_dropped():
    mesh = jax.make_mesh((1,), ("model",))
    rules = {"seq": "model", "vocab": "model"}
    spec = sh.spec_for(("seq", "vocab"), (8, 8), rules, mesh)
    assert spec == P("model")  # second use of "model" dropped


def test_tree_specs_maps_leaves():
    logical = {"a": ("vocab", "embed"), "b": {"c": ("mlp",)}}
    specs = sh.tree_specs(logical)
    assert specs["a"] == P("model")
    assert specs["b"]["c"] == P("model")


def test_constrain_noop_without_context():
    sh.set_context(None, None)
    import jax.numpy as jnp
    x = jnp.zeros((4, 4))
    y = sh.constrain(x, ("batch", None))
    assert y.shape == x.shape
