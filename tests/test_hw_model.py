"""28nm hardware cost model: paper Figs. 6-8 / Table IV reproduction."""
import numpy as np

from repro.analysis import hw_model as H


def test_area_savings_in_paper_band():
    """Paper: 22.5%-27% savings across head dims, 26.5% average area."""
    rows = H.savings_table()
    savings = [r["area_saving_%"] for r in rows]
    assert all(20.0 < s < 40.0 for s in savings), savings
    assert 24.0 < np.mean(savings) < 33.0


def test_power_savings_in_paper_band():
    rows = H.savings_table()
    savings = [r["power_saving_%"] for r in rows]
    assert all(18.0 < s < 35.0 for s in savings), savings
    assert 20.0 < np.mean(savings) < 30.0


def test_savings_hold_across_head_dims():
    """Fig. 7: consistently above ~22% for d in {32, 64, 128}."""
    for r in H.savings_table():
        assert r["area_saving_%"] > 22.0
        assert r["power_saving_%"] > 18.0


def test_sram_identical_between_designs():
    fa = H.accelerator("fa2", 64)
    hf = H.accelerator("hfa", 64)
    assert fa["sram_mm2"] == hf["sram_mm2"]


def test_exec_time_model_matches_fig8():
    """~6x speedup at 8 blocks for N=1024 (paper: 'a factor of 6')."""
    rows = H.exec_time_model()
    by_blocks = {r["blocks"]: r for r in rows}
    assert 5.0 < by_blocks[8]["speedup"] < 7.0
    assert by_blocks[2]["speedup"] > 1.8
    # area grows sub-linearly at first (shared SRAM), monotonically
    areas = [r["area_mm2"] for r in rows]
    assert all(a2 > a1 for a1, a2 in zip(areas, areas[1:]))


def test_table4_throughput_matches_paper():
    """H-FA-1-4: 0.256 BF16 TFLOPS (exact from op counts), ~0.91 FIX16 TOPS."""
    rows = {r["config"]: r for r in H.throughput_table()}
    r14 = rows["H-FA-1-4"]
    assert abs(r14["bf16_tflops"] - 0.262) < 0.02   # 2d+3 ops x 4 x 500MHz
    assert abs(r14["fix16_tops"] - 0.91) < 0.05
    r44 = rows["H-FA-4-4"]
    assert r44["bf16_tflops"] > 3.9 * r14["bf16_tflops"]
    # paper area: 1.14 mm^2 (1-4) / 3.34 (4-4) - model within ~2x
    assert 0.5 < r14["area_mm2"] < 2.3
