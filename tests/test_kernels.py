"""Per-kernel Pallas validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode on CPU), as required by the task spec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reference as cref
from repro.kernels import decode, fa2, hfa, hfa_datapath, ops, ref


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


SHAPES = [
    # (bh, lq, lkv, d, block_q, block_kv)
    (1, 128, 128, 64, 128, 128),
    (2, 128, 256, 64, 128, 128),
    (2, 256, 256, 128, 128, 128),
    (3, 128, 384, 32, 128, 128),
    (1, 256, 512, 64, 128, 256),
]
DTYPES = [jnp.bfloat16, jnp.float32]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("causal", [False, True])
def test_fa2_kernel_vs_oracle(shape, dtype, causal):
    bh, lq, lkv, d, bq, bk = shape
    q = _rand((bh, lq, d), dtype, 1)
    k = _rand((bh, lkv, d), dtype, 2)
    v = _rand((bh, lkv, d), dtype, 3)
    out = np.asarray(fa2.fa2_pallas(q, k, v, causal=causal,
                                    block_q=bq, block_kv=bk))
    gold = np.asarray(ref.ref_fa2(q, k, v, causal=causal))
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out, gold, atol=tol)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("causal", [False, True])
def test_hfa_kernel_matches_tile_oracle(shape, causal):
    """hfa.py must match its op-order-identical jnp oracle ~bit-exactly."""
    bh, lq, lkv, d, bq, bk = shape
    q = _rand((bh, lq, d), jnp.bfloat16, 4)
    k = _rand((bh, lkv, d), jnp.bfloat16, 5)
    v = _rand((bh, lkv, d), jnp.bfloat16, 6)
    out = np.asarray(hfa.hfa_pallas(q, k, v, causal=causal,
                                    block_q=bq, block_kv=bk))
    gold = np.asarray(ref.ref_hfa_mxu(q, k, v, causal=causal, block_kv=bk))
    np.testing.assert_allclose(out, gold, atol=1e-6)


def test_hfa_kernel_accuracy_vs_exact():
    q = _rand((2, 128, 64), jnp.bfloat16, 7)
    k = _rand((2, 256, 64), jnp.bfloat16, 8)
    v = _rand((2, 256, 64), jnp.bfloat16, 9)
    out = np.asarray(hfa.hfa_pallas(q, k, v, causal=True))
    gold = np.asarray(ref.ref_fa2(q, k, v, causal=True))
    assert np.isfinite(out).all()
    assert np.abs(out - gold).mean() < 0.02  # quantized-exp regime


def test_hfa_datapath_kernel_bit_exact_vs_emulation():
    """The per-element LNS kernel == core.hfa emulation EXACTLY."""
    q = _rand((2, 8, 32), jnp.bfloat16, 10)
    k = _rand((2, 32, 32), jnp.bfloat16, 11)
    v = _rand((2, 32, 32), jnp.bfloat16, 12)
    for causal in (False, True):
        out = np.asarray(hfa_datapath.hfa_datapath_pallas(
            q, k, v, causal=causal).astype(jnp.float32))
        gold = np.asarray(ref.ref_hfa_datapath(q, k, v, causal=causal)
                          .astype(jnp.float32))
        assert np.array_equal(out, gold)


@pytest.mark.parametrize("use_hfa", [False, True])
@pytest.mark.parametrize("g,s,d", [(4, 256, 64), (8, 384, 128), (2, 512, 32)])
def test_decode_partial_vs_oracle(use_hfa, g, s, d):
    q = _rand((3, g, d), jnp.bfloat16, 13)
    k = _rand((3, s, d), jnp.bfloat16, 14)
    v = _rand((3, s, d), jnp.bfloat16, 15)
    o, m, l = decode.decode_partial_pallas(q, k, v, use_hfa=use_hfa)
    og, mg, lg = ref.ref_decode_partial(q, k, v, use_hfa=use_hfa)
    np.testing.assert_allclose(np.asarray(o), np.asarray(og), atol=2e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mg), atol=0)
    np.testing.assert_allclose(np.asarray(l), np.asarray(lg), atol=2e-5)


@pytest.mark.parametrize("parts", [2, 4])
@pytest.mark.parametrize("use_hfa", [False, True])
def test_decode_split_merge_equals_full(parts, use_hfa):
    """Paper Fig. 2 at decode: split KV + ACC merge == single span."""
    g, s, d = 4, 512, 64
    q = _rand((2, g, d), jnp.bfloat16, 16)
    k = _rand((2, s, d), jnp.bfloat16, 17)
    v = _rand((2, s, d), jnp.bfloat16, 18)
    span = s // parts
    triplets = [decode.decode_partial_pallas(
        q, k[:, i * span:(i + 1) * span], v[:, i * span:(i + 1) * span],
        use_hfa=use_hfa) for i in range(parts)]
    om, mm, lm = decode.merge_partials(
        jnp.stack([t[0] for t in triplets]),
        jnp.stack([t[1] for t in triplets]),
        jnp.stack([t[2] for t in triplets]), use_hfa=use_hfa)
    merged = np.asarray(decode.finalize_decode(om, lm, use_hfa=use_hfa))
    gold = np.asarray(cref.exact_attention(q, k, v))
    tol = 5e-2 if use_hfa else 1e-5
    np.testing.assert_allclose(merged, gold, atol=tol)


def test_decode_kv_len_masking():
    g, s, d = 4, 256, 64
    q = _rand((2, g, d), jnp.bfloat16, 19)
    k = _rand((2, s, d), jnp.bfloat16, 20)
    v = _rand((2, s, d), jnp.bfloat16, 21)
    o, m, l = decode.decode_partial_pallas(q, k, v, kv_len=100)
    got = np.asarray(decode.finalize_decode(jnp.asarray(o), jnp.asarray(l)))
    gold = np.asarray(cref.exact_attention(q, k[:, :100], v[:, :100]))
    np.testing.assert_allclose(got, gold, atol=2e-5)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("shape", [(2, 128, 256, 64), (1, 128, 128, 32),
                                   (2, 256, 384, 128)])
def test_fa2_backward_kernel_vs_autodiff(causal, shape):
    """Pallas FA-2 backward (dq/dkv kernels) vs jax.grad of the oracle."""
    bh, lq, lkv, d = shape
    q = _rand((bh, lq, d), jnp.float32, 30)
    k = _rand((bh, lkv, d), jnp.float32, 31)
    v = _rand((bh, lkv, d), jnp.float32, 32)

    def loss_pallas(q, k, v):
        from repro.kernels.ops import _pallas_attention
        out = _pallas_attention(q, k, v, "fa2_pallas", causal, 128, 128,
                                lkv, lkv - lq)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    def loss_ref(q, k, v):
        out = cref.exact_attention(q, k, v, causal=causal)
        return jnp.sum(out ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gp, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, err_msg=f"d{name}")


def test_fa2_forward_lse_residual():
    q = _rand((2, 128, 64), jnp.bfloat16, 33)
    k = _rand((2, 256, 64), jnp.bfloat16, 34)
    v = _rand((2, 256, 64), jnp.bfloat16, 35)
    out, lse = fa2.fa2_pallas(q, k, v, causal=True, return_lse=True)
    s = np.einsum("bqd,bkd->bqk", np.asarray(q, np.float32),
                  np.asarray(k, np.float32)) / 8.0
    mask = np.tril(np.ones((128, 256), bool), k=128)
    s = np.where(mask, s, -1e30)
    want = np.log(np.exp(s - s.max(-1, keepdims=True)).sum(-1)) \
        + s.max(-1)
    np.testing.assert_allclose(np.asarray(lse), want, atol=1e-3)


@pytest.mark.parametrize("impl", ["fa2_pallas", "hfa_pallas"])
def test_ops_gqa_and_padding(impl):
    """Wrapper: GQA expansion + non-multiple seq lengths."""
    q = _rand((2, 100, 8, 64), jnp.bfloat16, 22)
    k = _rand((2, 100, 2, 64), jnp.bfloat16, 23)
    v = _rand((2, 100, 2, 64), jnp.bfloat16, 24)
    out = np.asarray(ops.multihead_attention(q, k, v, impl=impl)
                     .astype(jnp.float32))
    gold = np.asarray(ops.multihead_attention(q, k, v, impl="exact")
                      .astype(jnp.float32))
    tol = 0.35 if impl == "hfa_pallas" else 5e-3
    assert np.abs(out - gold).max() < tol


def test_ops_decode_wrapper_consistency():
    q = _rand((2, 1, 8, 64), jnp.bfloat16, 25)
    kc = _rand((2, 200, 2, 64), jnp.bfloat16, 26)
    vc = _rand((2, 200, 2, 64), jnp.bfloat16, 27)
    a = np.asarray(ops.decode_attention(q, kc, vc, impl="fa2_pallas",
                                        kv_len=150).astype(jnp.float32))
    b = np.asarray(ops.decode_attention(q, kc, vc, impl="fa2",
                                        kv_len=150).astype(jnp.float32))
    np.testing.assert_allclose(a, b, atol=5e-3)


# ----------------------------------------- paged_verify golden parity
def _verify_setup(seed, *, b=2, hkv=2, g=4, d=64, page=8, pages_each=3,
                  kw=1):
    """Random pools + shuffled page table + ragged seq_lens with room
    for a kw-token verify step, whose K/V is already written."""
    from repro.kernels import paged_prefill as paged_pf
    rng = np.random.default_rng(seed)
    num_pages = b * pages_each + 2
    kp = _rand((num_pages, page, hkv, d), jnp.float32, seed + 1)
    vp = _rand((num_pages, page, hkv, d), jnp.float32, seed + 2)
    pt = jnp.asarray(rng.permutation(num_pages)[:b * pages_each]
                     .reshape(b, pages_each).astype(np.int32))
    sl = jnp.asarray(rng.integers(1, pages_each * page - kw + 1, b)
                     .astype(np.int32))
    cl = jnp.full((b,), kw, jnp.int32)
    q = _rand((b, hkv, g, kw, d), jnp.float32, seed + 3)
    return q, kp, vp, pt, sl, cl


@pytest.mark.parametrize("d", [64, 128])
@pytest.mark.parametrize("g", [1, 4])
def test_paged_verify_k1_triplet_parity_matrix(d, g):
    """Golden-parity matrix over head_dim and GQA group size: with one
    verify column the paged_verify kernel, the paged_decode kernel, the
    dense decode kernel, and the jnp triplet oracle must emit the same
    (m, l, o~) triplets (fp32 tolerance) on ragged seq_lens."""
    from repro.kernels import paged_decode as paged
    from repro.kernels import paged_verify as paged_ver
    q, kp, vp, pt, sl, cl = _verify_setup(50 + d + g, d=d, g=g, kw=1)
    kvl = sl + 1
    ov, mv, lv = paged_ver.paged_verify_partial_pallas(
        q, kp, vp, pt, sl, cl, interpret=True)
    od, md, ld = paged.paged_decode_partial_pallas(
        q[:, :, :, 0, :], kp, vp, pt, kvl, interpret=True)
    np.testing.assert_allclose(np.asarray(mv[..., 0]), np.asarray(md),
                               atol=0)
    np.testing.assert_allclose(np.asarray(lv[..., 0]), np.asarray(ld),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ov[:, :, :, 0]), np.asarray(od),
                               atol=1e-4)
    # jnp triplet oracle (order-free softmax pieces)
    orf, mrf, lrf = paged_ver.paged_verify_partial_ref(q, kp, vp, pt, sl,
                                                       cl)
    np.testing.assert_allclose(np.asarray(mv), np.asarray(mrf), atol=0)
    np.testing.assert_allclose(np.asarray(lv), np.asarray(lrf), atol=1e-4)
    np.testing.assert_allclose(np.asarray(ov), np.asarray(orf), atol=1e-3)
    # dense decode kernel on the gathered contiguous view, row by row
    k_dense = paged.gather_pages(kp, pt)
    v_dense = paged.gather_pages(vp, pt)
    for i in range(q.shape[0]):
        o3, m3, l3 = decode.decode_partial_pallas(
            q[i, :, :, 0, :], jnp.swapaxes(k_dense[i], 0, 1),
            jnp.swapaxes(v_dense[i], 0, 1), block_kv=8,
            kv_len=int(kvl[i]))
        np.testing.assert_allclose(np.asarray(mv[i, :, :, 0]),
                                   np.asarray(m3), atol=0)
        np.testing.assert_allclose(np.asarray(lv[i, :, :, 0]),
                                   np.asarray(l3), atol=1e-5)
        np.testing.assert_allclose(np.asarray(ov[i, :, :, 0]),
                                   np.asarray(o3), atol=1e-4)


@pytest.mark.parametrize("use_hfa", [False, True])
def test_paged_verify_rows_match_paged_decode_positions(use_hfa):
    """Each verify column i scores position seq_lens + i: its triplet
    must equal a paged_decode call with kv_len = seq_lens + i + 1 -
    including through the FIX16 H-FA datapath (identical page walk,
    identical quantized numerics)."""
    from repro.kernels import paged_decode as paged
    from repro.kernels import paged_verify as paged_ver
    kw = 4
    q, kp, vp, pt, sl, cl = _verify_setup(77, kw=kw)
    ov, mv, lv = paged_ver.paged_verify_partial_pallas(
        q, kp, vp, pt, sl, cl, use_hfa=use_hfa, interpret=True)
    for i in range(kw):
        od, md, ld = paged.paged_decode_partial_pallas(
            q[:, :, :, i, :], kp, vp, pt, sl + i + 1, use_hfa=use_hfa,
            interpret=True)
        np.testing.assert_allclose(np.asarray(mv[..., i]), np.asarray(md),
                                   atol=0)
        np.testing.assert_allclose(np.asarray(lv[..., i]), np.asarray(ld),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(ov[:, :, :, i]),
                                   np.asarray(od), atol=1e-4)


def test_paged_verify_ragged_chunks_and_free_slot():
    """Ragged chunk_lens: a free slot (cl == 0) emits a zero triplet,
    short rows only attend KV below seq_lens + chunk_lens, and live
    rows are untouched by junk in other slots' pages."""
    from repro.kernels import paged_verify as paged_ver
    q, kp, vp, pt, sl, cl = _verify_setup(91, b=3, kw=4)
    sl = sl.at[1].set(0)
    cl = jnp.asarray(np.array([4, 0, 2], np.int32))
    ov, mv, lv = paged_ver.paged_verify_partial_pallas(
        q, kp, vp, pt, sl, cl, interpret=True)
    assert np.all(np.asarray(ov)[1] == 0.0)
    assert np.all(np.asarray(lv)[1] == 0.0)
    orf, mrf, lrf = paged_ver.paged_verify_partial_ref(q, kp, vp, pt, sl,
                                                       cl)
    # live columns agree with the oracle (garbage columns excluded)
    for b, k_real in ((0, 4), (2, 2)):
        np.testing.assert_allclose(np.asarray(ov)[b, :, :, :k_real],
                                   np.asarray(orf)[b, :, :, :k_real],
                                   atol=1e-3)
        np.testing.assert_allclose(np.asarray(lv)[b, :, :, :k_real],
                                   np.asarray(lrf)[b, :, :, :k_real],
                                   atol=1e-4)


def test_ops_paged_verify_jnp_matches_pallas_and_decode():
    """ops.paged_verify_attention: the jnp gather path (CPU serving) ==
    the Pallas kernel path, and K = 1 == ops.paged_decode_attention."""
    from repro.kernels import paged_verify as paged_ver  # noqa: F401
    q, kp, vp, pt, sl, cl = _verify_setup(93, kw=4)
    b, hkv, g, kw, d = q.shape
    q4 = jnp.swapaxes(q.reshape(b, hkv * g, kw, d), 1, 2)   # (B, K, H, d)
    for impl, tol in (("fa2_pallas", 1e-5), ("hfa_pallas", 2e-2)):
        a = np.asarray(ops.paged_verify_attention(
            q4, kp, vp, pt, sl, cl, impl=impl, force_pallas=True))
        jj = np.asarray(ops.paged_verify_attention(
            q4, kp, vp, pt, sl, cl, impl=impl))
        np.testing.assert_allclose(a, jj, atol=tol)
    one = np.asarray(ops.paged_verify_attention(
        q4[:, :1], kp, vp, pt, sl, jnp.ones_like(cl), impl="fa2"))
    dec = np.asarray(ops.paged_decode_attention(
        q4[:, :1], kp, vp, pt, sl + 1, impl="fa2"))
    np.testing.assert_allclose(one, dec, atol=1e-5)


# ------------------------------------ COW fork golden parity (groups)
def _cow_tables(seed, *, b=2, hkv=2, g=2, d=64, page=8, pages_each=3,
                kw=1):
    """Two page-table views of identical KV: ``shared`` aliases one
    physical page set across both slots (a COW fork before any
    divergence), ``mat`` backs slot 1 with a materialized byte-for-byte
    copy into fresh pages (what a non-COW engine would allocate)."""
    from repro.kernels import paged_prefill as paged_pf
    rng = np.random.default_rng(seed)
    num_pages = 2 * pages_each + 2               # room for the copies
    kp = _rand((num_pages, page, hkv, d), jnp.float32, seed + 1)
    vp = _rand((num_pages, page, hkv, d), jnp.float32, seed + 2)
    src = rng.permutation(pages_each).astype(np.int32)       # slot 0 pages
    dst = (pages_each + rng.permutation(pages_each)).astype(np.int32)
    kp = paged_pf.copy_pages(kp, jnp.asarray(src), jnp.asarray(dst))
    vp = paged_pf.copy_pages(vp, jnp.asarray(src), jnp.asarray(dst))
    shared = jnp.asarray(np.stack([src, src]))
    mat = jnp.asarray(np.stack([src, dst]))
    sl = jnp.asarray(
        rng.integers(1, pages_each * page - kw + 1, b).astype(np.int32))
    q = _rand((b, hkv, g, kw, d), jnp.float32, seed + 3)
    return q, kp, vp, shared, mat, sl


@pytest.mark.parametrize("use_hfa", [False, True])
def test_paged_decode_forked_table_bit_equal_materialized(use_hfa):
    """A decode step over a COW-shared page table (fork: two slots, one
    physical page set) must be BIT-equal to the same step over a
    materialized copy - page aliasing is invisible to the kernel, on
    the fp and FIX16 H-FA rails alike.  This is what makes sequence
    groups free: a fork costs refcounts, never numerics."""
    from repro.kernels import paged_decode as paged
    q, kp, vp, shared, mat, sl = _cow_tables(201)
    q1 = q[:, :, :, 0, :]
    o_s, m_s, l_s = paged.paged_decode_partial_pallas(
        q1, kp, vp, shared, sl, use_hfa=use_hfa, interpret=True)
    o_m, m_m, l_m = paged.paged_decode_partial_pallas(
        q1, kp, vp, mat, sl, use_hfa=use_hfa, interpret=True)
    np.testing.assert_array_equal(np.asarray(o_s), np.asarray(o_m))
    np.testing.assert_array_equal(np.asarray(m_s), np.asarray(m_m))
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_m))


@pytest.mark.parametrize("use_hfa", [False, True])
def test_paged_verify_forked_table_bit_equal_materialized(use_hfa):
    """Same contract for the K-token verify walk: a speculative step
    over a forked (COW-shared) table == the materialized copy, bit for
    bit, fa2 + hfa."""
    from repro.kernels import paged_verify as paged_ver
    kw = 3
    q, kp, vp, shared, mat, sl = _cow_tables(203, kw=kw)
    cl = jnp.full((2,), kw, jnp.int32)
    # KV for the verify columns is pre-written in the pools; aliasing
    # covers it identically by construction of _cow_tables.
    o_s, m_s, l_s = paged_ver.paged_verify_partial_pallas(
        q, kp, vp, shared, sl, cl, use_hfa=use_hfa, interpret=True)
    o_m, m_m, l_m = paged_ver.paged_verify_partial_pallas(
        q, kp, vp, mat, sl, cl, use_hfa=use_hfa, interpret=True)
    np.testing.assert_array_equal(np.asarray(o_s), np.asarray(o_m))
    np.testing.assert_array_equal(np.asarray(m_s), np.asarray(m_m))
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_m))


@pytest.mark.parametrize("impl", ["fa2", "hfa_pallas"])
def test_ops_paged_jnp_forked_table_bit_equal_materialized(impl):
    """The jnp gather paths (CPU serving) honor the same aliasing
    contract end to end through ops.paged_{decode,verify}_attention."""
    q, kp, vp, shared, mat, sl = _cow_tables(207, kw=2)
    b, hkv, g, kw, d = q.shape
    q4 = jnp.swapaxes(q.reshape(b, hkv * g, kw, d), 1, 2)
    cl = jnp.full((b,), kw, jnp.int32)
    v_s = np.asarray(ops.paged_verify_attention(q4, kp, vp, shared, sl, cl,
                                                impl=impl))
    v_m = np.asarray(ops.paged_verify_attention(q4, kp, vp, mat, sl, cl,
                                                impl=impl))
    np.testing.assert_array_equal(v_s, v_m)
    d_s = np.asarray(ops.paged_decode_attention(q4[:, :1], kp, vp, shared,
                                                sl, impl=impl))
    d_m = np.asarray(ops.paged_decode_attention(q4[:, :1], kp, vp, mat,
                                                sl, impl=impl))
    np.testing.assert_array_equal(d_s, d_m)
