"""Per-kernel Pallas validation: shape/dtype sweeps vs the ref.py oracles
(interpret mode on CPU), as required by the task spec."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import reference as cref
from repro.kernels import decode, fa2, hfa, hfa_datapath, ops, ref


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


SHAPES = [
    # (bh, lq, lkv, d, block_q, block_kv)
    (1, 128, 128, 64, 128, 128),
    (2, 128, 256, 64, 128, 128),
    (2, 256, 256, 128, 128, 128),
    (3, 128, 384, 32, 128, 128),
    (1, 256, 512, 64, 128, 256),
]
DTYPES = [jnp.bfloat16, jnp.float32]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("causal", [False, True])
def test_fa2_kernel_vs_oracle(shape, dtype, causal):
    bh, lq, lkv, d, bq, bk = shape
    q = _rand((bh, lq, d), dtype, 1)
    k = _rand((bh, lkv, d), dtype, 2)
    v = _rand((bh, lkv, d), dtype, 3)
    out = np.asarray(fa2.fa2_pallas(q, k, v, causal=causal,
                                    block_q=bq, block_kv=bk))
    gold = np.asarray(ref.ref_fa2(q, k, v, causal=causal))
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(out, gold, atol=tol)


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("causal", [False, True])
def test_hfa_kernel_matches_tile_oracle(shape, causal):
    """hfa.py must match its op-order-identical jnp oracle ~bit-exactly."""
    bh, lq, lkv, d, bq, bk = shape
    q = _rand((bh, lq, d), jnp.bfloat16, 4)
    k = _rand((bh, lkv, d), jnp.bfloat16, 5)
    v = _rand((bh, lkv, d), jnp.bfloat16, 6)
    out = np.asarray(hfa.hfa_pallas(q, k, v, causal=causal,
                                    block_q=bq, block_kv=bk))
    gold = np.asarray(ref.ref_hfa_mxu(q, k, v, causal=causal, block_kv=bk))
    np.testing.assert_allclose(out, gold, atol=1e-6)


def test_hfa_kernel_accuracy_vs_exact():
    q = _rand((2, 128, 64), jnp.bfloat16, 7)
    k = _rand((2, 256, 64), jnp.bfloat16, 8)
    v = _rand((2, 256, 64), jnp.bfloat16, 9)
    out = np.asarray(hfa.hfa_pallas(q, k, v, causal=True))
    gold = np.asarray(ref.ref_fa2(q, k, v, causal=True))
    assert np.isfinite(out).all()
    assert np.abs(out - gold).mean() < 0.02  # quantized-exp regime


def test_hfa_datapath_kernel_bit_exact_vs_emulation():
    """The per-element LNS kernel == core.hfa emulation EXACTLY."""
    q = _rand((2, 8, 32), jnp.bfloat16, 10)
    k = _rand((2, 32, 32), jnp.bfloat16, 11)
    v = _rand((2, 32, 32), jnp.bfloat16, 12)
    for causal in (False, True):
        out = np.asarray(hfa_datapath.hfa_datapath_pallas(
            q, k, v, causal=causal).astype(jnp.float32))
        gold = np.asarray(ref.ref_hfa_datapath(q, k, v, causal=causal)
                          .astype(jnp.float32))
        assert np.array_equal(out, gold)


@pytest.mark.parametrize("use_hfa", [False, True])
@pytest.mark.parametrize("g,s,d", [(4, 256, 64), (8, 384, 128), (2, 512, 32)])
def test_decode_partial_vs_oracle(use_hfa, g, s, d):
    q = _rand((3, g, d), jnp.bfloat16, 13)
    k = _rand((3, s, d), jnp.bfloat16, 14)
    v = _rand((3, s, d), jnp.bfloat16, 15)
    o, m, l = decode.decode_partial_pallas(q, k, v, use_hfa=use_hfa)
    og, mg, lg = ref.ref_decode_partial(q, k, v, use_hfa=use_hfa)
    np.testing.assert_allclose(np.asarray(o), np.asarray(og), atol=2e-5)
    np.testing.assert_allclose(np.asarray(m), np.asarray(mg), atol=0)
    np.testing.assert_allclose(np.asarray(l), np.asarray(lg), atol=2e-5)


@pytest.mark.parametrize("parts", [2, 4])
@pytest.mark.parametrize("use_hfa", [False, True])
def test_decode_split_merge_equals_full(parts, use_hfa):
    """Paper Fig. 2 at decode: split KV + ACC merge == single span."""
    g, s, d = 4, 512, 64
    q = _rand((2, g, d), jnp.bfloat16, 16)
    k = _rand((2, s, d), jnp.bfloat16, 17)
    v = _rand((2, s, d), jnp.bfloat16, 18)
    span = s // parts
    triplets = [decode.decode_partial_pallas(
        q, k[:, i * span:(i + 1) * span], v[:, i * span:(i + 1) * span],
        use_hfa=use_hfa) for i in range(parts)]
    om, mm, lm = decode.merge_partials(
        jnp.stack([t[0] for t in triplets]),
        jnp.stack([t[1] for t in triplets]),
        jnp.stack([t[2] for t in triplets]), use_hfa=use_hfa)
    merged = np.asarray(decode.finalize_decode(om, lm, use_hfa=use_hfa))
    gold = np.asarray(cref.exact_attention(q, k, v))
    tol = 5e-2 if use_hfa else 1e-5
    np.testing.assert_allclose(merged, gold, atol=tol)


def test_decode_kv_len_masking():
    g, s, d = 4, 256, 64
    q = _rand((2, g, d), jnp.bfloat16, 19)
    k = _rand((2, s, d), jnp.bfloat16, 20)
    v = _rand((2, s, d), jnp.bfloat16, 21)
    o, m, l = decode.decode_partial_pallas(q, k, v, kv_len=100)
    got = np.asarray(decode.finalize_decode(jnp.asarray(o), jnp.asarray(l)))
    gold = np.asarray(cref.exact_attention(q, k[:, :100], v[:, :100]))
    np.testing.assert_allclose(got, gold, atol=2e-5)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("shape", [(2, 128, 256, 64), (1, 128, 128, 32),
                                   (2, 256, 384, 128)])
def test_fa2_backward_kernel_vs_autodiff(causal, shape):
    """Pallas FA-2 backward (dq/dkv kernels) vs jax.grad of the oracle."""
    bh, lq, lkv, d = shape
    q = _rand((bh, lq, d), jnp.float32, 30)
    k = _rand((bh, lkv, d), jnp.float32, 31)
    v = _rand((bh, lkv, d), jnp.float32, 32)

    def loss_pallas(q, k, v):
        from repro.kernels.ops import _pallas_attention
        out = _pallas_attention(q, k, v, "fa2_pallas", causal, 128, 128,
                                lkv, lkv - lq)
        return jnp.sum(out.astype(jnp.float32) ** 2)

    def loss_ref(q, k, v):
        out = cref.exact_attention(q, k, v, causal=causal)
        return jnp.sum(out ** 2)

    gp = jax.grad(loss_pallas, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gp, gr, "qkv"):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-3, err_msg=f"d{name}")


def test_fa2_forward_lse_residual():
    q = _rand((2, 128, 64), jnp.bfloat16, 33)
    k = _rand((2, 256, 64), jnp.bfloat16, 34)
    v = _rand((2, 256, 64), jnp.bfloat16, 35)
    out, lse = fa2.fa2_pallas(q, k, v, causal=True, return_lse=True)
    s = np.einsum("bqd,bkd->bqk", np.asarray(q, np.float32),
                  np.asarray(k, np.float32)) / 8.0
    mask = np.tril(np.ones((128, 256), bool), k=128)
    s = np.where(mask, s, -1e30)
    want = np.log(np.exp(s - s.max(-1, keepdims=True)).sum(-1)) \
        + s.max(-1)
    np.testing.assert_allclose(np.asarray(lse), want, atol=1e-3)


@pytest.mark.parametrize("impl", ["fa2_pallas", "hfa_pallas"])
def test_ops_gqa_and_padding(impl):
    """Wrapper: GQA expansion + non-multiple seq lengths."""
    q = _rand((2, 100, 8, 64), jnp.bfloat16, 22)
    k = _rand((2, 100, 2, 64), jnp.bfloat16, 23)
    v = _rand((2, 100, 2, 64), jnp.bfloat16, 24)
    out = np.asarray(ops.multihead_attention(q, k, v, impl=impl)
                     .astype(jnp.float32))
    gold = np.asarray(ops.multihead_attention(q, k, v, impl="exact")
                      .astype(jnp.float32))
    tol = 0.35 if impl == "hfa_pallas" else 5e-3
    assert np.abs(out - gold).max() < tol


def test_ops_decode_wrapper_consistency():
    q = _rand((2, 1, 8, 64), jnp.bfloat16, 25)
    kc = _rand((2, 200, 2, 64), jnp.bfloat16, 26)
    vc = _rand((2, 200, 2, 64), jnp.bfloat16, 27)
    a = np.asarray(ops.decode_attention(q, kc, vc, impl="fa2_pallas",
                                        kv_len=150).astype(jnp.float32))
    b = np.asarray(ops.decode_attention(q, kc, vc, impl="fa2",
                                        kv_len=150).astype(jnp.float32))
    np.testing.assert_allclose(a, b, atol=5e-3)


# ----------------------------------------- paged_verify golden parity
def _verify_setup(seed, *, b=2, hkv=2, g=4, d=64, page=8, pages_each=3,
                  kw=1):
    """Random pools + shuffled page table + ragged seq_lens with room
    for a kw-token verify step, whose K/V is already written."""
    from repro.kernels import paged_prefill as paged_pf
    rng = np.random.default_rng(seed)
    num_pages = b * pages_each + 2
    kp = _rand((num_pages, page, hkv, d), jnp.float32, seed + 1)
    vp = _rand((num_pages, page, hkv, d), jnp.float32, seed + 2)
    pt = jnp.asarray(rng.permutation(num_pages)[:b * pages_each]
                     .reshape(b, pages_each).astype(np.int32))
    sl = jnp.asarray(rng.integers(1, pages_each * page - kw + 1, b)
                     .astype(np.int32))
    cl = jnp.full((b,), kw, jnp.int32)
    q = _rand((b, hkv, g, kw, d), jnp.float32, seed + 3)
    return q, kp, vp, pt, sl, cl


@pytest.mark.parametrize("d", [64, 128])
@pytest.mark.parametrize("g", [1, 4])
def test_paged_verify_k1_triplet_parity_matrix(d, g):
    """Golden-parity matrix over head_dim and GQA group size: with one
    verify column the paged_verify kernel, the paged_decode kernel, the
    dense decode kernel, and the jnp triplet oracle must emit the same
    (m, l, o~) triplets (fp32 tolerance) on ragged seq_lens."""
    from repro.kernels import paged_decode as paged
    from repro.kernels import paged_verify as paged_ver
    q, kp, vp, pt, sl, cl = _verify_setup(50 + d + g, d=d, g=g, kw=1)
    kvl = sl + 1
    ov, mv, lv = paged_ver.paged_verify_partial_pallas(
        q, kp, vp, pt, sl, cl, interpret=True)
    od, md, ld = paged.paged_decode_partial_pallas(
        q[:, :, :, 0, :], kp, vp, pt, kvl, interpret=True)
    np.testing.assert_allclose(np.asarray(mv[..., 0]), np.asarray(md),
                               atol=0)
    np.testing.assert_allclose(np.asarray(lv[..., 0]), np.asarray(ld),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(ov[:, :, :, 0]), np.asarray(od),
                               atol=1e-4)
    # jnp triplet oracle (order-free softmax pieces)
    orf, mrf, lrf = paged_ver.paged_verify_partial_ref(q, kp, vp, pt, sl,
                                                       cl)
    np.testing.assert_allclose(np.asarray(mv), np.asarray(mrf), atol=0)
    np.testing.assert_allclose(np.asarray(lv), np.asarray(lrf), atol=1e-4)
    np.testing.assert_allclose(np.asarray(ov), np.asarray(orf), atol=1e-3)
    # dense decode kernel on the gathered contiguous view, row by row
    k_dense = paged.gather_pages(kp, pt)
    v_dense = paged.gather_pages(vp, pt)
    for i in range(q.shape[0]):
        o3, m3, l3 = decode.decode_partial_pallas(
            q[i, :, :, 0, :], jnp.swapaxes(k_dense[i], 0, 1),
            jnp.swapaxes(v_dense[i], 0, 1), block_kv=8,
            kv_len=int(kvl[i]))
        np.testing.assert_allclose(np.asarray(mv[i, :, :, 0]),
                                   np.asarray(m3), atol=0)
        np.testing.assert_allclose(np.asarray(lv[i, :, :, 0]),
                                   np.asarray(l3), atol=1e-5)
        np.testing.assert_allclose(np.asarray(ov[i, :, :, 0]),
                                   np.asarray(o3), atol=1e-4)


@pytest.mark.parametrize("use_hfa", [False, True])
def test_paged_verify_rows_match_paged_decode_positions(use_hfa):
    """Each verify column i scores position seq_lens + i: its triplet
    must equal a paged_decode call with kv_len = seq_lens + i + 1 -
    including through the FIX16 H-FA datapath (identical page walk,
    identical quantized numerics)."""
    from repro.kernels import paged_decode as paged
    from repro.kernels import paged_verify as paged_ver
    kw = 4
    q, kp, vp, pt, sl, cl = _verify_setup(77, kw=kw)
    ov, mv, lv = paged_ver.paged_verify_partial_pallas(
        q, kp, vp, pt, sl, cl, use_hfa=use_hfa, interpret=True)
    for i in range(kw):
        od, md, ld = paged.paged_decode_partial_pallas(
            q[:, :, :, i, :], kp, vp, pt, sl + i + 1, use_hfa=use_hfa,
            interpret=True)
        np.testing.assert_allclose(np.asarray(mv[..., i]), np.asarray(md),
                                   atol=0)
        np.testing.assert_allclose(np.asarray(lv[..., i]), np.asarray(ld),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(ov[:, :, :, i]),
                                   np.asarray(od), atol=1e-4)


def test_paged_verify_ragged_chunks_and_free_slot():
    """Ragged chunk_lens: a free slot (cl == 0) emits a zero triplet,
    short rows only attend KV below seq_lens + chunk_lens, and live
    rows are untouched by junk in other slots' pages."""
    from repro.kernels import paged_verify as paged_ver
    q, kp, vp, pt, sl, cl = _verify_setup(91, b=3, kw=4)
    sl = sl.at[1].set(0)
    cl = jnp.asarray(np.array([4, 0, 2], np.int32))
    ov, mv, lv = paged_ver.paged_verify_partial_pallas(
        q, kp, vp, pt, sl, cl, interpret=True)
    assert np.all(np.asarray(ov)[1] == 0.0)
    assert np.all(np.asarray(lv)[1] == 0.0)
    orf, mrf, lrf = paged_ver.paged_verify_partial_ref(q, kp, vp, pt, sl,
                                                       cl)
    # live columns agree with the oracle (garbage columns excluded)
    for b, k_real in ((0, 4), (2, 2)):
        np.testing.assert_allclose(np.asarray(ov)[b, :, :, :k_real],
                                   np.asarray(orf)[b, :, :, :k_real],
                                   atol=1e-3)
        np.testing.assert_allclose(np.asarray(lv)[b, :, :, :k_real],
                                   np.asarray(lrf)[b, :, :, :k_real],
                                   atol=1e-4)


def test_ops_paged_verify_jnp_matches_pallas_and_decode():
    """ops.paged_verify_attention: the jnp gather path (CPU serving) ==
    the Pallas kernel path, and K = 1 == ops.paged_decode_attention."""
    from repro.kernels import paged_verify as paged_ver  # noqa: F401
    q, kp, vp, pt, sl, cl = _verify_setup(93, kw=4)
    b, hkv, g, kw, d = q.shape
    q4 = jnp.swapaxes(q.reshape(b, hkv * g, kw, d), 1, 2)   # (B, K, H, d)
    for impl, tol in (("fa2_pallas", 1e-5), ("hfa_pallas", 2e-2)):
        a = np.asarray(ops.paged_verify_attention(
            q4, kp, vp, pt, sl, cl, impl=impl, force_pallas=True))
        jj = np.asarray(ops.paged_verify_attention(
            q4, kp, vp, pt, sl, cl, impl=impl))
        np.testing.assert_allclose(a, jj, atol=tol)
    one = np.asarray(ops.paged_verify_attention(
        q4[:, :1], kp, vp, pt, sl, jnp.ones_like(cl), impl="fa2"))
    dec = np.asarray(ops.paged_decode_attention(
        q4[:, :1], kp, vp, pt, sl + 1, impl="fa2"))
    np.testing.assert_allclose(one, dec, atol=1e-5)


# ------------------------------------ COW fork golden parity (groups)
def _cow_tables(seed, *, b=2, hkv=2, g=2, d=64, page=8, pages_each=3,
                kw=1):
    """Two page-table views of identical KV: ``shared`` aliases one
    physical page set across both slots (a COW fork before any
    divergence), ``mat`` backs slot 1 with a materialized byte-for-byte
    copy into fresh pages (what a non-COW engine would allocate)."""
    from repro.kernels import paged_prefill as paged_pf
    rng = np.random.default_rng(seed)
    num_pages = 2 * pages_each + 2               # room for the copies
    kp = _rand((num_pages, page, hkv, d), jnp.float32, seed + 1)
    vp = _rand((num_pages, page, hkv, d), jnp.float32, seed + 2)
    src = rng.permutation(pages_each).astype(np.int32)       # slot 0 pages
    dst = (pages_each + rng.permutation(pages_each)).astype(np.int32)
    kp = paged_pf.copy_pages(kp, jnp.asarray(src), jnp.asarray(dst))
    vp = paged_pf.copy_pages(vp, jnp.asarray(src), jnp.asarray(dst))
    shared = jnp.asarray(np.stack([src, src]))
    mat = jnp.asarray(np.stack([src, dst]))
    sl = jnp.asarray(
        rng.integers(1, pages_each * page - kw + 1, b).astype(np.int32))
    q = _rand((b, hkv, g, kw, d), jnp.float32, seed + 3)
    return q, kp, vp, shared, mat, sl


@pytest.mark.parametrize("use_hfa", [False, True])
def test_paged_decode_forked_table_bit_equal_materialized(use_hfa):
    """A decode step over a COW-shared page table (fork: two slots, one
    physical page set) must be BIT-equal to the same step over a
    materialized copy - page aliasing is invisible to the kernel, on
    the fp and FIX16 H-FA rails alike.  This is what makes sequence
    groups free: a fork costs refcounts, never numerics."""
    from repro.kernels import paged_decode as paged
    q, kp, vp, shared, mat, sl = _cow_tables(201)
    q1 = q[:, :, :, 0, :]
    o_s, m_s, l_s = paged.paged_decode_partial_pallas(
        q1, kp, vp, shared, sl, use_hfa=use_hfa, interpret=True)
    o_m, m_m, l_m = paged.paged_decode_partial_pallas(
        q1, kp, vp, mat, sl, use_hfa=use_hfa, interpret=True)
    np.testing.assert_array_equal(np.asarray(o_s), np.asarray(o_m))
    np.testing.assert_array_equal(np.asarray(m_s), np.asarray(m_m))
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_m))


@pytest.mark.parametrize("use_hfa", [False, True])
def test_paged_verify_forked_table_bit_equal_materialized(use_hfa):
    """Same contract for the K-token verify walk: a speculative step
    over a forked (COW-shared) table == the materialized copy, bit for
    bit, fa2 + hfa."""
    from repro.kernels import paged_verify as paged_ver
    kw = 3
    q, kp, vp, shared, mat, sl = _cow_tables(203, kw=kw)
    cl = jnp.full((2,), kw, jnp.int32)
    # KV for the verify columns is pre-written in the pools; aliasing
    # covers it identically by construction of _cow_tables.
    o_s, m_s, l_s = paged_ver.paged_verify_partial_pallas(
        q, kp, vp, shared, sl, cl, use_hfa=use_hfa, interpret=True)
    o_m, m_m, l_m = paged_ver.paged_verify_partial_pallas(
        q, kp, vp, mat, sl, cl, use_hfa=use_hfa, interpret=True)
    np.testing.assert_array_equal(np.asarray(o_s), np.asarray(o_m))
    np.testing.assert_array_equal(np.asarray(m_s), np.asarray(m_m))
    np.testing.assert_array_equal(np.asarray(l_s), np.asarray(l_m))


@pytest.mark.parametrize("impl", ["fa2", "hfa_pallas"])
def test_ops_paged_jnp_forked_table_bit_equal_materialized(impl):
    """The jnp gather paths (CPU serving) honor the same aliasing
    contract end to end through ops.paged_{decode,verify}_attention."""
    q, kp, vp, shared, mat, sl = _cow_tables(207, kw=2)
    b, hkv, g, kw, d = q.shape
    q4 = jnp.swapaxes(q.reshape(b, hkv * g, kw, d), 1, 2)
    cl = jnp.full((b,), kw, jnp.int32)
    v_s = np.asarray(ops.paged_verify_attention(q4, kp, vp, shared, sl, cl,
                                                impl=impl))
    v_m = np.asarray(ops.paged_verify_attention(q4, kp, vp, mat, sl, cl,
                                                impl=impl))
    np.testing.assert_array_equal(v_s, v_m)
    d_s = np.asarray(ops.paged_decode_attention(q4[:, :1], kp, vp, shared,
                                                sl, impl=impl))
    d_m = np.asarray(ops.paged_decode_attention(q4[:, :1], kp, vp, mat,
                                                sl, impl=impl))
    np.testing.assert_array_equal(d_s, d_m)


# ------------------------------------------- page codecs (quantized KV)
PAGE_CODECS = ["fp", "int8", "log16"]


@pytest.mark.parametrize("name", PAGE_CODECS)
def test_page_codec_roundtrip(name):
    """Per-codec encode/decode contract: fp is the identity (bit-exact);
    int8 per-row absmax error is bounded by half a quantization step
    and all-zero rows survive exactly; log16's stored uint16 IS the
    BFloat16 bit pattern, so its roundtrip equals a bf16 cast exactly."""
    from repro.kernels import page_codec
    c = page_codec.get_codec(name)
    x = _rand((3, 8, 2, 64), jnp.float32, 301)
    x = x.at[1, 2].set(0.0)                       # an all-zero token row
    data, scales = c.encode(x)
    y = np.asarray(c.decode(data, scales))
    if name == "fp":
        assert scales is None
        np.testing.assert_array_equal(y, np.asarray(x))
    elif name == "int8":
        assert data.dtype == jnp.int8
        assert scales.shape == x.shape[:-1] + (1,)
        err = np.abs(y - np.asarray(x))
        bound = 0.5 * np.asarray(scales) * (1 + 1e-5) + 1e-7
        assert (err <= bound).all(), float((err - bound).max())
        np.testing.assert_array_equal(y[1, 2], 0.0)
    else:
        assert data.dtype == jnp.uint16 and scales is None
        ref = np.asarray(x.astype(jnp.bfloat16).astype(jnp.float32))
        np.testing.assert_array_equal(y, ref)


@pytest.mark.parametrize("name", PAGE_CODECS)
def test_page_codec_pool_byte_accounting(name):
    """The pool arrays `stack_init_paged_cache` actually allocates (data
    + scale sidecars) match the codec's declared `bytes_per_token`, the
    single source of truth the engine and the benchmark scoreboard use
    for slots-at-equal-pool-bytes."""
    from repro.configs import get_config
    from repro.kernels import page_codec
    from repro.models import transformer
    cfg = get_config("qwen3-1.7b").reduced()
    num_pages, page = 6, 8
    layers = transformer.stack_init_paged_cache(
        cfg, num_pages, page, jnp.float32, codec=name)
    total = sum(int(a.nbytes) for d in layers.values()
                for a in d.values())
    expect = (cfg.n_layers * num_pages * page *
              page_codec.bytes_per_token(name, cfg.n_kv_heads,
                                         cfg.d_head, jnp.float32))
    assert total == expect
    keys = set(next(iter(layers.values())))
    want = {"k_pages", "v_pages"} | (
        {"k_scale", "v_scale"} if name == "int8" else set())
    assert keys == want


def _codec_pools(name, kp, vp):
    """Encode raw f32 pools; read rule: fp stays on codec=None (the
    byte-identical pre-codec path), quantized codecs pass themselves."""
    from repro.kernels import page_codec
    c = page_codec.get_codec(name)
    kd, ks = c.encode(kp)
    vd, vs = c.encode(vp)
    rc = None if c.name == "fp" else c
    return kd, vd, dict(codec=rc, k_scales=ks, v_scales=vs)


# Output drift vs the raw fp pool, both rails (fp must be bit-exact;
# int8/log16 bounds are ~4x the drift measured on N(0,1) pools).
PAGE_CODEC_ATOL = {"fp": 0.0, "int8": 5e-2, "log16": 5e-2}


@pytest.mark.parametrize("impl,pal_atol", [("fa2_pallas", 1e-4),
                                           ("hfa_pallas", 2e-2)])
@pytest.mark.parametrize("name", PAGE_CODECS)
def test_paged_codec_parity_matrix(name, impl, pal_atol):
    """codec x rail x op parity matrix through the ops wrappers: for
    each of paged decode/prefill/verify, (1) the codec path tracks the
    raw fp pool within the documented atol (fp: bit-exact), and (2) the
    Pallas kernel (dequant in the tile loop) matches the jnp gather
    fallback (dequant on the gathered view) within rail tolerance."""
    kw = 4
    q, kp, vp, pt, sl, cl = _verify_setup(400, kw=kw)
    b, hkv, g, _, d = q.shape
    q4 = jnp.swapaxes(q.reshape(b, hkv * g, kw, d), 1, 2)  # (B,kw,H,d)
    kd, vd, ck = _codec_pools(name, kp, vp)

    def runs(tag, call):
        ref = np.asarray(call(kp, vp, {}))               # raw fp pool
        y_jnp = np.asarray(call(kd, vd, ck))
        y_pal = np.asarray(call(kd, vd, {**ck, "force_pallas": True}))
        if name == "fp":
            np.testing.assert_array_equal(y_jnp, ref, err_msg=tag)
        else:
            np.testing.assert_allclose(y_jnp, ref, err_msg=tag,
                                       atol=PAGE_CODEC_ATOL[name])
        np.testing.assert_allclose(y_pal, y_jnp, atol=pal_atol,
                                   err_msg=tag)

    runs("decode", lambda k, v, e: ops.paged_decode_attention(
        q4[:, :1], k, v, pt, sl + 1, impl=impl, **e))
    runs("verify", lambda k, v, e: ops.paged_verify_attention(
        q4, k, v, pt, sl, cl, impl=impl, **e))
    runs("prefill", lambda k, v, e: ops.paged_prefill_attention(
        q4, k, v, pt, sl, cl, impl=impl, **e))


@pytest.mark.parametrize("impl", ["fa2_pallas", "hfa_pallas"])
@pytest.mark.parametrize("name", ["int8", "log16"])
def test_paged_codec_cow_fork_and_rollback(name, impl):
    """Encoded pools honor the COW contracts: (1) a forked (page-
    aliased) table is BIT-equal to a materialized copy when the scale
    sidecars ride the same `copy_pages`; (2) rows past seq_len - the
    stale encodings (and stale scales) a speculative rollback leaves
    behind - never reach the output, so rollback stays a pure seq_len
    decrement for every codec."""
    from repro.kernels import page_codec
    from repro.kernels import paged_prefill as paged_pf
    rng = np.random.default_rng(501)
    b, hkv, h, d, page, pages_each, kw = 2, 2, 4, 64, 8, 3, 2
    num_pages = 2 * pages_each + 2
    kp = _rand((num_pages, page, hkv, d), jnp.float32, 502)
    vp = _rand((num_pages, page, hkv, d), jnp.float32, 503)
    c = page_codec.get_codec(name)
    kd, ks = c.encode(kp)
    vd, vs = c.encode(vp)
    src = rng.permutation(pages_each).astype(np.int32)
    dst = (pages_each + rng.permutation(pages_each)).astype(np.int32)
    sj, dj = jnp.asarray(src), jnp.asarray(dst)
    kd = paged_pf.copy_pages(kd, sj, dj)
    vd = paged_pf.copy_pages(vd, sj, dj)
    if ks is not None:
        ks = paged_pf.copy_pages(ks, sj, dj)
        vs = paged_pf.copy_pages(vs, sj, dj)
    shared = jnp.asarray(np.stack([src, src]))
    mat = jnp.asarray(np.stack([src, dst]))
    sl = jnp.asarray(rng.integers(1, pages_each * page - kw + 1,
                                  b).astype(np.int32))
    cl = jnp.full((b,), kw, jnp.int32)
    q = _rand((b, kw, h, d), jnp.float32, 504)
    ck = dict(impl=impl, codec=c, k_scales=ks, v_scales=vs,
              force_pallas=True)
    v_s = np.asarray(ops.paged_verify_attention(q, kd, vd, shared, sl,
                                                cl, **ck))
    v_m = np.asarray(ops.paged_verify_attention(q, kd, vd, mat, sl, cl,
                                                **ck))
    np.testing.assert_array_equal(v_s, v_m)
    d_s = np.asarray(ops.paged_decode_attention(q[:, :1], kd, vd, shared,
                                                sl, **ck))
    d_m = np.asarray(ops.paged_decode_attention(q[:, :1], kd, vd, mat,
                                                sl, **ck))
    np.testing.assert_array_equal(d_s, d_m)
    # Rollback half: trash every encoded row (and scale) at positions
    # >= sl + kw; the reads above are bounded by seq/chunk lens, so the
    # outputs must not move by a single bit.
    keep = np.zeros(kd.shape[:2], bool)           # (P, row) rows read
    mat_np = np.asarray(mat)
    for i in range(b):
        for pos in range(int(sl[i]) + kw):
            keep[mat_np[i, pos // page], pos % page] = True
    jr = np.random.default_rng(505)
    pools = {"k": np.array(kd), "v": np.array(vd)}
    for key, orig in (("k", kd), ("v", vd)):
        a = pools[key]
        a[...] = jr.integers(1, 120, a.shape).astype(a.dtype)
        a[keep] = np.asarray(orig)[keep]
    ksx, vsx = ks, vs
    if ks is not None:
        ksx, vsx = np.array(ks), np.array(vs)
        for a, orig in ((ksx, ks), (vsx, vs)):
            a[...] = jr.standard_normal(a.shape).astype(a.dtype)
            a[keep] = np.asarray(orig)[keep]
    ck2 = dict(impl=impl, codec=c, k_scales=None if ksx is None
               else jnp.asarray(ksx),
               v_scales=None if vsx is None else jnp.asarray(vsx),
               force_pallas=True)
    kdx, vdx = jnp.asarray(pools["k"]), jnp.asarray(pools["v"])
    v_j = np.asarray(ops.paged_verify_attention(q, kdx, vdx, mat, sl, cl,
                                                **ck2))
    np.testing.assert_array_equal(v_j, v_m)
    d_j = np.asarray(ops.paged_decode_attention(q[:, :1], kdx, vdx, mat,
                                                sl, **ck2))
    np.testing.assert_array_equal(d_j, d_m)
