"""Conformance suite: parallel sampling + beam search over COW forks.

The contracts this file pins down:

  (a) *n-parallel sampling is branch-for-branch token-identical to n
      independent single-slot requests* submitted with the derived
      per-branch seeds (``branch_seed(seed, b)``), on both the fp and
      ``use_hfa`` attention rails, with and without speculation, and
      under 2-way tensor parallelism (subprocess, simulated mesh) -
      the fan-out over ``PagedKVCache.fork`` must be invisible in the
      tokens.
  (b) *Beam width 1 equals greedy*: the degenerate beam reduces to the
      engine's plain argmax stream.
  (c) *Beam results are invariant to slot permutation*: candidate
      ordering is a function of (score, branch, token), never of the
      slot numbers the branches happen to occupy.
  (d) *Group eviction is lossless*: preemption drops all branch
      progress, and the deterministic re-derivation yields the same
      completions.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.serving import (InvalidRequestError, Request, SamplingParams,
                           ServingEngine, branch_seed)

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def qwen_smoke():
    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def qwen_hfa(qwen_smoke):
    from repro.models.model import build_model
    cfg, _, params = qwen_smoke
    cfg = dataclasses.replace(cfg, attn_impl="hfa")
    return cfg, build_model(cfg), params


def _engine(model, params, **kw):
    kw.setdefault("max_batch", 6)
    kw.setdefault("page_size", 4)
    kw.setdefault("max_seq", 64)
    return ServingEngine(model, params, **kw)


def _run_one(model, params, req, **kw):
    engine = _engine(model, params, **kw)
    [fin] = engine.run([(0, req)])
    engine.cache.check_invariants()
    return fin, engine


# --------------------------------------------- (a) parallel sampling
@pytest.mark.parametrize("rail", ["fa2", "hfa"])
def test_parallel_sampling_matches_independent_requests(
        qwen_smoke, qwen_hfa, rail):
    """n=4 branches of one group == 4 independent requests with the
    derived branch seeds, token for token and branch for branch."""
    cfg, model, params = qwen_smoke if rail == "fa2" else qwen_hfa
    rng = np.random.default_rng(11)
    prompt = rng.integers(1, cfg.vocab_size, 6).tolist()
    sp = SamplingParams(temperature=0.8, top_k=8, seed=314)
    fin, engine = _run_one(model, params, Request(
        rid=0, prompt=prompt, max_new_tokens=6, sampling=sp, n=4))
    assert fin.completions is not None and len(fin.completions) == 4
    assert [c.branch for c in fin.completions] == [0, 1, 2, 3]
    assert engine.stats["groups"] == 1 and engine.stats["forks"] == 3
    assert fin.tokens == fin.completions[0].tokens
    for c in fin.completions:
        solo, _ = _run_one(model, params, Request(
            rid=1, prompt=prompt, max_new_tokens=6,
            sampling=dataclasses.replace(
                sp, seed=branch_seed(sp.seed, c.branch))))
        assert c.tokens == solo.tokens, (rail, c.branch)


def test_parallel_sampling_composes_with_speculation(qwen_smoke):
    """Exact-accept speculation runs per branch: the group's streams
    are unchanged by spec_k (the lossless-acceptance contract applied
    branch-wise)."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(13)
    prompt = rng.integers(1, cfg.vocab_size, 5).tolist()
    sp = SamplingParams(temperature=0.7, top_k=4, seed=99)
    req = lambda: Request(rid=0, prompt=prompt, max_new_tokens=12,  # noqa
                          sampling=sp, n=3)
    plain, _ = _run_one(model, params, req())
    spec, eng = _run_one(model, params, req(), spec_k=3)
    assert [c.tokens for c in spec.completions] == \
        [c.tokens for c in plain.completions]
    assert eng.stats["draft_tokens"] > 0, "never speculated"


def test_best_of_returns_top_n_by_score(qwen_smoke):
    """best_of=4, n=2 returns the 2 best of the 4 branch streams by
    length-normalized cumulative logprob - the same streams the full
    n=4 group produces."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(17)
    prompt = rng.integers(1, cfg.vocab_size, 6).tolist()
    sp = SamplingParams(temperature=0.9, top_k=8, seed=7)
    full, _ = _run_one(model, params, Request(
        rid=0, prompt=prompt, max_new_tokens=5, sampling=sp, n=4,
        best_of=4))
    top2, _ = _run_one(model, params, Request(
        rid=0, prompt=prompt, max_new_tokens=5, sampling=sp, n=2,
        best_of=4))
    assert len(top2.completions) == 2
    # ranked: scores descend, and equal the best of the full set
    want = sorted(full.completions, key=lambda c: (-c.score, c.branch))[:2]
    assert [(c.branch, c.tokens) for c in top2.completions] == \
        [(c.branch, c.tokens) for c in want]
    assert top2.completions[0].score >= top2.completions[1].score


def test_parallel_sampling_group_preemption_is_lossless(qwen_smoke):
    """A group evicted under pool pressure re-derives the identical
    completions after re-admission (seeded determinism)."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(19)
    prompt = rng.integers(1, cfg.vocab_size, 6).tolist()
    sp = SamplingParams(temperature=0.8, top_k=4, seed=5)
    mk = lambda rid: Request(rid=rid, prompt=prompt, max_new_tokens=8,  # noqa
                             sampling=sp, n=2)
    calm, _ = _run_one(model, params, mk(0))
    # tight pool: group + a competing stream force preemptions
    engine = _engine(model, params, max_batch=4, num_pages=8, max_seq=40)
    longp = rng.integers(1, cfg.vocab_size, 4).tolist()
    fins = engine.run([(0, mk(0)), (0, Request(rid=1, prompt=longp,
                                               max_new_tokens=8))])
    engine.cache.check_invariants()
    by_rid = {f.rid: f for f in fins}
    assert engine.stats["preemptions"] >= 1, "pool never pressured"
    assert [c.tokens for c in by_rid[0].completions] == \
        [c.tokens for c in calm.completions]


def test_group_width_over_max_batch_rejected(qwen_smoke):
    """Resource rejection (width over this engine's capacity) finishes
    as reason="rejected"; contradictory knobs are client misuse and
    raise InvalidRequestError even through run()."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(23)
    prompt = rng.integers(1, cfg.vocab_size, 4).tolist()
    engine = _engine(model, params, max_batch=3)
    with pytest.raises(ValueError):
        engine.submit(Request(rid=0, prompt=prompt, max_new_tokens=4,
                              n=4))
    fins = engine.run([(0, Request(rid=0, prompt=prompt, max_new_tokens=4,
                                   n=4))])
    assert fins[0].reason == "rejected"
    for bad in (Request(rid=1, prompt=prompt, max_new_tokens=4,
                        beam_width=2, best_of=3),
                Request(rid=2, prompt=prompt, max_new_tokens=4,
                        n=3, best_of=2),
                Request(rid=3, prompt=prompt, max_new_tokens=4,
                        beam_width=2,
                        sampling=SamplingParams(temperature=0.5))):
        with pytest.raises(InvalidRequestError):
            engine.run([(0, bad)])


# ------------------------------------------------------ (b) beam == greedy
@pytest.mark.parametrize("rail", ["fa2", "hfa"])
def test_beam_width_one_equals_greedy(qwen_smoke, qwen_hfa, rail):
    cfg, model, params = qwen_smoke if rail == "fa2" else qwen_hfa
    rng = np.random.default_rng(29)
    for trial in range(2):
        prompt = rng.integers(1, cfg.vocab_size, 5 + trial).tolist()
        greedy, _ = _run_one(model, params, Request(
            rid=0, prompt=prompt, max_new_tokens=6))
        beam, _ = _run_one(model, params, Request(
            rid=0, prompt=prompt, max_new_tokens=6, beam_width=1))
        assert beam.completions[0].tokens == greedy.tokens, rail
        assert beam.tokens == greedy.tokens


# ------------------------------------- (c) slot-permutation invariance
def test_beam_results_invariant_to_slot_permutation(qwen_smoke):
    """The same beam request must produce identical completions whether
    its branches land on slots 0..w-1 (alone) or on higher slots
    (neighbors admitted first): candidate ranking keys are
    (score, branch, token), never slot ids."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(31)
    prompt = rng.integers(1, cfg.vocab_size, 6).tolist()
    mk = lambda: Request(rid=9, prompt=prompt, max_new_tokens=5,  # noqa
                         beam_width=3, n=3)
    alone, _ = _run_one(model, params, mk())
    engine = _engine(model, params, max_batch=6)
    neighbors = [Request(rid=i, prompt=rng.integers(
        1, cfg.vocab_size, 4 + i).tolist(), max_new_tokens=10)
        for i in range(2)]
    # neighbors first: the beam group fans out on permuted slots
    fins = engine.run([(0, neighbors[0]), (0, neighbors[1]), (1, mk())])
    engine.cache.check_invariants()
    shifted = next(f for f in fins if f.rid == 9)
    assert [(c.tokens, round(c.score, 5)) for c in shifted.completions] \
        == [(c.tokens, round(c.score, 5)) for c in alone.completions]


def test_beam_scores_are_ranked_and_normalized(qwen_smoke):
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(37)
    prompt = rng.integers(1, cfg.vocab_size, 6).tolist()
    fin, engine = _run_one(model, params, Request(
        rid=0, prompt=prompt, max_new_tokens=5, beam_width=4, n=4))
    assert len(fin.completions) == 4
    scores = [c.score for c in fin.completions]
    assert scores == sorted(scores, reverse=True)
    assert all(s < 0 for s in scores), "logprob scores must be negative"
    assert engine.stats["beam_steps"] > 0


# ----------------------------------------------- (a cont.) under --tp 2
_TP_CODE = """
import dataclasses
import numpy as np
import jax
from repro.configs import get_config
from repro.launch.mesh import make_tp_mesh
from repro.models.model import build_model
from repro.serving import Request, SamplingParams, ServingEngine, branch_seed

cfg = get_config("qwen3-1.7b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
rng = np.random.default_rng(41)
prompt = rng.integers(1, cfg.vocab_size, 6).tolist()
sp = SamplingParams(temperature=0.8, top_k=8, seed=77)

def run(n, mesh=None, seed=None):
    engine = ServingEngine(model, params, max_batch=6, page_size=4,
                           max_seq=48, mesh=mesh)
    s = sp if seed is None else dataclasses.replace(sp, seed=seed)
    [fin] = engine.run([(0, Request(rid=0, prompt=prompt, max_new_tokens=5,
                                    sampling=s, n=n))])
    engine.cache.check_invariants()
    return fin

single = run(4)
tp = run(4, mesh=make_tp_mesh(2))
assert [c.tokens for c in tp.completions] == \\
    [c.tokens for c in single.completions], "TP diverged from single shard"
for c in tp.completions:
    solo = run(1, mesh=make_tp_mesh(2), seed=branch_seed(77, c.branch))
    assert solo.tokens == c.tokens, ("tp-independent", c.branch)
print("TP-PARALLEL-OK")
"""


def test_parallel_sampling_token_identical_under_tp2():
    """Group bookkeeping is host-side and replicated, so 2-way tensor
    parallelism must not perturb any branch stream: group-under-TP ==
    group-single-shard == independent requests under TP."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = REPO_SRC
    proc = subprocess.run([sys.executable, "-c", _TP_CODE], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "TP-PARALLEL-OK" in proc.stdout


# --------------------------------------------- beam early-stopping
def _drive_beam(early_stop):
    """Host-side beam run against a real Scheduler + PagedKVCache with a
    deterministic candidate stream: the root expansion immediately
    finishes two strong eos hypotheses, every later candidate is far
    weaker, so with n=2 the best-live-vs-n-th-finished bound proves
    convergence at the first reorder while the exhaustive run decodes
    its branches to the length budget.  Returns (FinishedRequest,
    reorder_steps, Scheduler)."""
    from repro.serving import PagedKVCache, Scheduler
    eos = 7
    cache = PagedKVCache(64, 4, 8, 8)
    s = Scheduler(cache)
    s.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5], max_new_tokens=4,
                     eos_id=eos, beam_width=3, n=2,
                     beam_early_stop=early_stop))
    chunks, _ = s.schedule_prefill(None)
    for ck in chunks:
        s.complete_chunk(ck)
        cache.register_pages(ck.slot, s.running[ck.slot].tokens())
    (slot,) = list(s.running)
    fr = s.fan_out_beam(slot, [(eos, -0.1), (eos, -0.15), (10, -4.0),
                               (11, -4.2), (12, -4.4), (13, -4.6)])
    steps = 0
    while fr is None:
        steps += 1
        assert steps < 20
        group = None
        for step in s.schedule_decode(0):
            st = s.running[step.slot]
            assert cache.ensure_append_capacity(step.slot)
            n = int(cache.seq_lens[step.slot])
            cache.mark_prefilled(step.slot, n + len(step.tokens))
            cache.register_pages(step.slot, st.tokens())
            group = st.group
        weak = [(20 + steps, -0.5), (21 + steps, -0.6), (22 + steps, -0.7),
                (23 + steps, -0.8), (24 + steps, -0.9), (25 + steps, -1.0)]
        fr = s.beam_reorder(group, {sl: list(weak) for sl in group.slots})
    cache.check_invariants()
    assert cache.available_page_count == cache.num_pages
    return fr, steps, s


def test_beam_early_stop_results_unchanged():
    """Early stopping is an optimization, never a semantic change: the
    early-stopped run returns the exact completions (tokens, reasons,
    scores) of the run-to-exhaustion baseline, stops strictly sooner,
    and is the only one to bump the `beam_early_stops` counter."""
    fast, fast_steps, s_fast = _drive_beam(True)
    slow, slow_steps, s_slow = _drive_beam(False)
    assert s_fast.beam_early_stops == 1
    assert s_slow.beam_early_stops == 0
    assert fast_steps < slow_steps
    assert fast.tokens == slow.tokens and fast.reason == slow.reason
    assert [(c.tokens, c.reason) for c in fast.completions] == \
        [(c.tokens, c.reason) for c in slow.completions]
    for a, b in zip(fast.completions, slow.completions):
        assert a.score == b.score
    # the winning hypotheses are the two root eos candidates
    assert [c.tokens for c in fast.completions] == [[7], [7]]
