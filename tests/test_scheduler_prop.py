"""Property-based tests for the Scheduler + PagedKVCache layer.

Extends ``test_paged_cache_prop.py`` one layer up: instead of driving
the block pool directly, random traces of *engine-shaped* events -
admit / admit-group / chunked-prefill / fan-out / pause / preempt /
group-preempt / speculative-accept (with rollback) / branch-retire /
beam-reorder / retire - flow through the real ``Scheduler`` against a
real ``PagedKVCache``, mirroring exactly the bookkeeping
``ServingEngine`` performs around each jitted call.  After every event:

  * ``check_invariants`` holds (refcount conservation, page-set
    partition, hash-table bijection, LRU cap);
  * no slot is double-used: the scheduler's running set and the cache's
    owned/free slot sets stay mutually consistent, and the free pool
    always covers the group slot reservations;
  * scheduler progress counters and cache ``seq_lens`` agree (a
    decoding slot's KV is always exactly one token behind its stream -
    the carry token's KV lands during the next verify step);
  * sequence-group invariants: live branch slots are running, every
    branch stream extends the group's prompt, and the full prompt
    pages recorded at fan-out stay physically shared by every branch
    (COW never splits a page below the prompt).

Latency-class / SLA events (PR 6): every submission carries a random
latency class, a cancel event drops a random in-flight request (the
pool must come back refcount-clean wherever it was), admission is
asserted priority-ordered (the scheduler only ever admits the best
(class priority, queue_seq) waiting candidate), and the adaptive
prefill budget is asserted inside its [floor, ceiling] clamp for
arbitrary headroom/rate combinations (deterministic fake clock).

Runs through hypothesis when installed, through a numpy manual-trace
battery otherwise.  Pure host logic, no jax.
"""
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # manual traces only
    HAVE_HYPOTHESIS = False

from repro.serving import (BATCH, INTERACTIVE, LATENCY_CLASSES, STANDARD,
                           PagedKVCache, Request, Scheduler)

PAGE = 4
NUM_PAGES = 24
MAX_BATCH = 4
PAGES_PER_SEQ = 6
EOS = 7

# Prompts drawn as prefixes of a fixed base plus a random tail make
# prefix-cache hits (shared pages at admission) common in the trace.
BASE = list(range(100, 100 + PAGES_PER_SEQ * PAGE))

CLASSES = sorted(LATENCY_CLASSES.values(), key=lambda c: c.priority)

N_OPS = 9

if HAVE_HYPOTHESIS:
    op_strategy = st.lists(
        st.tuples(st.integers(0, N_OPS - 1), st.integers(0, 10 ** 6)),
        min_size=1, max_size=100)


def manual_traces(n_traces, max_len, n_ops, seed=0):
    rng = np.random.default_rng(seed)
    for _ in range(n_traces):
        length = int(rng.integers(1, max_len + 1))
        yield [(int(rng.integers(0, n_ops)), int(rng.integers(0, 10 ** 6)))
               for _ in range(length)]


class _Driver:
    """Mirrors ServingEngine's host-side use of Scheduler + cache."""

    def __init__(self, spec_k: int, max_cached: int | None):
        self.c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ,
                              max_cached_pages=max_cached)
        # Deterministic fake clock, bumped by random deltas per op, so
        # SLA state (headroom, TTFT) is exercised without wall time.
        self.now = 0.0
        self.s = Scheduler(self.c, clock=lambda: self.now)
        self.spec_k = spec_k
        self.rid = 0
        self.finished: list = []

    # ------------------------------------------------------------ checks
    def check(self):
        self.c.check_invariants()
        # engine would apply these to the device pools; here: drain and
        # sanity-check them
        for src, dst in self.c.take_pending_copies():
            assert 0 <= src < NUM_PAGES and 0 <= dst < NUM_PAGES
            assert src != dst
        running = set(self.s.running)
        assert running == set(self.c._slot_pages), \
            "scheduler running set != cache owned-slot set"
        assert not running & set(self.c._free_slots), "slot double-use"
        assert self.c.free_slot_count >= self.s._reserved_slots(), \
            "group slot reservation exceeds the free pool"
        for slot, rst in self.s.running.items():
            sl = int(self.c.seq_lens[slot])
            if rst.decoding:
                # stream = prompt + generated; the last generated token
                # is the carry whose KV lands next verify step
                assert sl == rst.target - 1, (slot, sl, rst.target)
            else:
                assert sl == rst.computed, (slot, sl, rst.computed)
                assert rst.computed < rst.target
        self._check_groups()

    def _check_groups(self):
        groups = {}
        for slot, rst in self.s.running.items():
            if rst.group is not None:
                groups[id(rst.group)] = rst.group
        for g in groups.values():
            if not g.fanned_out:
                continue
            assert g.slots <= set(self.s.running), "dead branch slot"
            n_prefix = len(g.req.prompt) // PAGE
            assert len(g.prefix_pages) == n_prefix
            for slot in g.slots:
                rst = self.s.running[slot]
                assert rst.group is g
                # branch streams extend the shared prompt
                assert rst.tokens()[:len(g.req.prompt)] == g.req.prompt
                # shared-prefix invariant: the full prompt pages stay
                # physically shared - branches never write below the
                # prompt, so COW can never have split them
                assert self.c.slot_pages(slot)[:n_prefix] == \
                    g.prefix_pages, (slot, g.prefix_pages)
                for p in g.prefix_pages:
                    assert self.c.refcount(p) >= 1
        self._check_sla()

    def _check_sla(self):
        """Adaptive budget stays clamped for any headroom x rate, and
        headroom exists iff something is decoding."""
        headroom = self.s.sla_headroom()
        decoding = bool(self.s.decoding_slots())
        assert (headroom is None) == (not decoding)
        for rate in (0.0, 50.0, 1e9):
            b = self.s.adaptive_prefill_budget(rate, floor=2, ceiling=10)
            assert 2 <= b <= 10, (rate, headroom, b)
            if not decoding:
                assert b == 10          # no deadline -> full ceiling

    # --------------------------------------------------------------- ops
    def submit(self, rng):
        n_shared = int(rng.integers(0, len(BASE)))
        tail = rng.integers(0, 50, int(rng.integers(1, 6))).tolist()
        prompt = (BASE[:n_shared] + tail)[:PAGES_PER_SEQ * PAGE - 2]
        cls = CLASSES[int(rng.integers(len(CLASSES)))]
        self.s.submit(Request(rid=self.rid, prompt=prompt,
                              max_new_tokens=int(rng.integers(1, 9)),
                              eos_id=EOS, latency_class=cls))
        self.rid += 1

    def submit_group(self, rng):
        """Admit-group event: a parallel-sampling or beam request."""
        n_shared = int(rng.integers(0, len(BASE)))
        tail = rng.integers(0, 50, int(rng.integers(1, 6))).tolist()
        prompt = (BASE[:n_shared] + tail)[:PAGES_PER_SEQ * PAGE - 2]
        width = int(rng.integers(2, MAX_BATCH + 1))
        kw = {"beam_width": width} if rng.integers(0, 2) \
            else {"n": width}
        cls = CLASSES[int(rng.integers(len(CLASSES)))]
        self.s.submit(Request(rid=self.rid, prompt=prompt,
                              max_new_tokens=int(rng.integers(1, 7)),
                              eos_id=EOS, latency_class=cls, **kw))
        self.rid += 1

    def cancel(self, rng):
        """Cancel event: drop a random in-flight request - waiting,
        mid-prefill, mid-decode, or a whole fanned-out group - and
        demand it is gone everywhere (the post-op check() then proves
        the pool is refcount-clean)."""
        rids = sorted({st.req.rid for st in self.s.running.values()} |
                      {w.req.rid for w in self.s.waiting})
        if not rids:
            assert not self.s.cancel(10 ** 9)     # miss reports False
            return
        rid = rids[int(rng.integers(len(rids)))]
        assert self.s.cancel(rid)
        assert all(st.req.rid != rid for st in self.s.running.values())
        assert all(w.req.rid != rid for w in self.s.waiting)

    def _schedule_prefill_checked(self, budget):
        """schedule_prefill + the priority-ordering property: whatever
        was admitted must be exactly the best (class priority,
        queue_seq) prefix of the waiting queue."""
        before = {w.req.rid: self.s._waiting_key(w) for w in self.s.waiting}
        chunks, reused = self.s.schedule_prefill(budget)
        left = {w.req.rid for w in self.s.waiting}
        admitted = sorted(k for rid, k in before.items() if rid not in left)
        assert admitted == sorted(before.values())[:len(admitted)], \
            "admission skipped a more urgent waiting request"
        return chunks, reused

    def prefill(self, rng):
        budget = [None, 3, 7, 16][int(rng.integers(0, 4))]
        chunks, _ = self._schedule_prefill_checked(budget)
        for ck in chunks:
            self.s.complete_chunk(ck)
            self.c.register_pages(ck.slot, self.s.running[ck.slot].tokens())
            if ck.is_final:
                self._first_tokens(ck.slot, rng)

    def _first_tokens(self, slot, rng):
        """Engine's _finish_prefills: plain sequences record one sampled
        token; groups fan out (parallel: width branches + one token
        each; beam: top-2k root expansion)."""
        st = self.s.running[slot]
        if st.group is None:
            self._record(slot, 1, rng)
        elif st.group.beam:
            fr = self.s.fan_out_beam(slot,
                                     self._beam_cands(st.group.width, rng))
            if fr is not None:
                self.finished.append(fr)
        else:
            for bslot, _ in self.s.fan_out(slot):
                self._record(bslot, 1, rng)

    def _beam_cands(self, width, rng):
        toks = rng.choice(12, size=2 * width, replace=False)
        lps = -np.sort(rng.random(2 * width))
        return [(int(t), float(lp)) for t, lp in zip(toks, lps)]

    def _capacity_pass(self):
        for slot in self.s.decoding_slots():
            if slot not in self.s.running:
                continue
            while slot in self.s.running and \
                    not self.c.ensure_append_capacity(slot):
                at_ceiling = self.c.pages_for(
                    int(self.c.seq_lens[slot]) + 1) > PAGES_PER_SEQ
                victim = slot if at_ceiling else self.s.choose_victim()
                self.s.preempt(victim)

    def decode(self, rng):
        """One speculative decode step: capacity, draft trim, optimistic
        KV commit, random acceptance, rollback, beam reorder - the
        engine's _run_decode without the device call."""
        self._capacity_pass()
        steps = self.s.schedule_decode(self.spec_k)
        beam_groups = {}
        for step in steps:
            slot = step.slot
            if slot not in self.s.running:
                continue
            st = self.s.running[slot]
            sl = int(self.c.seq_lens[slot])
            c = len(step.tokens)
            if c > 1 and not self.c.ensure_capacity(slot, sl + c):
                c = max(1, min(
                    c, self.c.writable_token_capacity(slot) - sl))
            self.c.mark_prefilled(slot, sl + c)
            if st.group is not None and st.group.beam:
                assert c == 1, "speculation not disabled in a beam group"
                beam_groups[id(st.group)] = st.group
                self.c.register_pages(slot, st.tokens())
                continue
            a = int(rng.integers(1, c + 1))      # accepted prefix length
            used = self._record(slot, a, rng)
            if used is None:
                continue                          # retired: slot is gone
            if used < c:
                self.c.rollback(slot, sl + used)
            self.c.register_pages(slot, self.s.running[slot].tokens())
        for group in beam_groups.values():
            if not group.slots:
                continue
            per_slot = {s: self._beam_cands(group.width, rng)
                        for s in group.slots}
            fr = self.s.beam_reorder(group, per_slot)
            if fr is not None:
                self.finished.append(fr)

    def _record(self, slot, n, rng):
        """Record up to n sampled tokens; returns tokens consumed, or
        None when the sequence finished (slot retired / branch done)."""
        used = 0
        for _ in range(n):
            tok = int(rng.integers(0, 12))        # EOS sometimes
            used += 1
            status = self.s.record_token(slot, tok)
            if status != "running":
                fr = self.s.finish(slot, status)
                if fr is not None:
                    self.finished.append(fr)
                return None
        return used

    def preempt(self, rng):
        if not self.s.running:
            return
        slots = sorted(self.s.running)
        self.s.preempt(slots[int(rng.integers(len(slots)))])

    def preempt_group(self, rng):
        """Group-preempt event: evict a whole live group explicitly."""
        groups = {}
        for st in self.s.running.values():
            if st.group is not None:
                groups[id(st.group)] = st.group
        if not groups:
            return
        keys = sorted(groups)
        self.s.preempt_group(groups[keys[int(rng.integers(len(keys)))]])

    def pause_probe(self, rng):
        """Pool-pressure pause: schedule prefill with a huge budget while
        pages are scarce - paused sequences must keep slot + pages and
        stay consistent (the scheduler returns no chunk for them)."""
        chunks, _ = self._schedule_prefill_checked(None)
        scheduled = {ck.slot for ck in chunks}
        for slot in self.s.prefilling_slots():
            if slot not in scheduled:
                # paused in place: owns its pages, no progress made
                assert slot in self.c._slot_pages
        for ck in chunks:
            self.s.complete_chunk(ck)
            self.c.register_pages(ck.slot, self.s.running[ck.slot].tokens())
            if ck.is_final:
                self._first_tokens(ck.slot, rng)


def _run_trace(ops, spec_k, max_cached):
    d = _Driver(spec_k, max_cached)
    dispatch = [d.submit, d.submit_group, d.prefill, d.decode, d.decode,
                d.preempt, d.preempt_group, d.pause_probe, d.cancel]
    assert len(dispatch) == N_OPS
    for code, seed in ops:
        d.now += (seed % 997) / 100.0        # deterministic clock advance
        dispatch[code](np.random.default_rng(seed))
        d.check()
    # teardown: retire everything; nothing leaks
    for slot in sorted(d.s.running):
        if slot not in d.s.running:
            continue
        st = d.s.running[slot]
        if st.group is not None:
            d.s.drop_branch(slot)
        else:
            d.s.retire(slot, "length")
    d.c.check_invariants()
    assert d.c.available_page_count == NUM_PAGES
    assert d.c.free_slot_count == MAX_BATCH
    for fr in d.finished:
        if fr.completions is not None:
            assert 1 <= len(fr.completions) <= MAX_BATCH
            assert fr.tokens == fr.completions[0].tokens


if HAVE_HYPOTHESIS:
    @settings(max_examples=50, deadline=None)
    @given(ops=op_strategy, spec_k=st.integers(0, 4),
           max_cached=st.sampled_from([None, 0, 4, 12]))
    def test_scheduler_random_trace(ops, spec_k, max_cached):
        _run_trace(ops, spec_k, max_cached)


def test_scheduler_trace_manual():
    """No-hypothesis fallback: the same driver over numpy traces across
    the spec_k x LRU-cap grid."""
    cfgs = [(0, None), (1, 4), (2, 12), (4, 0), (3, None)]
    for i, (spec_k, max_cached) in enumerate(cfgs):
        for ops in manual_traces(60, 100, N_OPS, seed=100 + i):
            _run_trace(ops, spec_k, max_cached)


def _run_rollback_churn(seed, spec_k):
    """Focused rollback churn: speculative commits that mostly reject
    must never leak a page or corrupt a refcount, including when the
    rolled-back tail pages are shared with a forked sibling."""
    rng = np.random.default_rng(seed)
    c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ)
    slot = c.alloc_slot(int(rng.integers(1, 9)))
    forks: list[int] = []
    for _ in range(40):
        sl = int(c.seq_lens[slot])
        want = sl + spec_k + 1
        if not c.ensure_capacity(slot, want):
            want = max(sl + 1, c.writable_token_capacity(slot))
            if want <= sl or not c.ensure_capacity(slot, want):
                break
        c.mark_prefilled(slot, want)
        keep = sl + int(rng.integers(1, want - sl + 1))
        if rng.random() < 0.3 and c.free_slot_count:
            # fork INSIDE the commit/rollback window, truncated at the
            # accepted prefix (contract point 5)
            forks.append(c.fork(slot, keep))
            c.check_invariants()
        if keep < want:
            c.rollback(slot, keep)
        c.check_invariants()
        assert int(c.seq_lens[slot]) == keep
        if rng.random() < 0.2 and c.free_slot_count:
            forks.append(c.fork(slot))
            c.check_invariants()
        elif forks and rng.random() < 0.3:
            c.free_slot(forks.pop())
            c.check_invariants()
    for f in forks:
        c.free_slot(f)
    c.free_slot(slot)
    c.check_invariants()
    assert c.available_page_count == NUM_PAGES


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(seed=st.integers(0, 10 ** 6), spec_k=st.integers(1, 4))
    def test_rollback_conserves_pages_and_refcounts(seed, spec_k):
        _run_rollback_churn(seed, spec_k)


def test_rollback_churn_manual():
    for seed in range(30):
        _run_rollback_churn(seed, 1 + seed % 4)


# ----------------------------------------------------- SLA determinism
def _sla_sched():
    clock = {"t": 0.0}
    c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ)
    s = Scheduler(c, clock=lambda: clock["t"])
    return s, c, clock


def _req(rid, cls, n_prompt=4, budget=8):
    return Request(rid=rid, prompt=list(range(10, 10 + n_prompt)),
                   max_new_tokens=budget, latency_class=cls)


def test_priority_admission_order():
    """Classes jump the FCFS queue by priority; FCFS holds within a
    class; preempted work resumes ahead of later same-class arrivals."""
    s, c, clock = _sla_sched()
    s.submit(_req(0, BATCH))
    s.submit(_req(1, STANDARD))
    s.submit(_req(2, INTERACTIVE))
    s.submit(_req(3, INTERACTIVE))
    admitted = s.admit()           # everything fits: one legacy admit
    order = [s.running[slot].req.rid for slot, _ in admitted]
    assert order == [2, 3, 1, 0]

    # Preempt the first interactive: it re-queues ahead of a NEW
    # interactive arrival but still ahead of nothing more urgent.
    first = next(sl for sl, st in s.running.items() if st.req.rid == 2)
    s.preempt(first)
    s.submit(_req(4, INTERACTIVE))
    nxt = s._next_waiting()
    assert nxt.req.rid == 2, "preempted work lost its place"


def test_choose_victim_prefers_least_urgent_class():
    s, c, clock = _sla_sched()
    s.submit(_req(0, INTERACTIVE))
    s.submit(_req(1, BATCH))
    s.admit()
    by_rid = {st.req.rid: sl for sl, st in s.running.items()}
    assert s.choose_victim() == by_rid[1]


def test_adaptive_budget_headroom_arithmetic():
    """budget = clamp(headroom * rate): exact on a fake clock."""
    s, c, clock = _sla_sched()
    assert s.sla_headroom() is None
    assert s.adaptive_prefill_budget(100.0, 4, 64) == 64   # no deadline

    s.submit(_req(0, STANDARD))          # tpot_target = 0.2s
    slot, toks = s.admit()[0]
    s.record_token(slot, 1)              # last_token_time = 0.0
    clock["t"] = 0.1                     # 0.1s headroom left
    assert abs(s.sla_headroom() - 0.1) < 1e-9
    assert s.adaptive_prefill_budget(100.0, 4, 64) == 10   # 0.1 * 100
    assert s.adaptive_prefill_budget(100.0, 4, 8) == 8     # ceiling
    clock["t"] = 10.0                    # already late
    assert s.adaptive_prefill_budget(100.0, 4, 64) == 4    # floor
    # The most urgent decoding slot sets the headroom.
    s.retire(slot, "length")
    s.submit(_req(1, INTERACTIVE))       # tpot_target = 0.05s
    slot2, _ = s.admit()[0]
    s.record_token(slot2, 1)             # last_token_time = 10.0
    clock["t"] = 10.01
    assert abs(s.sla_headroom() - 0.04) < 1e-9


def test_retire_reports_ttft():
    s, c, clock = _sla_sched()
    s.submit(_req(0, STANDARD))
    clock["t"] = 1.5
    slot, _ = s.admit()[0]
    clock["t"] = 2.0
    s.record_token(slot, 1)
    clock["t"] = 9.0                     # later tokens don't move TTFT
    s.record_token(slot, 2)
    fr = s.retire(slot, "length")
    assert abs(fr.ttft - 2.0) < 1e-9
    # Never-started requests report no TTFT.
    s.submit(_req(1, STANDARD))
    slot, _ = s.admit()[0]
    assert s.retire(slot, "cancelled").ttft is None


def test_cancel_everywhere_frees_pages():
    """Cancel while waiting, mid-prefill, and mid-decode: the pool must
    return to fully free every time."""
    s, c, clock = _sla_sched()
    # waiting
    s.submit(_req(0, STANDARD))
    assert s.cancel(0) and not s.waiting
    # mid-prefill (chunked, partial progress)
    s.submit(_req(1, STANDARD, n_prompt=12))
    chunks, _ = s.schedule_prefill(4)
    s.complete_chunk(chunks[0])
    assert s.prefilling_slots()
    assert s.cancel(1)
    c.check_invariants()
    assert c.available_page_count == NUM_PAGES
    assert c.free_slot_count == MAX_BATCH
    # mid-decode
    s.submit(_req(2, STANDARD))
    slot, _ = s.admit()[0]
    s.record_token(slot, 1)
    assert s.cancel(2)
    c.check_invariants()
    assert c.available_page_count == NUM_PAGES
    assert c.free_slot_count == MAX_BATCH
    # a miss is reported, not raised
    assert not s.cancel(99)
