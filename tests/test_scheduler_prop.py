"""Property-based tests for the Scheduler + PagedKVCache layer.

Extends ``test_paged_cache_prop.py`` one layer up: instead of driving
the block pool directly, random traces of *engine-shaped* events -
admit / chunked-prefill / pause / preempt / speculative-accept (with
rollback) / retire - flow through the real ``Scheduler`` against a real
``PagedKVCache``, mirroring exactly the bookkeeping ``ServingEngine``
performs around each jitted call.  After every event:

  * ``check_invariants`` holds (refcount conservation, page-set
    partition, hash-table bijection, LRU cap);
  * no slot is double-used: the scheduler's running set and the cache's
    owned/free slot sets stay mutually consistent;
  * scheduler progress counters and cache ``seq_lens`` agree (a
    decoding slot's KV is always exactly one token behind its stream -
    the carry token's KV lands during the next verify step).

Pure host logic, no jax.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional 'hypothesis' dep")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serving import PagedKVCache, Request, Scheduler  # noqa: E402

PAGE = 4
NUM_PAGES = 24
MAX_BATCH = 4
PAGES_PER_SEQ = 6
EOS = 7

# Prompts drawn as prefixes of a fixed base plus a random tail make
# prefix-cache hits (shared pages at admission) common in the trace.
BASE = list(range(100, 100 + PAGES_PER_SEQ * PAGE))

op_strategy = st.lists(
    st.tuples(st.integers(0, 5), st.integers(0, 10 ** 6)),
    min_size=1, max_size=100)


class _Driver:
    """Mirrors ServingEngine's host-side use of Scheduler + cache."""

    def __init__(self, spec_k: int, max_cached: int | None):
        self.c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ,
                              max_cached_pages=max_cached)
        self.s = Scheduler(self.c)
        self.spec_k = spec_k
        self.rid = 0
        self.finished: list = []

    # ------------------------------------------------------------ checks
    def check(self):
        self.c.check_invariants()
        # engine would apply these to the device pools; here: drain and
        # sanity-check them
        for src, dst in self.c.take_pending_copies():
            assert 0 <= src < NUM_PAGES and 0 <= dst < NUM_PAGES
            assert src != dst
        running = set(self.s.running)
        assert running == set(self.c._slot_pages), \
            "scheduler running set != cache owned-slot set"
        assert not running & set(self.c._free_slots), "slot double-use"
        for slot, rst in self.s.running.items():
            sl = int(self.c.seq_lens[slot])
            if rst.decoding:
                # stream = prompt + generated; the last generated token
                # is the carry whose KV lands next verify step
                assert sl == rst.target - 1, (slot, sl, rst.target)
            else:
                assert sl == rst.computed, (slot, sl, rst.computed)
                assert rst.computed < rst.target

    # --------------------------------------------------------------- ops
    def submit(self, rng):
        n_shared = int(rng.integers(0, len(BASE)))
        tail = rng.integers(0, 50, int(rng.integers(1, 6))).tolist()
        prompt = (BASE[:n_shared] + tail)[:PAGES_PER_SEQ * PAGE - 2]
        self.s.submit(Request(rid=self.rid, prompt=prompt,
                              max_new_tokens=int(rng.integers(1, 9)),
                              eos_id=EOS))
        self.rid += 1

    def prefill(self, rng):
        budget = [None, 3, 7, 16][int(rng.integers(0, 4))]
        chunks, _ = self.s.schedule_prefill(budget)
        for ck in chunks:
            self.s.complete_chunk(ck)
            self.c.register_pages(ck.slot, self.s.running[ck.slot].tokens())
            if ck.is_final:
                self._record(ck.slot, 1, rng)

    def _capacity_pass(self):
        for slot in self.s.decoding_slots():
            if slot not in self.s.running:
                continue
            while not self.c.ensure_append_capacity(slot):
                at_ceiling = self.c.pages_for(
                    int(self.c.seq_lens[slot]) + 1) > PAGES_PER_SEQ
                victim = slot if at_ceiling else self.s.choose_victim()
                self.s.preempt(victim)
                if victim == slot:
                    break

    def decode(self, rng):
        """One speculative decode step: capacity, draft trim, optimistic
        KV commit, random acceptance, rollback - the engine's
        _run_decode without the device call."""
        self._capacity_pass()
        steps = self.s.schedule_decode(self.spec_k)
        for step in steps:
            slot = step.slot
            if slot not in self.s.running:
                continue
            sl = int(self.c.seq_lens[slot])
            c = len(step.tokens)
            if c > 1 and not self.c.ensure_capacity(slot, sl + c):
                c = max(1, min(
                    c, self.c.writable_token_capacity(slot) - sl))
            self.c.mark_prefilled(slot, sl + c)
            a = int(rng.integers(1, c + 1))      # accepted prefix length
            used = self._record(slot, a, rng)
            if used is None:
                continue                          # retired: slot is gone
            if used < c:
                self.c.rollback(slot, sl + used)
            self.c.register_pages(slot, self.s.running[slot].tokens())

    def _record(self, slot, n, rng):
        """Record up to n sampled tokens; returns tokens consumed, or
        None when the request finished (slot retired)."""
        used = 0
        for _ in range(n):
            tok = int(rng.integers(0, 12))        # EOS sometimes
            used += 1
            status = self.s.record_token(slot, tok)
            if status != "running":
                self.finished.append(self.s.retire(slot, status))
                return None
        return used

    def preempt(self, rng):
        if not self.s.running:
            return
        slots = sorted(self.s.running)
        self.s.preempt(slots[int(rng.integers(len(slots)))])

    def pause_probe(self, rng):
        """Pool-pressure pause: schedule prefill with a huge budget while
        pages are scarce - paused sequences must keep slot + pages and
        stay consistent (the scheduler returns no chunk for them)."""
        chunks, _ = self.s.schedule_prefill(None)
        scheduled = {ck.slot for ck in chunks}
        for slot in self.s.prefilling_slots():
            if slot not in scheduled:
                # paused in place: owns its pages, no progress made
                assert slot in self.c._slot_pages
        for ck in chunks:
            self.s.complete_chunk(ck)
            self.c.register_pages(ck.slot, self.s.running[ck.slot].tokens())
            if ck.is_final:
                self._record(ck.slot, 1, rng)


@settings(max_examples=50, deadline=None)
@given(ops=op_strategy, spec_k=st.integers(0, 4),
       max_cached=st.sampled_from([None, 0, 4, 12]))
def test_scheduler_random_trace(ops, spec_k, max_cached):
    d = _Driver(spec_k, max_cached)
    dispatch = [d.submit, d.prefill, d.decode, d.decode, d.preempt,
                d.pause_probe]
    for code, seed in ops:
        dispatch[code](np.random.default_rng(seed))
        d.check()
    # teardown: retire everything; nothing leaks
    for slot in sorted(d.s.running):
        d.s.retire(slot, "length")
    d.c.check_invariants()
    assert d.c.available_page_count == NUM_PAGES
    assert d.c.free_slot_count == MAX_BATCH


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10 ** 6), spec_k=st.integers(1, 4))
def test_rollback_conserves_pages_and_refcounts(seed, spec_k):
    """Focused rollback churn: speculative commits that mostly reject
    must never leak a page or corrupt a refcount, including when the
    rolled-back tail pages are shared with a forked sibling."""
    rng = np.random.default_rng(seed)
    c = PagedKVCache(NUM_PAGES, PAGE, MAX_BATCH, PAGES_PER_SEQ)
    slot = c.alloc_slot(int(rng.integers(1, 9)))
    forks: list[int] = []
    for _ in range(40):
        sl = int(c.seq_lens[slot])
        want = sl + spec_k + 1
        if not c.ensure_capacity(slot, want):
            want = max(sl + 1, c.writable_token_capacity(slot))
            if want <= sl or not c.ensure_capacity(slot, want):
                break
        c.mark_prefilled(slot, want)
        keep = sl + int(rng.integers(1, want - sl + 1))
        if keep < want:
            c.rollback(slot, keep)
        c.check_invariants()
        assert int(c.seq_lens[slot]) == keep
        if rng.random() < 0.2 and c.free_slot_count:
            forks.append(c.fork(slot))
            c.check_invariants()
        elif forks and rng.random() < 0.3:
            c.free_slot(forks.pop())
            c.check_invariants()
    for f in forks:
        c.free_slot(f)
    c.free_slot(slot)
    c.check_invariants()
    assert c.available_page_count == NUM_PAGES
