"""Per-arch smoke tests (reduced configs) + prefill/decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.data import DataPipeline
from repro.models.model import build_model
from repro.optim import build_optimizer
from repro.optim.schedule import constant
from repro.runtime.trainer import make_train_step


def _batch(cfg, b, s, seed=0):
    rng = np.random.default_rng(seed)
    out = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)))}
    if cfg.family == "vlm":
        out["patches"] = jnp.asarray(
            rng.standard_normal((b, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        out["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.enc_seq, cfg.d_model)), jnp.float32)
    return out


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_smoke_forward_and_train_step(arch):
    """Reduced config: one forward + one full train step, shapes + finite."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch(cfg, b, s)

    logits, _ = model.apply(params, batch)
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    opt = build_optimizer(cfg, constant(1e-3))
    step = jax.jit(make_train_step(model, opt))
    carry = {"params": params, "opt_state": opt.init(params)}
    carry, metrics = step(carry, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_arch_prefill_decode_consistency(arch):
    """prefill(S-1) + decode(1) logits == full forward logits."""
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)  # no drops
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = _batch(cfg, b, s)
    enc_out = None
    prefix = batch.get("patches")
    if cfg.family == "encdec":
        enc_out = model._encode(params, batch["frames"], jnp.float32)
    full, _ = model.apply(params, batch)

    cache = model.init_cache(params, b, max_seq=64, enc_out=enc_out)
    toks = batch["tokens"]
    lg_p, cache = model.prefill(params, cache, toks[:, :s - 1],
                                prefix_embeds=prefix)
    lg_d, cache = model.decode_step(params, cache, toks[:, s - 1:s])
    np.testing.assert_allclose(np.asarray(lg_p[:, 0]),
                               np.asarray(full[:, s - 2]), atol=2e-3)
    np.testing.assert_allclose(np.asarray(lg_d[:, 0]),
                               np.asarray(full[:, s - 1]), atol=2e-3)


def test_multi_step_decode_matches_full_forward():
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    b, s = 1, 12
    batch = _batch(cfg, b, s, seed=3)
    full, _ = model.apply(params, batch)
    cache = model.init_cache(params, b, max_seq=32)
    lg, cache = model.prefill(params, cache, batch["tokens"][:, :4])
    outs = [lg]
    for t in range(4, s):
        lg, cache = model.decode_step(params, cache, batch["tokens"][:, t:t+1])
        outs.append(lg)
    got = np.concatenate([np.asarray(o[:, 0]) for o in outs], axis=0)
    want = np.asarray(full[0, 3:])
    np.testing.assert_allclose(got, want, atol=2e-3)


def test_hfa_attention_impl_end_to_end():
    """The paper's kernel as the model's attention: loss finite, close to fa2."""
    cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                              attn_impl="hfa_pallas")
    cfg_ref = dataclasses.replace(cfg, attn_impl="fa2")
    batch = _batch(cfg, 2, 16)
    model = build_model(cfg)
    model_ref = build_model(cfg_ref)
    params = model.init(jax.random.PRNGKey(0))
    lg_hfa, _ = model.apply(params, batch)
    lg_ref, _ = model_ref.apply(params, batch)
    a = np.asarray(lg_hfa.astype(jnp.float32))
    b = np.asarray(lg_ref.astype(jnp.float32))
    assert np.isfinite(a).all()
    # logits stay correlated under the H-FA approximation
    corr = np.corrcoef(a.ravel(), b.ravel())[0, 1]
    assert corr > 0.98


def test_param_count_sanity():
    """Config param_count stays within 25% of the real initialized count."""
    for arch in ["qwen3-1.7b", "granite-moe-1b-a400m", "mamba2-2.7b"]:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        shapes, _ = model.shape_and_logical()
        real = sum(np.prod(s.shape) for s in jax.tree.leaves(shapes))
        est = cfg.param_count()
        assert 0.5 < est / real < 1.5, (arch, est, real)
