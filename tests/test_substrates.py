"""Optimizers, checkpointing, data pipeline, gradient compression."""
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore, save
from repro.checkpoint.checkpoint import latest_step
from repro.data import DataPipeline
from repro.optim import adafactor, adamw, compression
from repro.optim.schedule import constant, warmup_cosine


def test_adamw_matches_numpy_reference():
    opt = adamw(constant(0.1), b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                master_fp32=False)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.5, 0.5, -1.0])}
    st = opt.init(p)
    p1, st = opt.update(g, st, p)
    m = 0.1 * np.asarray([0.5, 0.5, -1.0])
    v = 0.01 * np.asarray([0.25, 0.25, 1.0])
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = np.asarray([1.0, -2.0, 3.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p1["w"]), want, rtol=1e-6)


def test_adamw_weight_decay_pulls_to_zero():
    opt = adamw(constant(0.01), weight_decay=0.5, master_fp32=False)
    p = {"w": jnp.asarray([10.0])}
    st = opt.init(p)
    for _ in range(50):
        p, st = opt.update({"w": jnp.asarray([0.0])}, st, p)
    assert abs(float(p["w"][0])) < 10.0 * 0.9


@pytest.mark.parametrize("make", [lambda: adamw(constant(0.05)),
                                  lambda: adafactor(constant(0.5))])
def test_optimizer_descends_quadratic(make):
    opt = make()
    w = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal((8, 8)), jnp.float32)}
    st = opt.init(w)
    def loss(w_): return jnp.sum(w_["w"] ** 2)
    l0 = float(loss(w))
    for _ in range(60):
        g = jax.grad(loss)(w)
        w, st = opt.update(g, st, w)
    assert float(loss(w)) < 0.5 * l0


def test_adafactor_state_is_factored():
    opt = adafactor(constant(0.1))
    p = {"w": jnp.zeros((64, 32)), "b": jnp.zeros((64,))}
    st = opt.init(p)
    assert st["f"]["w"]["vr"].shape == (64,)
    assert st["f"]["w"]["vc"].shape == (32,)
    assert st["f"]["b"]["v"].shape == (64,)


def test_grad_compression_error_feedback_unbiased():
    """Error feedback: cumulative dequantized grads -> cumulative true grads."""
    rng = np.random.default_rng(0)
    g_true = [{"w": jnp.asarray(rng.standard_normal(256), jnp.float32)}
              for _ in range(30)]
    err = compression.init_error(g_true[0])
    acc_deq = np.zeros(256)
    acc_true = np.zeros(256)
    for g in g_true:
        deq, err = compression.compress_gradients(g, err)
        acc_deq += np.asarray(deq["w"])
        acc_true += np.asarray(g["w"])
    # residual bounded by one quantization step, not O(steps)
    assert np.abs(acc_deq - acc_true).max() < np.abs(acc_true).max() * 0.05 + 0.1


def test_checkpoint_roundtrip_and_dtype(tmp_path):
    tree = {"a": jnp.asarray([1.0, 2.0], jnp.bfloat16),
            "b": {"c": jnp.arange(6).reshape(2, 3)}}
    save(str(tmp_path), 3, tree)
    got, step = restore(str(tmp_path), None, tree)
    assert step == 3
    assert got["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(got["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_atomicity_no_partial_dirs(tmp_path):
    tree = {"w": jnp.zeros((4,))}
    save(str(tmp_path), 1, tree)
    save(str(tmp_path), 2, tree)
    names = os.listdir(tmp_path)
    assert all(not n.endswith(".tmp") for n in names)
    assert latest_step(str(tmp_path)) == 2


def test_checkpoint_manager_retention_and_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    tree = {"w": jnp.zeros((4,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    mgr.wait()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path))
    assert steps == [3, 4]
    got, step = mgr.restore_latest(tree)
    assert step == 4


def test_checkpoint_missing_leaf_raises(tmp_path):
    save(str(tmp_path), 1, {"a": jnp.zeros((2,))})
    with pytest.raises(KeyError):
        restore(str(tmp_path), 1, {"a": jnp.zeros((2,)), "b": jnp.zeros((2,))})


def test_data_pipeline_deterministic_and_sharded():
    pipe = DataPipeline(vocab_size=100, seq_len=16, global_batch=8, seed=7)
    a = pipe.batch(5)
    b = pipe.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = pipe.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    # shards partition the batch deterministically
    s0 = pipe.batch(5, shard=0, num_shards=2)
    s1 = pipe.batch(5, shard=1, num_shards=2)
    assert s0["tokens"].shape == (4, 16)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_data_pipeline_tokens_in_range():
    pipe = DataPipeline(vocab_size=50, seq_len=64, global_batch=4, seed=1)
    t = pipe.batch(0)["tokens"]
    assert t.min() >= 0 and t.max() < 50


def test_data_pipeline_has_structure():
    """Markov data: next-token entropy must be below iid-uniform entropy."""
    pipe = DataPipeline(vocab_size=1000, seq_len=256, global_batch=8, seed=3)
    t = pipe.batch(0)["tokens"]
    uniq = len(np.unique(t))
    assert uniq < 200  # projected 64-state chain, not iid over 1000
