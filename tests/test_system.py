"""End-to-end behaviour tests for the whole system."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataPipeline
from repro.models.model import build_model
from repro.runtime.trainer import Trainer, TrainerConfig


def test_train_then_serve_end_to_end(tmp_path):
    """Train a tiny LM on the Markov data, checkpoint, restore, decode."""
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    tcfg = TrainerConfig(steps=25, ckpt_every=10, ckpt_dir=str(tmp_path),
                         seq_len=64, global_batch=8, warmup=3, peak_lr=1e-3)
    tr = Trainer(model, tcfg)
    res = tr.run()
    losses = [m["loss"] for m in res["metrics"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses

    # restore and greedy-decode a continuation
    carry = tr._init_carry(jax.random.PRNGKey(0))
    carry, step = tr.ckpt.restore_latest(carry)
    assert step == 25
    params = carry["params"]
    pipe = tr.pipeline
    prompt = jnp.asarray(pipe.batch(999)["tokens"][:2, :16])
    cache = model.init_cache(params, 2, max_seq=48)
    logits, cache = model.prefill(params, cache, prompt)
    toks = []
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    for _ in range(8):
        toks.append(np.asarray(tok))
        logits, cache = model.decode_step(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    gen = np.concatenate(toks, axis=1)
    assert gen.shape == (2, 8)
    assert (gen >= 0).all() and (gen < cfg.padded_vocab).all()


def test_hfa_model_trains_like_fa2():
    """The paper's claim at system level: swapping FA-2 -> H-FA attention
    does not destabilize training on a small model."""
    results = {}
    for impl in ("fa2", "hfa_pallas"):
        cfg = dataclasses.replace(get_config("qwen3-1.7b").reduced(),
                                  attn_impl=impl, n_layers=2)
        model = build_model(cfg)
        from repro.optim import build_optimizer
        from repro.optim.schedule import constant
        from repro.runtime.trainer import make_train_step
        opt = build_optimizer(cfg, constant(1e-3))
        step = jax.jit(make_train_step(model, opt))
        params = model.init(jax.random.PRNGKey(0))
        carry = {"params": params, "opt_state": opt.init(params)}
        pipe = DataPipeline.for_config(cfg, 48, 4)
        losses = []
        for i in range(8):
            batch = jax.tree.map(jnp.asarray, pipe.batch(i))
            carry, m = step(carry, batch)
            losses.append(float(m["loss"]))
        results[impl] = losses
    assert np.isfinite(results["hfa_pallas"]).all()
    # same trend, bounded divergence between the two numerics
    d0 = abs(results["fa2"][0] - results["hfa_pallas"][0])
    assert d0 < 0.2, results
    assert results["hfa_pallas"][-1] < results["hfa_pallas"][0]
