"""Serving conformance suite: sampling + self-speculative decode.

Pins down the three contracts the sampler/spec subsystem must honor:

  (a) *Spec decode is lossless under greedy*: for spec_k in {1, 2, 4}
      the engine's greedy output is token-exact against the dense
      no-spec fixed-cache loop, draft hits and rollbacks included.
  (b) *Seeded sampling is bit-reproducible across batch compositions*:
      a request samples the same stream whether it shares a step with 0
      or 7 neighbors, with or without speculation, because keys are
      fold_in(PRNGKey(seed), stream_position) - never a function of the
      batch.
  (c) *Filter semantics match a numpy oracle*: top-k / top-p mass
      truncation and repetition penalty, elementwise.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.serving import Request, SamplingParams, ServingEngine
from repro.serving import sampler as S
from repro.serving.spec import propose_draft


@pytest.fixture(scope="module")
def qwen_smoke():
    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reference_stream(model, params, req, max_seq):
    """Dense fixed-cache loop + host-called sampler: the definitionally
    sequential oracle (one token at a time, no batching, no paging, no
    speculation).  Greedy when req.sampling is None."""
    sp = req.sampling or S.GREEDY
    vocab = model.cfg.padded_vocab
    presence = np.zeros((1, vocab), bool)
    presence[0, req.prompt] = True

    def pick(logits, pos):
        return int(S.sample_tokens(
            jnp.asarray(logits[None], jnp.float32), jnp.asarray(presence),
            jnp.asarray([sp.seed], jnp.int32), jnp.asarray([pos], jnp.int32),
            jnp.asarray([sp.temperature], jnp.float32),
            jnp.asarray([sp.top_k], jnp.int32),
            jnp.asarray([sp.top_p], jnp.float32),
            jnp.asarray([sp.repetition_penalty], jnp.float32))[0])

    cache = model.init_cache(params, 1, max_seq)
    lg, cache = model.prefill(params, cache,
                              jnp.asarray([req.prompt], jnp.int32))
    toks = [pick(np.asarray(lg[0, -1]), len(req.prompt))]
    presence[0, toks[-1]] = True
    for i in range(req.max_new_tokens - 1):
        lg, cache = model.decode_step(params, cache,
                                      jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(pick(np.asarray(lg[0, -1]), len(req.prompt) + i + 1))
        presence[0, toks[-1]] = True
    return toks


# ------------------------------------------------- (a) lossless greedy
@pytest.mark.parametrize("spec_k", [1, 2, 4])
def test_spec_greedy_token_exact(qwen_smoke, spec_k):
    """Greedy speculative decode must be lossless: every request's
    tokens equal the dense no-spec loop's, for every spec depth."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(101)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(2, 9))).tolist(),
                    max_new_tokens=int(rng.integers(6, 13)))
            for i in range(4)]
    gold = {r.rid: _reference_stream(model, params, r, 64) for r in reqs}
    engine = ServingEngine(model, params, max_batch=3, page_size=4,
                           max_seq=64, spec_k=spec_k)
    finished = engine.run([(i, r) for i, r in enumerate(reqs)])
    engine.cache.check_invariants()
    assert sorted(f.rid for f in finished) == list(range(4))
    for f in finished:
        assert f.tokens == gold[f.rid], (spec_k, f.rid)
    # the run actually speculated (drafts were proposed and scored)
    assert engine.stats["draft_tokens"] > 0


def test_spec_rollback_exercised(qwen_smoke):
    """A speculative run on looping-then-diverging output must hit both
    accepted drafts and rollbacks while staying token-exact."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(103)
    req = Request(rid=0, prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
                  max_new_tokens=40)
    gold = _reference_stream(model, params, req, 64)
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           max_seq=64, spec_k=4)
    [fin] = engine.run([(0, req)])
    engine.cache.check_invariants()
    assert fin.tokens == gold
    assert engine.stats["rollbacks"] > 0, "no rejected draft ever rolled back"


# --------------------------------------- (b) batch-composition invariance
def test_seeded_sampling_batch_composition_invariant(qwen_smoke):
    """A sampled request emits the same tokens solo, with 7 neighbors,
    and under speculation: keys depend on (seed, position) only."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(107)
    probe = Request(rid=0, prompt=rng.integers(1, cfg.vocab_size, 5).tolist(),
                    max_new_tokens=10,
                    sampling=SamplingParams(temperature=0.9, top_k=16,
                                            top_p=0.9, seed=1234))

    def run(neighbors, spec_k):
        engine = ServingEngine(model, params, max_batch=8, page_size=4,
                               max_seq=48, spec_k=spec_k)
        arrivals = [(0, probe)]
        for j in range(neighbors):
            arrivals.append((0, Request(
                rid=j + 1,
                prompt=rng.integers(1, cfg.vocab_size, 4 + j % 3).tolist(),
                max_new_tokens=8 + j % 4,
                sampling=SamplingParams(temperature=0.7, seed=77 + j))))
        finished = engine.run(arrivals)
        engine.cache.check_invariants()
        return next(f.tokens for f in finished if f.rid == 0)

    solo = run(0, 0)
    assert solo == _reference_stream(model, params, probe, 48)
    assert run(7, 0) == solo, "neighbors perturbed a seeded stream"
    assert run(7, 4) == solo, "speculation perturbed a seeded stream"


def test_sampled_engine_matches_reference_loop(qwen_smoke):
    """Engine-sampled output (with penalty + filters active) equals the
    sequential dense-loop oracle token for token."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(109)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
                    max_new_tokens=8,
                    sampling=SamplingParams(temperature=0.8, top_k=32,
                                            top_p=0.95,
                                            repetition_penalty=1.3,
                                            seed=i * 11 + 3))
            for i in range(3)]
    gold = {r.rid: _reference_stream(model, params, r, 48) for r in reqs}
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           max_seq=48)
    finished = engine.run([(i, r) for i, r in enumerate(reqs)])
    for f in finished:
        assert f.tokens == gold[f.rid], f.rid


# ------------------------------------------------- (c) numpy oracles
def _np_top_k(logits, k):
    """Numpy oracle: keep values >= the k-th largest (ties kept)."""
    out = logits.copy()
    for i, row in enumerate(logits):
        kk = row.size if k[i] <= 0 else min(k[i], row.size)
        kth = np.sort(row)[::-1][kk - 1]
        out[i] = np.where(row >= kth, row, S.NEG_INF)
    return out


def _np_top_p(logits, p):
    """Numpy oracle: smallest sorted prefix whose mass reaches p."""
    out = np.full_like(logits, S.NEG_INF)
    for i, row in enumerate(logits):
        order = np.argsort(-row, kind="stable")
        probs = np.exp(row[order] - row[order].max())
        probs /= probs.sum()
        csum = np.cumsum(probs)
        n_keep = 1 + int(np.sum(csum < p[i]))
        # drop any token the p-mass prefix already excludes
        n_keep = min(n_keep, row.size)
        out[i, order[:n_keep]] = row[order[:n_keep]]
    return out


def test_top_k_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    logits = rng.standard_normal((8, 37)).astype(np.float32) * 3
    k = np.array([0, 1, 2, 5, 17, 36, 37, 400], np.int32)
    got = np.asarray(S.apply_top_k(jnp.asarray(logits), jnp.asarray(k)))
    want = _np_top_k(logits, k)
    np.testing.assert_allclose(got, want)
    # mass check: exactly k survivors (no ties in continuous random data)
    for i, kk in enumerate([37, 1, 2, 5, 17, 36, 37, 37]):
        assert int(np.sum(got[i] > S.NEG_INF)) == kk


def test_top_p_mass_truncation_matches_numpy_oracle():
    rng = np.random.default_rng(1)
    logits = rng.standard_normal((6, 41)).astype(np.float32) * 2
    p = np.array([0.1, 0.3, 0.5, 0.9, 0.999, 1.0], np.float32)
    got = np.asarray(S.apply_top_p(jnp.asarray(logits), jnp.asarray(p)))
    want = _np_top_p(logits, p)
    np.testing.assert_allclose(got, want, atol=1e-6)
    for i in range(len(p)):
        keep = got[i] > S.NEG_INF
        probs = np.exp(logits[i] - logits[i].max())
        probs /= probs.sum()
        kept_mass = probs[keep].sum()
        # kept mass reaches p, and is minimal: dropping the smallest
        # kept token must fall below p
        assert kept_mass >= min(p[i], 1.0) - 1e-6
        if keep.sum() > 1:
            smallest = np.argmin(np.where(keep, probs, np.inf))
            assert kept_mass - probs[smallest] < p[i]
    # top-1 token always survives even at tiny p
    assert got[0].max() > S.NEG_INF


def test_repetition_penalty_matches_numpy_oracle():
    rng = np.random.default_rng(2)
    logits = rng.standard_normal((4, 19)).astype(np.float32)
    presence = rng.random((4, 19)) < 0.4
    pen = np.array([1.0, 1.2, 2.0, 0.8], np.float32)
    got = np.asarray(S.apply_repetition_penalty(
        jnp.asarray(logits), jnp.asarray(presence), jnp.asarray(pen)))
    want = logits.copy()
    for i in range(4):
        for v in range(19):
            if presence[i, v]:
                want[i, v] = (logits[i, v] / pen[i] if logits[i, v] > 0
                              else logits[i, v] * pen[i])
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_greedy_ignores_filters_and_matches_argmax():
    """temperature == 0 returns the penalized argmax regardless of
    top-k/top-p settings."""
    rng = np.random.default_rng(3)
    logits = rng.standard_normal((5, 23)).astype(np.float32)
    n = len(logits)
    zeros = jnp.zeros((n,), jnp.int32)
    toks = np.asarray(S.sample_tokens(
        jnp.asarray(logits), jnp.zeros((n, 23), bool), zeros, zeros,
        jnp.zeros((n,), jnp.float32), jnp.full((n,), 1, jnp.int32),
        jnp.full((n,), 0.01, jnp.float32), jnp.ones((n,), jnp.float32)))
    np.testing.assert_array_equal(toks, logits.argmax(-1))


def test_sample_key_is_position_and_seed_only():
    """The same (seed, position, logits) row samples the same token in
    any batch slot and batch size - the batch-invariance primitive."""
    rng = np.random.default_rng(4)
    row = rng.standard_normal((1, 101)).astype(np.float32)

    def draw(batch_rows, idx):
        n = len(batch_rows)
        return int(np.asarray(S.sample_tokens(
            jnp.asarray(np.stack(batch_rows)), jnp.zeros((n, 101), bool),
            jnp.full((n,), 42, jnp.int32), jnp.full((n,), 7, jnp.int32),
            jnp.full((n,), 0.9, jnp.float32), jnp.zeros((n,), jnp.int32),
            jnp.ones((n,), jnp.float32), jnp.ones((n,), jnp.float32)))[idx])

    other = [rng.standard_normal(101).astype(np.float32) for _ in range(7)]
    solo = draw([row[0]], 0)
    assert draw([row[0]] + other, 0) == solo
    assert draw(other[:3] + [row[0]] + other[3:], 3) == solo


def test_step_presence_accumulates_draft_inputs():
    """Position i's context = base | inputs 1..i (carry token excluded:
    it is already part of the base presence)."""
    base = np.zeros((1, 10), bool)
    base[0, 9] = True
    tokens = np.array([[3, 5, 5, 2]], np.int32)
    got = np.asarray(S.step_presence(jnp.asarray(base),
                                     jnp.asarray(tokens)))
    want = np.zeros((4, 10), bool)
    for i in range(4):
        want[i, 9] = True
        for j in range(1, i + 1):
            want[i, tokens[0, j]] = True
    np.testing.assert_array_equal(got[0], want)


# ------------------------------------------------------- spec proposer
def test_propose_draft_prompt_lookup():
    # trailing 3-gram (7, 8, 9) re-occurs: propose what followed it
    toks = [1, 7, 8, 9, 4, 5, 6, 7, 8, 9]
    assert propose_draft(toks, 3) == [4, 5, 6]
    # most recent occurrence wins
    toks = [7, 8, 1, 5, 7, 8, 2, 6, 7, 8]
    assert propose_draft(toks, 2) == [2, 6]
    # constant run: periodic extension proposes the run continuing for
    # the full k, not just the tokens left in history
    assert propose_draft([3, 3, 3], 4) == [3, 3, 3, 3]
    assert propose_draft([3, 3, 3, 3, 3], 4) == [3, 3, 3, 3]
    # 2-cycle: periodic extension unrolls the cycle
    assert propose_draft([4, 9, 4, 9], 4) == [4, 9, 4, 9]
    # no history match
    assert propose_draft([1, 2, 3, 4], 4) == []
    # k = 0 / degenerate history
    assert propose_draft([1, 2, 1, 2], 0) == []
    assert propose_draft([], 4) == []
    assert propose_draft([5], 4) == []


# ------------------------------------------- (d) draft-quality autotune
def test_spec_auto_token_exact_and_stats(qwen_smoke):
    """spec_k="auto": the engine tunes its per-step draft depth from
    the accept-rate EMA.  The stream stays token-exact (speculation is
    lossless at every depth), the EMA/spec_k_last stats populate, and
    every finished request reports its lifetime accept_rate in [0, 1].
    Deterministic: greedy decode, fixed prompts."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(113)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(4, 10))).tolist(),
                    max_new_tokens=12)
            for i in range(3)]
    gold = {r.rid: _reference_stream(model, params, r, 64) for r in reqs}
    engine = ServingEngine(model, params, max_batch=3, page_size=4,
                           max_seq=64, spec_k="auto")
    assert engine.auto_spec and engine.spec_k == engine.AUTO_SPEC_KMAX
    finished = engine.run([(i, r) for i, r in enumerate(reqs)])
    engine.cache.check_invariants()
    for f in finished:
        assert f.tokens == gold[f.rid], f.rid
        assert f.accept_rate is not None and 0.0 <= f.accept_rate <= 1.0
    assert engine.stats["draft_tokens"] > 0
    assert 0.0 < engine.stats["accept_rate_ema"] <= 1.0
    assert 1 <= engine.stats["spec_k_last"] <= engine.AUTO_SPEC_KMAX


def test_spec_auto_depth_tracks_accept_rate(qwen_smoke):
    """The depth schedule is a pure function of the EMA:
    k = clamp(round(ema * (kmax + 1)), 1, kmax).  Pin it at the
    boundary EMAs by priming the stat before a single step."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(127)
    prompt = rng.integers(1, cfg.vocab_size, 6).tolist()
    for ema, want in ((0.0, 1), (0.6, 3), (1.0, 4)):
        engine = ServingEngine(model, params, max_batch=2, page_size=4,
                               max_seq=48, spec_k="auto")
        engine.stats["accept_rate_ema"] = ema
        engine.submit(Request(rid=0, prompt=list(prompt),
                              max_new_tokens=4))
        engine.step()               # prefill
        engine.step()               # first auto-depth decode step
        assert engine.stats["spec_k_last"] == want, \
            (ema, engine.stats["spec_k_last"])


def test_spec_accept_rate_none_without_drafts(qwen_smoke):
    """spec_k=0 never drafts: accept_rate must be None (never NaN) and
    the EMA stays at its 0.0 init."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(131)
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           max_seq=48)
    [f] = engine.run([(0, Request(
        rid=0, prompt=rng.integers(1, cfg.vocab_size, 5).tolist(),
        max_new_tokens=5))])
    assert f.accept_rate is None
    assert engine.stats["accept_rate_ema"] == 0.0
    assert engine.stats["spec_k_last"] == 0
    with pytest.raises(ValueError, match="spec_k"):
        ServingEngine(model, params, max_batch=2, page_size=4,
                      max_seq=48, spec_k="fast")
