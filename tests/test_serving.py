"""Paged KV-cache + continuous-batching serving subsystem tests.

Covers, per the subsystem spec:
  * paged_decode Pallas kernel (interpret mode) vs the dense decode
    kernel / exact reference, float and HFA datapaths;
  * page scatter/gather ops;
  * PagedKVCache alloc/free/reuse invariants (randomized trace);
  * Scheduler admission/preemption/retirement (randomized trace, no jax);
  * model-level paged vs dense logits parity and engine-level greedy
    token parity under churn + preemption.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import decode, ops
from repro.kernels import paged_decode as paged
from repro.serving import PagedKVCache, Request, Scheduler, ServingEngine


def _rand(shape, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _paged_setup(seed, *, b=3, hkv=2, g=4, d=32, page=16, pages_each=4,
                 extra_pages=3, dtype=jnp.float32):
    """Random pools + a shuffled page table + ragged per-seq lengths."""
    rng = np.random.default_rng(seed)
    num_pages = b * pages_each + extra_pages
    q = _rand((b, hkv, g, d), seed + 1, dtype)
    k_pages = _rand((num_pages, page, hkv, d), seed + 2, dtype)
    v_pages = _rand((num_pages, page, hkv, d), seed + 3, dtype)
    perm = rng.permutation(num_pages)[:b * pages_each]
    page_table = jnp.asarray(perm.reshape(b, pages_each).astype(np.int32))
    kv_lens = jnp.asarray(
        rng.integers(1, pages_each * page + 1, b).astype(np.int32))
    return q, k_pages, v_pages, page_table, kv_lens


def _dense_view(k_pages, page_table):
    return np.asarray(paged.gather_pages(k_pages, page_table))


# ------------------------------------------------------------- kernel
@pytest.mark.parametrize("seed", [0, 1])
def test_paged_kernel_matches_dense_kernel_float(seed):
    """Same KV through the paged kernel (page gather) and the dense
    kernel (contiguous) must agree to float roundoff."""
    q, kp, vp, pt, kvl = _paged_setup(seed)
    o, m, l = paged.paged_decode_partial_pallas(q, kp, vp, pt, kvl,
                                                interpret=True)
    out = np.asarray(decode.finalize_decode(o, l))
    k_dense = paged.gather_pages(kp, pt)
    v_dense = paged.gather_pages(vp, pt)
    for i in range(q.shape[0]):     # dense kernel takes one kv_len at a time
        od, md, ld = decode.decode_partial_pallas(
            q[i], jnp.swapaxes(k_dense[i], 0, 1),
            jnp.swapaxes(v_dense[i], 0, 1),
            block_kv=16, kv_len=int(kvl[i]))
        gold = np.asarray(decode.finalize_decode(od, ld))
        np.testing.assert_allclose(out[i], gold, atol=1e-5)


@pytest.mark.parametrize("seed", [2, 3])
def test_paged_kernel_hfa_error_envelope(seed):
    """HFA paged decode carries the same quantization-error envelope as
    the dense HFA decode kernel (vs the exact float reference)."""
    q, kp, vp, pt, kvl = _paged_setup(seed)
    o, m, l = paged.paged_decode_partial_pallas(q, kp, vp, pt, kvl,
                                                use_hfa=True,
                                                interpret=True)
    out = np.asarray(decode.finalize_decode(o, l, use_hfa=True))
    k_dense = paged.gather_pages(kp, pt)
    v_dense = paged.gather_pages(vp, pt)
    for i in range(q.shape[0]):
        kvl_i = int(kvl[i])
        ki = k_dense[i, :kvl_i]
        vi = v_dense[i, :kvl_i]
        s = np.asarray(jnp.einsum("hgd,shd->hgs", q[i], ki)) / np.sqrt(
            q.shape[-1])
        p = np.exp(s - s.max(-1, keepdims=True))
        gold = np.einsum("hgs,shd->hgd", p / p.sum(-1, keepdims=True),
                         np.asarray(vi))
        od, md, ld = decode.decode_partial_pallas(
            q[i], jnp.swapaxes(k_dense[i], 0, 1),
            jnp.swapaxes(v_dense[i], 0, 1),
            block_kv=16, kv_len=kvl_i, use_hfa=True)
        dense_hfa = np.asarray(decode.finalize_decode(od, ld, use_hfa=True))
        err_paged = np.abs(out[i] - gold).max()
        err_dense = np.abs(dense_hfa - gold).max()
        # same envelope as the dense HFA decode kernel: the paged walk
        # must not amplify the PWL/FIX16 quantization error
        assert err_paged <= max(2.0 * err_dense, 1e-3), \
            (err_paged, err_dense)
        assert err_paged < 2e-1     # absolute sanity cap


def test_paged_kernel_free_slot_zero():
    q, kp, vp, pt, kvl = _paged_setup(7)
    kvl = kvl.at[1].set(0)
    o, m, l = paged.paged_decode_partial_pallas(q, kp, vp, pt, kvl,
                                                interpret=True)
    out = np.asarray(decode.finalize_decode(o, l))
    assert np.all(out[1] == 0.0)
    assert np.all(np.asarray(l)[1] == 0.0)


@pytest.mark.parametrize("use_hfa", [False, True])
def test_ops_paged_jnp_matches_pallas(use_hfa):
    """The jnp gather path (CPU serving) == the Pallas kernel path."""
    q, kp, vp, pt, kvl = _paged_setup(11)
    b, hkv, g, d = q.shape
    q4 = q.reshape(b, 1, hkv * g, d)
    impl = "hfa_pallas" if use_hfa else "fa2_pallas"
    a = np.asarray(ops.paged_decode_attention(q4, kp, vp, pt, kvl,
                                              impl=impl, force_pallas=True))
    jj = np.asarray(ops.paged_decode_attention(q4, kp, vp, pt, kvl,
                                               impl=impl))
    tol = 2e-2 if use_hfa else 1e-5
    np.testing.assert_allclose(a, jj, atol=tol)


def test_ops_paged_matches_dense_decode():
    """ops.paged_decode_attention == ops.decode_attention on the same KV."""
    q, kp, vp, pt, kvl = _paged_setup(13)
    b, hkv, g, d = q.shape
    q4 = q.reshape(b, 1, hkv * g, d)
    out = np.asarray(ops.paged_decode_attention(q4, kp, vp, pt, kvl,
                                                impl="fa2"))
    k_dense = paged.gather_pages(kp, pt)
    v_dense = paged.gather_pages(vp, pt)
    for i in range(b):
        gold = np.asarray(ops.decode_attention(
            q4[i:i + 1], k_dense[i:i + 1], v_dense[i:i + 1], impl="fa2",
            kv_len=int(kvl[i])))
        np.testing.assert_allclose(out[i], gold[0], atol=1e-5)


# ------------------------------------------------------ page cache ops
def test_append_and_prefill_write_roundtrip():
    page, hkv, d = 8, 2, 16
    kp = jnp.zeros((6, page, hkv, d))
    vp = jnp.zeros((6, page, hkv, d))
    pt = jnp.asarray(np.array([[4, 1, 3], [5, 0, 2]], np.int32))
    k_new = _rand((2, 11, hkv, d), 21)
    v_new = _rand((2, 11, hkv, d), 22)
    kp, vp = paged.write_prefill_kv(kp, vp, k_new, v_new, pt)
    got = _dense_view(kp, pt)
    np.testing.assert_allclose(got[:, :11], np.asarray(k_new))
    assert np.all(got[:, 11:] == 0.0)

    # append one token per row at position 11
    k1 = _rand((2, 1, hkv, d), 23)
    v1 = _rand((2, 1, hkv, d), 24)
    sl = jnp.asarray(np.array([11, 11], np.int32))
    kp2, vp2 = paged.append_kv(kp, vp, k1, v1, pt, sl)
    got = _dense_view(kp2, pt)
    np.testing.assert_allclose(got[:, 11], np.asarray(k1[:, 0]))
    np.testing.assert_allclose(got[:, :11], np.asarray(k_new))

    # free slot (seq_len 0): write must be dropped entirely
    sl0 = jnp.asarray(np.array([0, 12], np.int32))
    kp3, _ = paged.append_kv(kp2, vp2, k1, v1, pt, sl0)
    np.testing.assert_allclose(_dense_view(kp3, pt)[0],
                               _dense_view(kp2, pt)[0])


# ------------------------------------------------- host page bookkeeping
def test_paged_cache_alloc_free_reuse():
    c = PagedKVCache(num_pages=8, page_size=4, max_batch=3, pages_per_seq=4)
    s0 = c.alloc_slot(5)            # 2 pages
    s1 = c.alloc_slot(9)            # 3 pages
    c.check_invariants()
    assert c.free_page_count == 3
    assert not c.can_admit(16)      # would need 4 pages, only 3 free
    assert c.can_admit(12)
    with pytest.raises(RuntimeError):
        c.alloc_slot(16)
    # growth across a page boundary
    assert c.ensure_append_capacity(s0)     # pos 5 fits page 2
    c.advance(s0)
    for _ in range(2):
        assert c.ensure_append_capacity(s0)
        c.advance(s0)
    assert int(c.seq_lens[s0]) == 8
    assert c.ensure_append_capacity(s0)     # pos 8 -> needs page 3
    c.check_invariants()
    # exhaustion: grow s1 until the pool dries up
    grown = 0
    while c.ensure_append_capacity(s1):
        c.advance(s1)
        grown += 1
        if grown > 64:
            raise AssertionError("never exhausted")
    c.check_invariants()
    # free recycles everything
    c.free_slot(s0)
    c.free_slot(s1)
    c.check_invariants()
    assert c.free_page_count == 8 and c.free_slot_count == 3
    assert np.all(c.page_table == 0) and np.all(c.seq_lens == 0)


def test_paged_cache_randomized_trace():
    rng = np.random.default_rng(0)
    c = PagedKVCache(num_pages=24, page_size=4, max_batch=6,
                     pages_per_seq=6)
    live: list[int] = []
    for _ in range(400):
        op = rng.random()
        if op < 0.35 and c.free_slot_count:
            plen = int(rng.integers(1, 17))
            if c.can_admit(plen):
                live.append(c.alloc_slot(plen))
        elif op < 0.75 and live:
            slot = live[rng.integers(len(live))]
            if c.ensure_append_capacity(slot):
                c.advance(slot)
        elif live:
            live.remove(slot := live[rng.integers(len(live))])
            c.free_slot(slot)
        c.check_invariants()


def test_scheduler_randomized_trace():
    """Admission/preemption/retirement over a random request stream,
    driven without any model - pure host logic."""
    rng = np.random.default_rng(1)
    cache = PagedKVCache(num_pages=10, page_size=4, max_batch=3,
                         pages_per_seq=5)
    sched = Scheduler(cache)
    n_req = 25
    for i in range(n_req):
        sched.submit(Request(rid=i, prompt=[1] * int(rng.integers(1, 9)),
                             max_new_tokens=int(rng.integers(1, 8)),
                             eos_id=7))
    finished = []
    for step in range(500):
        if not sched.has_work:
            break
        for slot, tokens in sched.admit():
            st = sched.record_token(slot, int(rng.integers(0, 9)))
            if st != "running":
                finished.append(sched.retire(slot, st))
        for slot in sorted(sched.running):
            if not cache.ensure_append_capacity(slot):
                sched.preempt(slot)
        for slot in sorted(sched.running):
            cache.advance(slot)
            st = sched.record_token(slot, int(rng.integers(0, 9)))
            if st != "running":
                finished.append(sched.retire(slot, st))
        cache.check_invariants()
    assert sorted(f.rid for f in finished) == list(range(n_req))
    for f in finished:
        assert f.reason in ("eos", "length")
        if f.reason == "eos":
            assert f.tokens[-1] == 7
        else:
            assert len(f.tokens) >= 1
    cache.check_invariants()


# ------------------------------------------------------- model + engine
@pytest.fixture(scope="module")
def qwen_smoke():
    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("attn_impl", ["fa2", "hfa"])
def test_model_paged_matches_dense_logits(qwen_smoke, attn_impl):
    """paged prefill+decode logits == dense prefill+decode logits."""
    import dataclasses
    cfg, model, params = qwen_smoke
    if attn_impl != cfg.attn_impl:
        from repro.models.model import build_model
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
        model = build_model(cfg)
    rng = np.random.default_rng(3)
    b, l = 2, 7
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, l)), jnp.int32)
    nxt = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, 1)), jnp.int32)

    cache = model.init_cache(params, b, 32)
    lg_d, cache = model.prefill(params, cache, toks)
    lg_d2, _ = model.decode_step(params, cache, nxt)

    layers = model.init_paged_cache(num_pages=8, page_size=4)
    pt = jnp.asarray(np.array([[3, 5, 1], [2, 6, 0]], np.int32))
    lg_p, layers = model.paged_prefill(params, layers, toks, pt)
    sl = jnp.full((b,), l, jnp.int32)
    lg_p2, _ = model.paged_decode_step(params, layers, nxt, pt, sl)

    tol = 1e-4 if attn_impl == "hfa" else 1e-5
    np.testing.assert_allclose(np.asarray(lg_p[:, -1:]), np.asarray(lg_d),
                               atol=tol)
    np.testing.assert_allclose(np.asarray(lg_p2), np.asarray(lg_d2),
                               atol=tol)


def test_engine_matches_dense_generation_under_churn(qwen_smoke):
    """Greedy tokens from the continuous-batching engine == a dense
    fixed-cache loop per request, across churn and preemptions."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(5)
    engine = ServingEngine(model, params, max_batch=3, page_size=4,
                           num_pages=9, max_seq=40)
    reqs = []
    for i in range(6):
        plen = int(rng.integers(2, 9))
        reqs.append(Request(rid=i,
                            prompt=rng.integers(
                                1, cfg.vocab_size, plen).tolist(),
                            max_new_tokens=int(rng.integers(3, 9))))
    finished = engine.run([(i, r) for i, r in enumerate(reqs)])
    engine.cache.check_invariants()
    assert engine.cache.free_page_count == engine.cache.num_pages
    assert sorted(f.rid for f in finished) == list(range(6))

    dec = jax.jit(model.decode_step)
    pre = jax.jit(model.prefill)
    for f in finished:
        req = reqs[f.rid]
        cache = model.init_cache(params, 1, 40)
        lg, cache = pre(params, cache,
                        jnp.asarray([req.prompt], jnp.int32))
        want = [int(jnp.argmax(lg[0, -1]))]
        for _ in range(req.max_new_tokens - 1):
            lg, cache = dec(params, cache,
                            jnp.asarray([[want[-1]]], jnp.int32))
            want.append(int(jnp.argmax(lg[0, -1])))
        assert f.tokens == want, (f.rid, f.preemptions)


def test_paged_prefill_single_token_prompt(qwen_smoke):
    """A 1-token prompt is a PREFILL (even though S == 1): its KV must
    land in the pages and its logits must match the dense path."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(9)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 1)), jnp.int32)

    cache = model.init_cache(params, 1, 8)
    lg_d, cache = model.prefill(params, cache, toks)
    nxt = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 1)), jnp.int32)
    lg_d2, _ = model.decode_step(params, cache, nxt)

    layers = model.init_paged_cache(num_pages=4, page_size=1)
    pt = jnp.asarray(np.array([[2, 1, 3]], np.int32))
    lg_p, layers = model.paged_prefill(params, layers, toks, pt)
    assert float(jnp.abs(layers["l0"]["k_pages"]).sum()) > 0.0, \
        "prefill KV never written to the pages"
    lg_p2, _ = model.paged_decode_step(params, layers, nxt, pt,
                                       jnp.asarray([1], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_p[:, -1:]), np.asarray(lg_d),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(lg_p2), np.asarray(lg_d2),
                               atol=1e-5)


def test_engine_page_boundary_prompt(qwen_smoke):
    """Prompt length == a page multiple: the first decode append needs a
    fresh page; generation must still match the dense loop."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(11)
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           max_seq=24)
    prompt = rng.integers(1, cfg.vocab_size, 8).tolist()   # 2 full pages
    [fin] = engine.run([(0, Request(rid=0, prompt=prompt,
                                    max_new_tokens=5))])
    cache = model.init_cache(params, 1, 24)
    lg, cache = model.prefill(params, cache,
                              jnp.asarray([prompt], jnp.int32))
    want = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(4):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[want[-1]]], jnp.int32))
        want.append(int(jnp.argmax(lg[0, -1])))
    assert fin.tokens == want


def test_engine_rejects_oversized_request(qwen_smoke):
    _, model, params = qwen_smoke
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           max_seq=16)
    with pytest.raises(ValueError):
        engine.submit(Request(rid=0, prompt=[1] * 10, max_new_tokens=10))
