"""Paged KV-cache + continuous-batching serving subsystem tests.

Covers, per the subsystem spec:
  * paged_decode Pallas kernel (interpret mode) vs the dense decode
    kernel / exact reference, float and HFA datapaths;
  * page scatter/gather ops;
  * PagedKVCache alloc/free/reuse invariants (randomized trace);
  * Scheduler admission/preemption/retirement (randomized trace, no jax);
  * model-level paged vs dense logits parity and engine-level greedy
    token parity under churn + preemption.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import decode, ops
from repro.kernels import paged_decode as paged
from repro.kernels import paged_prefill as paged_pf
from repro.serving import PagedKVCache, Request, Scheduler, ServingEngine


def _rand(shape, seed, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype)


def _paged_setup(seed, *, b=3, hkv=2, g=4, d=32, page=16, pages_each=4,
                 extra_pages=3, dtype=jnp.float32):
    """Random pools + a shuffled page table + ragged per-seq lengths."""
    rng = np.random.default_rng(seed)
    num_pages = b * pages_each + extra_pages
    q = _rand((b, hkv, g, d), seed + 1, dtype)
    k_pages = _rand((num_pages, page, hkv, d), seed + 2, dtype)
    v_pages = _rand((num_pages, page, hkv, d), seed + 3, dtype)
    perm = rng.permutation(num_pages)[:b * pages_each]
    page_table = jnp.asarray(perm.reshape(b, pages_each).astype(np.int32))
    kv_lens = jnp.asarray(
        rng.integers(1, pages_each * page + 1, b).astype(np.int32))
    return q, k_pages, v_pages, page_table, kv_lens


def _dense_view(k_pages, page_table):
    return np.asarray(paged.gather_pages(k_pages, page_table))


# ------------------------------------------------------------- kernel
@pytest.mark.parametrize("seed", [0, 1])
def test_paged_kernel_matches_dense_kernel_float(seed):
    """Same KV through the paged kernel (page gather) and the dense
    kernel (contiguous) must agree to float roundoff."""
    q, kp, vp, pt, kvl = _paged_setup(seed)
    o, m, l = paged.paged_decode_partial_pallas(q, kp, vp, pt, kvl,
                                                interpret=True)
    out = np.asarray(decode.finalize_decode(o, l))
    k_dense = paged.gather_pages(kp, pt)
    v_dense = paged.gather_pages(vp, pt)
    for i in range(q.shape[0]):     # dense kernel takes one kv_len at a time
        od, md, ld = decode.decode_partial_pallas(
            q[i], jnp.swapaxes(k_dense[i], 0, 1),
            jnp.swapaxes(v_dense[i], 0, 1),
            block_kv=16, kv_len=int(kvl[i]))
        gold = np.asarray(decode.finalize_decode(od, ld))
        np.testing.assert_allclose(out[i], gold, atol=1e-5)


@pytest.mark.parametrize("seed", [2, 3])
def test_paged_kernel_hfa_error_envelope(seed):
    """HFA paged decode carries the same quantization-error envelope as
    the dense HFA decode kernel (vs the exact float reference)."""
    q, kp, vp, pt, kvl = _paged_setup(seed)
    o, m, l = paged.paged_decode_partial_pallas(q, kp, vp, pt, kvl,
                                                use_hfa=True,
                                                interpret=True)
    out = np.asarray(decode.finalize_decode(o, l, use_hfa=True))
    k_dense = paged.gather_pages(kp, pt)
    v_dense = paged.gather_pages(vp, pt)
    for i in range(q.shape[0]):
        kvl_i = int(kvl[i])
        ki = k_dense[i, :kvl_i]
        vi = v_dense[i, :kvl_i]
        s = np.asarray(jnp.einsum("hgd,shd->hgs", q[i], ki)) / np.sqrt(
            q.shape[-1])
        p = np.exp(s - s.max(-1, keepdims=True))
        gold = np.einsum("hgs,shd->hgd", p / p.sum(-1, keepdims=True),
                         np.asarray(vi))
        od, md, ld = decode.decode_partial_pallas(
            q[i], jnp.swapaxes(k_dense[i], 0, 1),
            jnp.swapaxes(v_dense[i], 0, 1),
            block_kv=16, kv_len=kvl_i, use_hfa=True)
        dense_hfa = np.asarray(decode.finalize_decode(od, ld, use_hfa=True))
        err_paged = np.abs(out[i] - gold).max()
        err_dense = np.abs(dense_hfa - gold).max()
        # same envelope as the dense HFA decode kernel: the paged walk
        # must not amplify the PWL/FIX16 quantization error
        assert err_paged <= max(2.0 * err_dense, 1e-3), \
            (err_paged, err_dense)
        assert err_paged < 2e-1     # absolute sanity cap


def test_paged_kernel_free_slot_zero():
    q, kp, vp, pt, kvl = _paged_setup(7)
    kvl = kvl.at[1].set(0)
    o, m, l = paged.paged_decode_partial_pallas(q, kp, vp, pt, kvl,
                                                interpret=True)
    out = np.asarray(decode.finalize_decode(o, l))
    assert np.all(out[1] == 0.0)
    assert np.all(np.asarray(l)[1] == 0.0)


@pytest.mark.parametrize("use_hfa", [False, True])
def test_ops_paged_jnp_matches_pallas(use_hfa):
    """The jnp gather path (CPU serving) == the Pallas kernel path."""
    q, kp, vp, pt, kvl = _paged_setup(11)
    b, hkv, g, d = q.shape
    q4 = q.reshape(b, 1, hkv * g, d)
    impl = "hfa_pallas" if use_hfa else "fa2_pallas"
    a = np.asarray(ops.paged_decode_attention(q4, kp, vp, pt, kvl,
                                              impl=impl, force_pallas=True))
    jj = np.asarray(ops.paged_decode_attention(q4, kp, vp, pt, kvl,
                                               impl=impl))
    tol = 2e-2 if use_hfa else 1e-5
    np.testing.assert_allclose(a, jj, atol=tol)


def test_ops_paged_matches_dense_decode():
    """ops.paged_decode_attention == ops.decode_attention on the same KV."""
    q, kp, vp, pt, kvl = _paged_setup(13)
    b, hkv, g, d = q.shape
    q4 = q.reshape(b, 1, hkv * g, d)
    out = np.asarray(ops.paged_decode_attention(q4, kp, vp, pt, kvl,
                                                impl="fa2"))
    k_dense = paged.gather_pages(kp, pt)
    v_dense = paged.gather_pages(vp, pt)
    for i in range(b):
        gold = np.asarray(ops.decode_attention(
            q4[i:i + 1], k_dense[i:i + 1], v_dense[i:i + 1], impl="fa2",
            kv_len=int(kvl[i])))
        np.testing.assert_allclose(out[i], gold[0], atol=1e-5)


# ------------------------------------------------------ page cache ops
def test_append_and_prefill_write_roundtrip():
    page, hkv, d = 8, 2, 16
    kp = jnp.zeros((6, page, hkv, d))
    vp = jnp.zeros((6, page, hkv, d))
    pt = jnp.asarray(np.array([[4, 1, 3], [5, 0, 2]], np.int32))
    k_new = _rand((2, 11, hkv, d), 21)
    v_new = _rand((2, 11, hkv, d), 22)
    kp, vp = paged.write_prefill_kv(kp, vp, k_new, v_new, pt)
    got = _dense_view(kp, pt)
    np.testing.assert_allclose(got[:, :11], np.asarray(k_new))
    assert np.all(got[:, 11:] == 0.0)

    # append one token per row at position 11
    k1 = _rand((2, 1, hkv, d), 23)
    v1 = _rand((2, 1, hkv, d), 24)
    sl = jnp.asarray(np.array([11, 11], np.int32))
    kp2, vp2 = paged.append_kv(kp, vp, k1, v1, pt, sl)
    got = _dense_view(kp2, pt)
    np.testing.assert_allclose(got[:, 11], np.asarray(k1[:, 0]))
    np.testing.assert_allclose(got[:, :11], np.asarray(k_new))

    # free slot (seq_len 0): write must be dropped entirely
    sl0 = jnp.asarray(np.array([0, 12], np.int32))
    kp3, _ = paged.append_kv(kp2, vp2, k1, v1, pt, sl0)
    np.testing.assert_allclose(_dense_view(kp3, pt)[0],
                               _dense_view(kp2, pt)[0])


# ------------------------------------------------- host page bookkeeping
def test_paged_cache_alloc_free_reuse():
    c = PagedKVCache(num_pages=8, page_size=4, max_batch=3, pages_per_seq=4)
    s0 = c.alloc_slot(5)            # 2 pages
    s1 = c.alloc_slot(9)            # 3 pages
    c.check_invariants()
    assert c.free_page_count == 3
    assert not c.can_admit(16)      # would need 5 pages > pages_per_seq
    assert c.can_admit(11)
    # 12 tokens exactly fill 3 pages: admission reserves the decode
    # append's page too, so with only 3 free this must be refused.
    assert not c.can_admit(12)
    with pytest.raises(RuntimeError):
        c.alloc_slot(16)
    # growth across a page boundary
    assert c.ensure_append_capacity(s0)     # pos 5 fits page 2
    c.advance(s0)
    for _ in range(2):
        assert c.ensure_append_capacity(s0)
        c.advance(s0)
    assert int(c.seq_lens[s0]) == 8
    assert c.ensure_append_capacity(s0)     # pos 8 -> needs page 3
    c.check_invariants()
    # exhaustion: grow s1 until the pool dries up
    grown = 0
    while c.ensure_append_capacity(s1):
        c.advance(s1)
        grown += 1
        if grown > 64:
            raise AssertionError("never exhausted")
    c.check_invariants()
    # free recycles everything
    c.free_slot(s0)
    c.free_slot(s1)
    c.check_invariants()
    assert c.free_page_count == 8 and c.free_slot_count == 3
    assert np.all(c.page_table == 0) and np.all(c.seq_lens == 0)


def test_paged_cache_randomized_trace():
    rng = np.random.default_rng(0)
    c = PagedKVCache(num_pages=24, page_size=4, max_batch=6,
                     pages_per_seq=6)
    live: list[int] = []
    for _ in range(400):
        op = rng.random()
        if op < 0.35 and c.free_slot_count:
            plen = int(rng.integers(1, 17))
            if c.can_admit(plen):
                live.append(c.alloc_slot(plen))
        elif op < 0.75 and live:
            slot = live[rng.integers(len(live))]
            if c.ensure_append_capacity(slot):
                c.advance(slot)
        elif live:
            live.remove(slot := live[rng.integers(len(live))])
            c.free_slot(slot)
        c.check_invariants()


def test_scheduler_randomized_trace():
    """Admission/preemption/retirement over a random request stream,
    driven without any model - pure host logic."""
    rng = np.random.default_rng(1)
    cache = PagedKVCache(num_pages=10, page_size=4, max_batch=3,
                         pages_per_seq=5)
    sched = Scheduler(cache)
    n_req = 25
    for i in range(n_req):
        sched.submit(Request(rid=i, prompt=[1] * int(rng.integers(1, 9)),
                             max_new_tokens=int(rng.integers(1, 8)),
                             eos_id=7))
    finished = []
    for step in range(500):
        if not sched.has_work:
            break
        for slot, tokens in sched.admit():
            st = sched.record_token(slot, int(rng.integers(0, 9)))
            if st != "running":
                finished.append(sched.retire(slot, st))
        for slot in sorted(sched.running):
            if not cache.ensure_append_capacity(slot):
                sched.preempt(slot)
        for slot in sorted(sched.running):
            cache.advance(slot)
            st = sched.record_token(slot, int(rng.integers(0, 9)))
            if st != "running":
                finished.append(sched.retire(slot, st))
        cache.check_invariants()
    assert sorted(f.rid for f in finished) == list(range(n_req))
    for f in finished:
        assert f.reason in ("eos", "length")
        if f.reason == "eos":
            assert f.tokens[-1] == 7
        else:
            assert len(f.tokens) >= 1
    cache.check_invariants()


# ------------------------------------------------------- model + engine
@pytest.fixture(scope="module")
def qwen_smoke():
    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("attn_impl", ["fa2", "hfa"])
def test_model_paged_matches_dense_logits(qwen_smoke, attn_impl):
    """paged prefill+decode logits == dense prefill+decode logits."""
    import dataclasses
    cfg, model, params = qwen_smoke
    if attn_impl != cfg.attn_impl:
        from repro.models.model import build_model
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
        model = build_model(cfg)
    rng = np.random.default_rng(3)
    b, l = 2, 7
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, l)), jnp.int32)
    nxt = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, 1)), jnp.int32)

    cache = model.init_cache(params, b, 32)
    lg_d, cache = model.prefill(params, cache, toks)
    lg_d2, _ = model.decode_step(params, cache, nxt)

    layers = model.init_paged_cache(num_pages=8, page_size=4)
    pt = jnp.asarray(np.array([[3, 5, 1], [2, 6, 0]], np.int32))
    lg_p, layers = model.paged_prefill(params, layers, toks, pt)
    sl = jnp.full((b,), l, jnp.int32)
    lg_p2, _ = model.paged_decode_step(params, layers, nxt, pt, sl)

    tol = 1e-4 if attn_impl == "hfa" else 1e-5
    np.testing.assert_allclose(np.asarray(lg_p[:, -1:]), np.asarray(lg_d),
                               atol=tol)
    np.testing.assert_allclose(np.asarray(lg_p2), np.asarray(lg_d2),
                               atol=tol)


def test_engine_matches_dense_generation_under_churn(qwen_smoke):
    """Greedy tokens from the continuous-batching engine == a dense
    fixed-cache loop per request, across churn and preemptions."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(5)
    engine = ServingEngine(model, params, max_batch=3, page_size=4,
                           num_pages=9, max_seq=40)
    reqs = []
    for i in range(6):
        plen = int(rng.integers(2, 9))
        reqs.append(Request(rid=i,
                            prompt=rng.integers(
                                1, cfg.vocab_size, plen).tolist(),
                            max_new_tokens=int(rng.integers(3, 9))))
    finished = engine.run([(i, r) for i, r in enumerate(reqs)])
    engine.cache.check_invariants()
    # Retired sequences' published prefix pages park in the cached LRU
    # (claimable by identical prompts); nothing is leaked outright.
    assert engine.cache.available_page_count == engine.cache.num_pages
    assert sorted(f.rid for f in finished) == list(range(6))

    dec = jax.jit(model.decode_step)
    pre = jax.jit(model.prefill)
    for f in finished:
        req = reqs[f.rid]
        cache = model.init_cache(params, 1, 40)
        lg, cache = pre(params, cache,
                        jnp.asarray([req.prompt], jnp.int32))
        want = [int(jnp.argmax(lg[0, -1]))]
        for _ in range(req.max_new_tokens - 1):
            lg, cache = dec(params, cache,
                            jnp.asarray([[want[-1]]], jnp.int32))
            want.append(int(jnp.argmax(lg[0, -1])))
        assert f.tokens == want, (f.rid, f.preemptions)


def test_paged_prefill_single_token_prompt(qwen_smoke):
    """A 1-token prompt is a PREFILL (even though S == 1): its KV must
    land in the pages and its logits must match the dense path."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(9)
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 1)), jnp.int32)

    cache = model.init_cache(params, 1, 8)
    lg_d, cache = model.prefill(params, cache, toks)
    nxt = jnp.asarray(rng.integers(1, cfg.vocab_size, (1, 1)), jnp.int32)
    lg_d2, _ = model.decode_step(params, cache, nxt)

    layers = model.init_paged_cache(num_pages=4, page_size=1)
    pt = jnp.asarray(np.array([[2, 1, 3]], np.int32))
    lg_p, layers = model.paged_prefill(params, layers, toks, pt)
    assert float(jnp.abs(layers["l0"]["k_pages"]).sum()) > 0.0, \
        "prefill KV never written to the pages"
    lg_p2, _ = model.paged_decode_step(params, layers, nxt, pt,
                                       jnp.asarray([1], jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_p[:, -1:]), np.asarray(lg_d),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(lg_p2), np.asarray(lg_d2),
                               atol=1e-5)


def test_engine_page_boundary_prompt(qwen_smoke):
    """Prompt length == a page multiple: the first decode append needs a
    fresh page; generation must still match the dense loop."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(11)
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           max_seq=24)
    prompt = rng.integers(1, cfg.vocab_size, 8).tolist()   # 2 full pages
    [fin] = engine.run([(0, Request(rid=0, prompt=prompt,
                                    max_new_tokens=5))])
    cache = model.init_cache(params, 1, 24)
    lg, cache = model.prefill(params, cache,
                              jnp.asarray([prompt], jnp.int32))
    want = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(4):
        lg, cache = model.decode_step(
            params, cache, jnp.asarray([[want[-1]]], jnp.int32))
        want.append(int(jnp.argmax(lg[0, -1])))
    assert fin.tokens == want


def test_engine_rejects_oversized_request(qwen_smoke):
    _, model, params = qwen_smoke
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           max_seq=16)
    with pytest.raises(ValueError):
        engine.submit(Request(rid=0, prompt=[1] * 10, max_new_tokens=10))


def test_engine_run_survives_oversized_request(qwen_smoke):
    """An oversized request arriving mid-trace is finished as
    reason="rejected" instead of killing the serving loop."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(17)
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           max_seq=16)
    good = lambda rid: Request(
        rid=rid, prompt=rng.integers(1, cfg.vocab_size, 4).tolist(),
        max_new_tokens=4)
    arrivals = [(0, good(0)), (1, Request(rid=1, prompt=[1] * 10,
                                          max_new_tokens=10)),
                (2, good(2))]
    finished = engine.run(arrivals)
    assert sorted(f.rid for f in finished) == [0, 1, 2]
    by_rid = {f.rid: f for f in finished}
    assert by_rid[1].reason == "rejected" and by_rid[1].tokens == []
    for rid in (0, 2):
        assert by_rid[rid].reason in ("eos", "length")
        assert len(by_rid[rid].tokens) == 4
    assert engine.stats["rejected"] == 1


# --------------------------------------------------- chunked prefill
def _golden_greedy(model, params, req, max_seq):
    """Dense fixed-cache greedy loop: the token-exactness oracle."""
    cache = model.init_cache(params, 1, max_seq)
    lg, cache = model.prefill(params, cache,
                              jnp.asarray([req.prompt], jnp.int32))
    want = [int(jnp.argmax(lg[0, -1]))]
    for _ in range(req.max_new_tokens - 1):
        lg, cache = model.decode_step(params, cache,
                                      jnp.asarray([[want[-1]]], jnp.int32))
        want.append(int(jnp.argmax(lg[0, -1])))
    return want


def _chunk_setup(seed, *, b=2, hkv=2, g=2, d=16, page=4, pages_each=6,
                 hist=(8, 5), chunk=(7, 4)):
    """Pools holding per-seq history of ``hist`` tokens, plus a written
    chunk of ``chunk`` tokens starting right after; returns the dense
    full K/V for the oracle."""
    rng = np.random.default_rng(seed)
    num_pages = b * pages_each + 2
    kp = jnp.zeros((num_pages, page, hkv, d), jnp.float32)
    vp = jnp.zeros((num_pages, page, hkv, d), jnp.float32)
    pt = jnp.asarray(rng.permutation(num_pages)[:b * pages_each]
                     .reshape(b, pages_each).astype(np.int32))
    start = np.asarray(hist, np.int32)
    cl = np.asarray(chunk, np.int32)
    total = start + cl
    lmax = int(cl.max())
    k_full = rng.standard_normal((b, int(total.max()), hkv, d)) \
        .astype(np.float32)
    v_full = rng.standard_normal((b, int(total.max()), hkv, d)) \
        .astype(np.float32)
    kp, vp = paged_pf.write_chunk_kv(
        kp, vp, jnp.asarray(k_full[:, :int(start.max())]),
        jnp.asarray(v_full[:, :int(start.max())]), pt,
        jnp.zeros((b,), jnp.int32), jnp.asarray(start))
    k_ch = np.zeros((b, lmax, hkv, d), np.float32)
    v_ch = np.zeros_like(k_ch)
    for i in range(b):
        k_ch[i, :cl[i]] = k_full[i, start[i]:total[i]]
        v_ch[i, :cl[i]] = v_full[i, start[i]:total[i]]
    kp, vp = paged_pf.write_chunk_kv(kp, vp, jnp.asarray(k_ch),
                                     jnp.asarray(v_ch), pt,
                                     jnp.asarray(start), jnp.asarray(cl))
    q = _rand((b, lmax, hkv * g, d), seed + 1)
    return q, kp, vp, pt, start, cl, k_full, v_full


def test_write_chunk_kv_is_position_exact():
    """Chunk writes land at start_pos.. and padding rows are DROPPED -
    pages outside the chunk (shared prefixes, later pages) are never
    touched, unlike the fresh-prefill padded scatter."""
    q, kp, vp, pt, start, cl, k_full, v_full = _chunk_setup(31)
    got = _dense_view(kp, pt)
    for b in range(q.shape[0]):
        total = int(start[b] + cl[b])
        np.testing.assert_allclose(got[b, :total], k_full[b, :total])
        assert np.all(got[b, total:] == 0.0), "padding row was written"


@pytest.mark.parametrize("seed", [41, 42])
def test_paged_prefill_kernel_matches_oracle(seed):
    """Chunk queries at pos start..start+L-1 attending causally over the
    paged history: Pallas kernel (interpret) == jnp gather path == per-row
    dense softmax oracle."""
    q, kp, vp, pt, start, cl, k_full, v_full = _chunk_setup(seed)
    b, lmax, h, d = q.shape
    hkv = kp.shape[2]
    g = h // hkv
    out_jnp = np.asarray(ops.paged_prefill_attention(
        q, kp, vp, pt, jnp.asarray(start), jnp.asarray(cl), impl="fa2"))
    out_pl = np.asarray(ops.paged_prefill_attention(
        q, kp, vp, pt, jnp.asarray(start), jnp.asarray(cl),
        impl="fa2_pallas", force_pallas=True))
    qn = np.asarray(q)
    for i in range(b):
        for li in range(int(cl[i])):
            pos = int(start[i]) + li
            for hh in range(h):
                hk = hh // g
                s = (qn[i, li, hh] @ k_full[i, :pos + 1, hk].T) / np.sqrt(d)
                p = np.exp(s - s.max())
                gold = (p / p.sum()) @ v_full[i, :pos + 1, hk]
                np.testing.assert_allclose(out_jnp[i, li, hh], gold,
                                           atol=1e-5)
                np.testing.assert_allclose(out_pl[i, li, hh], gold,
                                           atol=1e-5)


def test_paged_prefill_kernel_hfa_rowwise_matches_decode_kernel():
    """Each chunk row through the H-FA paged-prefill kernel is
    bit-identical to the same query through the H-FA paged-decode
    kernel (same page walk, same FIX16 datapath) - the chunk dimension
    must not perturb the quantized numerics."""
    q, kp, vp, pt, start, cl, _, _ = _chunk_setup(43)
    b, lmax, h, d = q.shape
    hkv = kp.shape[2]
    g = h // hkv
    qg = jnp.swapaxes(q, 1, 2).reshape(b, hkv, g, lmax, d)
    o, m, l = paged_pf.paged_prefill_partial_pallas(
        qg, kp, vp, pt, jnp.asarray(start), jnp.asarray(start + cl),
        use_hfa=True, interpret=True)
    out = np.asarray(decode.finalize_decode(o, l, use_hfa=True))
    for i in range(b):
        for li in range(int(cl[i])):
            pos = int(start[i]) + li
            od, md, ld = paged.paged_decode_partial_pallas(
                qg[i:i + 1, :, :, li, :], kp, vp, pt[i:i + 1],
                jnp.asarray([pos + 1], jnp.int32), use_hfa=True,
                interpret=True)
            gold = np.asarray(decode.finalize_decode(od, ld, use_hfa=True))
            np.testing.assert_array_equal(out[i, :, :, li], gold[0])


@pytest.mark.parametrize("attn_impl", ["fa2", "hfa"])
def test_model_chunked_prefill_matches_dense(qwen_smoke, attn_impl):
    """paged_prefill in two chunks (the second at pos > 0) must agree
    with the dense whole-prompt prefill: same last logits and same
    subsequent decode logits."""
    import dataclasses
    cfg, model, params = qwen_smoke
    if attn_impl != cfg.attn_impl:
        from repro.models.model import build_model
        cfg = dataclasses.replace(cfg, attn_impl=attn_impl)
        model = build_model(cfg)
    rng = np.random.default_rng(23)
    b, l, cut = 2, 7, 4
    toks = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, l)), jnp.int32)
    nxt = jnp.asarray(rng.integers(1, cfg.vocab_size, (b, 1)), jnp.int32)

    cache = model.init_cache(params, b, 32)
    lg_d, cache = model.prefill(params, cache, toks)
    lg_d2, _ = model.decode_step(params, cache, nxt)

    layers = model.init_paged_cache(num_pages=8, page_size=4)
    pt = jnp.asarray(np.array([[3, 5, 1], [2, 6, 0]], np.int32))
    zeros = jnp.zeros((b,), jnp.int32)
    _, layers = model.paged_prefill(
        params, layers, toks[:, :cut], pt,
        last_pos=jnp.full((b,), cut - 1, jnp.int32), start_pos=zeros)
    lg_p, layers = model.paged_prefill(
        params, layers, toks[:, cut:], pt,
        last_pos=jnp.full((b,), l - cut - 1, jnp.int32),
        start_pos=jnp.full((b,), cut, jnp.int32))
    sl = jnp.full((b,), l, jnp.int32)
    lg_p2, _ = model.paged_decode_step(params, layers, nxt, pt, sl)

    # fa2 paths share exact-softmax math.  The H-FA chunked path applies
    # the FIX16 quantization in a different accumulation order than the
    # dense emulation, so logits agree only within the quantization
    # envelope (amplified by wo + lm_head); greedy argmax must hold.
    tol = 5e-1 if attn_impl == "hfa" else 1e-4
    np.testing.assert_allclose(np.asarray(lg_p[:, -1:]), np.asarray(lg_d),
                               atol=tol)
    np.testing.assert_allclose(np.asarray(lg_p2), np.asarray(lg_d2),
                               atol=tol)
    assert np.array_equal(np.argmax(np.asarray(lg_p[:, -1:]), -1),
                          np.argmax(np.asarray(lg_d), -1))
    assert np.array_equal(np.argmax(np.asarray(lg_p2), -1),
                          np.argmax(np.asarray(lg_d2), -1))


def test_engine_chunked_prefill_token_exact(qwen_smoke):
    """For one arrival trace, every prefill chunk budget produces
    greedy outputs identical to the unchunked engine and to the dense
    per-request loop."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(29)
    reqs = [Request(rid=i,
                    prompt=rng.integers(1, cfg.vocab_size,
                                        int(rng.integers(2, 11))).tolist(),
                    max_new_tokens=int(rng.integers(2, 7)))
            for i in range(4)]
    gold = {r.rid: _golden_greedy(model, params, r, 48) for r in reqs}
    for budget in (None, 3, 8):
        engine = ServingEngine(model, params, max_batch=3, page_size=4,
                               max_seq=48, prefill_budget=budget)
        finished = engine.run([(i, r) for i, r in enumerate(reqs)])
        engine.cache.check_invariants()
        assert sorted(f.rid for f in finished) == list(range(4))
        for f in finished:
            assert f.tokens == gold[f.rid], (budget, f.rid, f.preemptions)


def test_decode_keeps_running_while_long_prompt_prefills(qwen_smoke):
    """A long prompt streaming in under a small chunk budget must not
    stall the running decode: every step during the multi-step prefill
    still yields a decode token."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(37)
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           max_seq=64, prefill_budget=4)
    engine.submit(Request(rid=0,
                          prompt=rng.integers(1, cfg.vocab_size, 4).tolist(),
                          max_new_tokens=20))
    engine.step()                        # rid 0 prefilled + decoding
    assert engine.sched.decoding_slots()
    engine.submit(Request(rid=1,
                          prompt=rng.integers(1, cfg.vocab_size,
                                              20).tolist(),
                          max_new_tokens=5))

    def rid1_prefilling():
        return any(st.req.rid == 1 and not st.decoding
                   for st in engine.sched.running.values()) or \
            any(st.req.rid == 1 for st in engine.sched.waiting)

    prefill_steps = 0
    engine.step()                        # rid 1 admitted, first chunk
    prefill_steps += 1
    while rid1_prefilling():
        before = engine.stats["generated_tokens"]
        engine.step()
        assert engine.stats["generated_tokens"] > before, \
            "decode stalled during chunked prefill"
        prefill_steps += 1
        assert prefill_steps < 20
    # 20 prompt tokens at 4 tokens/step: the prefill really was chunked
    # across multiple steps while rid 0 kept decoding.
    assert prefill_steps >= 5


def test_admission_reserves_decode_page_no_livelock(qwen_smoke):
    """Regression: a prompt that exactly fills the free pages used to be
    admitted, prefilled (wasted work), preempted on its first decode
    append, and re-admitted next step - quadratic replay thrash.  Now
    admission reserves the decode-append page: the infeasible request is
    never admitted (zero wasted prefills) and the stall is reported."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(43)
    prompt = rng.integers(1, cfg.vocab_size, 8).tolist()   # 2 full pages
    engine = ServingEngine(model, params, max_batch=1, page_size=4,
                           num_pages=2, max_seq=16)
    with pytest.raises(RuntimeError, match="stalled"):
        engine.run([(0, Request(rid=0, prompt=prompt, max_new_tokens=4))],
                   max_steps=50)
    assert engine.stats["prefills"] == 0, "wasted prefill before preempt"
    assert engine.stats["preemptions"] == 0

    # One page of headroom makes it feasible - and it must then complete
    # without a single preemption (the old code thrashed even here when
    # the pool later ran dry).
    engine = ServingEngine(model, params, max_batch=1, page_size=4,
                           num_pages=3, max_seq=16)
    [fin] = engine.run([(0, Request(rid=0, prompt=prompt,
                                    max_new_tokens=4))])
    assert fin.reason in ("eos", "length") and len(fin.tokens) == 4
    assert engine.stats["preemptions"] == 0


def test_preemption_evicts_least_work_victim(qwen_smoke):
    """Pool pressure must evict the sequence with the least accumulated
    work (cheapest replay), not the lowest slot id: here slot 0 holds the
    long-running sequence, so the old sorted()-first policy would evict
    it at maximal replay cost."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(47)
    long_req = Request(rid=0, prompt=rng.integers(1, cfg.vocab_size,
                                                  8).tolist(),
                       max_new_tokens=9)
    short_req = Request(rid=1, prompt=rng.integers(1, cfg.vocab_size,
                                                   4).tolist(),
                        max_new_tokens=9)
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           num_pages=5, max_seq=20, prefix_caching=False)
    finished = engine.run([(0, long_req), (1, short_req)])
    by_rid = {f.rid: f for f in finished}
    assert engine.stats["preemptions"] >= 1
    assert by_rid[0].preemptions == 0, \
        "evicted the longest-running sequence (maximal replay cost)"
    assert by_rid[1].preemptions >= 1
    gold = {r.rid: _golden_greedy(model, params, r, 20)
            for r in (long_req, short_req)}
    for f in finished:
        assert f.tokens == gold[f.rid]


def test_scheduler_choose_victim_least_work():
    """Host-level: choose_victim picks the fewest materialized KV tokens,
    breaking ties toward the newest admission."""
    cache = PagedKVCache(num_pages=16, page_size=4, max_batch=4,
                         pages_per_seq=4)
    sched = Scheduler(cache)
    for rid, plen in ((0, 9), (1, 3), (2, 5)):
        sched.submit(Request(rid=rid, prompt=[1] * plen, max_new_tokens=4))
    admitted = sched.admit()
    assert len(admitted) == 3
    slots = {sched.running[s].req.rid: s for s, _ in admitted}
    assert sched.choose_victim() == slots[1]          # 3 tokens: least work
    # equal work: the newer admission loses
    cache.seq_lens[slots[1]] = 5
    assert sched.choose_victim() == slots[2]


def test_engine_prefix_reuse_shared_system_prompt(qwen_smoke):
    """Requests sharing a system prompt must reuse its full pages (fewer
    prefill tokens computed) and still generate token-exact outputs."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(53)
    sysp = rng.integers(1, cfg.vocab_size, 12).tolist()    # 3 full pages
    reqs = [Request(rid=i,
                    prompt=sysp + rng.integers(1, cfg.vocab_size,
                                               3).tolist(),
                    max_new_tokens=4)
            for i in range(3)]
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           max_seq=48)
    finished = engine.run([(2 * i, r) for i, r in enumerate(reqs)])
    engine.cache.check_invariants()
    # first request prefills the system prompt; later ones claim it
    assert engine.stats["cached_prefill_tokens"] >= 2 * len(sysp)
    total_prompt = sum(len(r.prompt) for r in reqs)
    assert engine.stats["prefill_tokens"] <= total_prompt - 2 * len(sysp)
    for f in finished:
        assert f.tokens == _golden_greedy(model, params, reqs[f.rid], 48)


def test_cached_lru_cap_bounds_dead_prefix_pages():
    """Regression (ROADMAP follow-up): long-running multi-tenant churn
    used to park every retired prefix in the cached LRU until the
    entire free pool was dead single-use prefixes - each later
    allocation then paid an eviction + hash retraction instead of a
    free-list pop.  With ``max_cached_pages`` the LRU is bounded and
    ages out oldest-first, so strictly-free pages stay available."""
    def churn(cache, tenants):
        for i in range(tenants):
            toks = [1000 * i + t for t in range(13)]     # distinct prefix
            slot = cache.alloc_slot(len(toks))           # 4 pages
            cache.register_pages(slot, toks)             # 3 full pages
            cache.free_slot(slot)
            cache.check_invariants()

    uncapped = PagedKVCache(16, 4, 2, 4)
    churn(uncapped, 8)
    assert uncapped.free_page_count == 16 - len(uncapped._cached)
    assert len(uncapped._cached) > 8, "churn never built up dead prefixes"

    capped = PagedKVCache(16, 4, 2, 4, max_cached_pages=4)
    churn(capped, 8)
    assert len(capped._cached) <= 4
    assert capped.free_page_count >= 12, \
        "dead prefix pages still crowd out the free pool"
    # aging is LRU: the most recent tenant's prefix is still claimable,
    # the oldest ones are gone
    last = [1000 * 7 + t for t in range(13)]
    assert len(capped.lookup_prefix(last)) > 0
    assert len(capped.lookup_prefix([0, 1, 2, 3, 4, 5])) == 0
    capped.check_invariants()


def test_engine_cached_frac_plumbs_to_cache(qwen_smoke):
    cfg, model, params = qwen_smoke
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           num_pages=12, max_seq=32, cached_frac=0.25)
    assert engine.cache.max_cached_pages == 3
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           num_pages=12, max_seq=32, cached_frac=1.0)
    assert engine.cache.max_cached_pages is None


def test_paged_cache_fork_cow():
    """fork shares every page by refcount; the first append into the
    shared tail page copies it (pending device copy) and leaves the full
    prefix pages shared."""
    c = PagedKVCache(num_pages=8, page_size=4, max_batch=4, pages_per_seq=4)
    s0 = c.alloc_slot(6)                     # 2 pages, partial tail
    c.check_invariants()
    s1 = c.fork(s0)
    c.check_invariants()
    assert int(c.seq_lens[s1]) == 6
    assert c.refcount(int(c.page_table[s0, 0])) == 2
    assert c.refcount(int(c.page_table[s0, 1])) == 2
    assert not c.take_pending_copies()
    assert c.ensure_append_capacity(s1)      # append into shared tail
    copies = c.take_pending_copies()
    assert len(copies) == 1
    src, dst = copies[0]
    assert src == int(c.page_table[s0, 1]) and dst == int(
        c.page_table[s1, 1])
    assert c.page_table[s1, 0] == c.page_table[s0, 0], \
        "full prefix page must stay shared"
    assert c.refcount(src) == 1 and c.refcount(dst) == 1
    c.advance(s1)
    c.check_invariants()
    # the original owner's tail is now exclusive: no further copy
    assert c.ensure_append_capacity(s0)
    assert not c.take_pending_copies()
    c.free_slot(s0)
    c.free_slot(s1)
    c.check_invariants()
    assert c.free_page_count == 8


def test_cow_failure_never_exposes_shared_page_for_writing():
    """When copy-on-write cannot allocate (pool dry), the shrunk-chunk
    capacity must exclude the still-shared page: writing it would
    corrupt the forked sibling's KV."""
    c = PagedKVCache(num_pages=2, page_size=4, max_batch=3, pages_per_seq=2)
    s0 = c.alloc_slot(6)                     # both pages, partial tail
    s1 = c.fork(s0)                          # tail page shared, pool dry
    assert not c.ensure_append_capacity(s1), "COW without a free page?"
    assert not c.take_pending_copies()
    # allocation capacity still counts the shared page, but the
    # *writable* capacity (what a shrunk prefill chunk may use) must
    # stop before it - and stay below seq_lens, i.e. nothing writable.
    assert c.token_capacity(s1) == 8
    assert c.writable_token_capacity(s1) == 4
    with pytest.raises(AssertionError):
        c.mark_prefilled(s1, 7)              # would write the shared page
    c.check_invariants()
    c.free_slot(s0)
    # sole owner again: append now succeeds without any copy
    assert c.ensure_append_capacity(s1)
    assert not c.take_pending_copies()
    c.advance(s1)
    c.check_invariants()


def test_copy_pages_device_semantics():
    """copy_pages duplicates page contents along the chosen axis, drops
    padding entries (out-of-range dst), and leaves every other page
    untouched - including on the stacked (groups, P, page, ...) layer
    layout the engine uses (axis=1)."""
    rng = np.random.default_rng(67)
    pool = jnp.asarray(rng.standard_normal((6, 4, 2, 8)), jnp.float32)
    out = np.asarray(paged_pf.copy_pages(
        pool, jnp.asarray([2, 0], jnp.int32),
        jnp.asarray([5, 6], jnp.int32)))          # dst 6 is padding
    np.testing.assert_allclose(out[5], np.asarray(pool)[2])
    np.testing.assert_allclose(out[:5], np.asarray(pool)[:5])

    stacked = jnp.asarray(rng.standard_normal((2, 6, 4, 2, 8)), jnp.float32)
    out = np.asarray(paged_pf.copy_pages(
        stacked, jnp.asarray([1], jnp.int32), jnp.asarray([4], jnp.int32),
        axis=1))
    np.testing.assert_allclose(out[:, 4], np.asarray(stacked)[:, 1])
    np.testing.assert_allclose(out[:, :4], np.asarray(stacked)[:, :4])


def test_engine_applies_cow_copies_to_device_pools(qwen_smoke):
    """fork + divergent append end-to-end at the engine layer: the
    pending COW copy must be applied to every layer's device pools, so
    the fork's pages read back identical KV to the original."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(71)
    engine = ServingEngine(model, params, max_batch=3, page_size=4,
                           max_seq=32)
    engine.submit(Request(rid=0,
                          prompt=rng.integers(1, cfg.vocab_size, 6).tolist(),
                          max_new_tokens=8))
    engine.step()                              # 6-token KV + partial tail
    [slot] = engine.sched.decoding_slots()

    def dense_kv(s):
        pt = jnp.asarray(engine.cache.page_table[s:s + 1, :2])
        return np.asarray(paged.gather_pages(
            engine.layers["l0"]["k_pages"][0], pt))[0]

    before = dense_kv(slot)
    fork = engine.cache.fork(slot)             # tail page now shared
    assert engine.cache.ensure_append_capacity(fork)
    assert engine.cache._pending_copies       # COW queued, not yet applied
    engine._apply_pending_copies()
    assert engine.stats["cow_copies"] == 1
    n = int(engine.cache.seq_lens[slot])
    np.testing.assert_allclose(dense_kv(fork)[:n], before[:n])
    np.testing.assert_allclose(dense_kv(slot)[:n], before[:n])
    engine.cache.advance(fork)
    engine.cache.check_invariants()


def test_engine_hfa_free_slot_no_nan():
    """H-FA jnp decode over a mixed free/active batch: junk (NaN/Inf) in
    a free slot's pages must not leak NaN into any row (0 * NaN guard)."""
    rng = np.random.default_rng(61)
    b, hkv, g, d, page, J = 3, 2, 2, 16, 4, 3
    num_pages = 10
    kp = rng.standard_normal((num_pages, page, hkv, d)).astype(np.float32)
    vp = rng.standard_normal((num_pages, page, hkv, d)).astype(np.float32)
    pt = np.array([[1, 2, 3], [0, 0, 0], [4, 5, 6]], np.int32)
    kvl = np.array([5, 0, 7], np.int32)          # slot 1 is free
    q = _rand((b, 1, hkv * g, d), 62)
    clean = {impl: np.asarray(ops.paged_decode_attention(
        q, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(pt),
        jnp.asarray(kvl), impl=impl)) for impl in ("fa2", "hfa_pallas")}
    kp[0] = np.nan                                # free slot's pages rot
    vp[0] = np.inf
    for impl in ("fa2", "hfa_pallas"):
        out = np.asarray(ops.paged_decode_attention(
            q, jnp.asarray(kp), jnp.asarray(vp), jnp.asarray(pt),
            jnp.asarray(kvl), impl=impl))
        assert np.isfinite(out).all(), impl
        assert np.all(out[1] == 0.0), "free slot row must be zero"
        np.testing.assert_allclose(out[[0, 2]], clean[impl][[0, 2]],
                                   atol=1e-6)


def test_engine_logprobs_match_dense_recompute(qwen_smoke):
    """`Request(logprobs=True)`: prompt logprobs (position 0 None, the
    rest log p(prompt[t] | prompt[:t])) and per-generated-token
    logprobs from the paged engine == log_softmax over one dense
    `model.apply` forward of the full stream (teacher-forced)."""
    cfg, model, params = qwen_smoke
    engine = ServingEngine(model, params, max_batch=2, page_size=4,
                           num_pages=12, max_seq=40)
    rng = np.random.default_rng(11)
    prompt = rng.integers(1, cfg.vocab_size, 7).tolist()
    (f,) = engine.run([(0, Request(rid=0, prompt=prompt,
                                   max_new_tokens=5, logprobs=True))])
    assert f.prompt_logprobs is not None
    assert len(f.prompt_logprobs) == len(prompt)
    assert f.prompt_logprobs[0] is None
    assert all(lp is not None and lp <= 0.0
               for lp in f.prompt_logprobs[1:])
    assert len(f.token_logprobs) == len(f.tokens)

    stream = prompt + f.tokens
    lg, _ = model.apply(params, {"tokens": jnp.asarray([stream],
                                                       jnp.int32)},
                        train=False)
    lsm = np.asarray(jax.nn.log_softmax(lg[0].astype(jnp.float32), -1))
    for t in range(1, len(prompt)):
        np.testing.assert_allclose(f.prompt_logprobs[t],
                                   lsm[t - 1, prompt[t]], atol=5e-4)
    for i, tok in enumerate(f.tokens):
        np.testing.assert_allclose(f.token_logprobs[i],
                                   lsm[len(prompt) - 1 + i, tok],
                                   atol=5e-4)
