"""Conformance suite: prefill/decode disaggregated serving.

The contract (:mod:`repro.serving.disagg`): running a request's prompt
on a *prefill worker* and its generation on a *decode worker* - with
the prompt's KV pages shipped across pools through the chain-hash
manifest - must stream **token-identical** output to the same request
on a single engine.  Pinned here as a matrix:

  * decode mode: greedy x seeded-sampled x speculative x beam search;
  * attention rail: fp (fa2) x hfa (FIX16/PWL log-domain);
  * page codec: fp x int8 x log16 (quantized pages are copied raw -
    codec sidecars ride the same layer tree).

Plus the lifecycle edges: mid-handoff cancellation (abort returns
staged pages, releases export pins, both pools invariant-clean),
duplicate-prefix handoffs (staged dupes freed, pages shared), and the
staging-fallback path (decode pool too small: the request is served by
plain recompute, still token-exact).
"""
import dataclasses

import numpy as np
import pytest

import jax

from repro.serving import (DisaggPair, Request, SamplingParams,
                           ServingEngine)


@pytest.fixture(scope="module")
def qwen_smoke():
    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def qwen_hfa(qwen_smoke):
    from repro.models.model import build_model
    cfg, _, params = qwen_smoke
    cfg = dataclasses.replace(cfg, attn_impl="hfa")
    return cfg, build_model(cfg), params


def _rail(rail, qwen_smoke, qwen_hfa):
    return qwen_smoke if rail == "fp" else qwen_hfa


def _requests(cfg, mode, n=3, seed=211):
    """A small arrival trace for ``mode``; prompts long enough that at
    least one full page (page_size=4) is handed off per request."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        prompt = rng.integers(1, cfg.vocab_size,
                              int(rng.integers(6, 14))).tolist()
        mnt = int(rng.integers(4, 8))
        if mode == "sampled":
            reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=mnt,
                                sampling=SamplingParams(temperature=0.8,
                                                        top_k=16,
                                                        seed=500 + i)))
        elif mode == "beam":
            reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=mnt,
                                beam_width=2, n=2))
        else:                              # greedy / spec share requests
            reqs.append(Request(rid=i, prompt=prompt, max_new_tokens=mnt))
    return reqs


def _clone(reqs):
    """Fresh Request objects so the two runs share no mutable state."""
    return [dataclasses.replace(r, prompt=list(r.prompt)) for r in reqs]


def _result_key(fin):
    """Everything a client can observe, per rid."""
    out = {}
    for f in fin:
        comps = None if f.completions is None else \
            [(c.tokens, c.branch, c.reason) for c in f.completions]
        out[f.rid] = (f.tokens, f.reason, comps)
    return out


def _run_matrix(model, params, reqs, *, codec="fp", spec_k=0,
                pool_kw=None):
    """Single-engine gold run vs DisaggPair run on cloned requests;
    returns (gold, got, pair)."""
    kw = dict(max_batch=3, page_size=4, max_seq=64, spec_k=spec_k,
              kv_codec=codec)
    kw.update(pool_kw or {})
    arrivals = lambda rs: [(i, r) for i, r in enumerate(rs)]
    single = ServingEngine(model, params, **kw)
    gold = _result_key(single.run(arrivals(_clone(reqs))))
    pair = DisaggPair(ServingEngine(model, params, **kw),
                      ServingEngine(model, params, **kw))
    got = _result_key(pair.run(arrivals(_clone(reqs))))
    return gold, got, pair


# ------------------------------------------------- token-parity matrix
@pytest.mark.parametrize("rail", ["fp", "hfa"])
@pytest.mark.parametrize("mode", ["greedy", "sampled", "spec", "beam"])
def test_disagg_token_parity(qwen_smoke, qwen_hfa, rail, mode):
    """Prefill-on-A / decode-on-B == single engine, token for token,
    across decode modes and both attention rails."""
    cfg, model, params = _rail(rail, qwen_smoke, qwen_hfa)
    reqs = _requests(cfg, mode)
    spec_k = 2 if mode == "spec" else 0
    gold, got, pair = _run_matrix(model, params, reqs, spec_k=spec_k)
    assert got == gold, (rail, mode)
    assert pair.stats["handoffs"] == len(reqs)
    assert pair.stats["handoff_pages"] > 0, "nothing was ever handed off"
    pair.check_invariants()
    for cache in (pair.prefill.cache, pair.decode.cache):
        assert cache.available_page_count == cache.num_pages


@pytest.mark.parametrize("rail", ["fp", "hfa"])
@pytest.mark.parametrize("codec", ["int8", "log16"])
def test_disagg_token_parity_quantized_pages(qwen_smoke, qwen_hfa, rail,
                                             codec):
    """Quantized page pools hand off raw coded bytes (plus codec
    sidecars): the disaggregated stream must still equal the
    single-engine stream bit for bit."""
    cfg, model, params = _rail(rail, qwen_smoke, qwen_hfa)
    reqs = _requests(cfg, "greedy", seed=223)
    gold, got, pair = _run_matrix(model, params, reqs, codec=codec)
    assert got == gold, (rail, codec)
    assert pair.stats["handoffs"] == len(reqs)
    pair.check_invariants()


def test_disagg_shared_prefix_dedup(qwen_smoke):
    """Two requests sharing a system prompt: the second handoff's
    staged pages for the shared pages are duplicates (freed, table
    entry shared) and output stays token-exact."""
    cfg, model, params = qwen_smoke
    rng = np.random.default_rng(227)
    sysp = rng.integers(1, cfg.vocab_size, 12).tolist()     # 3 full pages
    reqs = [Request(rid=i,
                    prompt=sysp + rng.integers(1, cfg.vocab_size,
                                               3).tolist(),
                    max_new_tokens=4)
            for i in range(2)]
    gold, got, pair = _run_matrix(model, params, reqs)
    assert got == gold
    assert pair.stats["handoff_dupes"] >= 3, pair.stats
    pair.check_invariants()


# ------------------------------------------------- lifecycle edges
def test_disagg_mid_handoff_cancel(qwen_smoke):
    """Cancellation between stage and commit: abort must return every
    staged page to the decode worker's free list and release the
    exporter's pins - no refcount violation, no leaked page, and both
    workers still serve afterwards."""
    cfg, model, params = qwen_smoke
    mk = lambda: ServingEngine(model, params, max_batch=2, page_size=4,
                               max_seq=48)
    pair = DisaggPair(mk(), mk())
    req = Request(rid=0, prompt=list(range(1, 14)), max_new_tokens=4)
    h = pair.start_handoff(req)
    assert h is not None and len(h.src_pages) == 3
    # mid-handoff: staged pages are neither free nor owned, exporter
    # pinned - and the books still balance
    pair.check_invariants()
    assert pair.decode.cache.available_page_count == \
        pair.decode.cache.num_pages - len(h.dst_pages)
    pair.abort(h)
    assert h.state == "aborted"
    pair.check_invariants()
    assert pair.decode.cache.available_page_count == \
        pair.decode.cache.num_pages
    assert not np.any(pair.prefill.cache._export_pins)
    assert pair.stats["handoff_aborts"] == 1
    # both workers still serve; the prefill worker's parked prefix is
    # claimable again (pins gone), so a retried handoff succeeds
    h2 = pair.start_handoff(req)
    assert h2 is not None and h2.hashes == h.hashes
    pair.commit(h2)
    [fin] = pair.decode.run([(0, req)])
    assert fin.reason in ("eos", "length")
    pair.check_invariants()


def test_disagg_stage_fallback_when_pool_busy(qwen_smoke):
    """A decode pool with too few claimable pages to stage the
    transfer (the rest pinned under a live sequence): start_handoff
    returns None (fallback counted), the exporter's pins are released,
    and plain submission still serves the request token-exactly (the
    decode worker recomputes the prompt)."""
    cfg, model, params = qwen_smoke
    req = Request(rid=0, prompt=list(range(1, 14)), max_new_tokens=4)
    gold_engine = ServingEngine(model, params, max_batch=2, page_size=4,
                                max_seq=32)
    [gold] = gold_engine.run([(0, dataclasses.replace(
        req, prompt=list(req.prompt)))])
    pair = DisaggPair(
        ServingEngine(model, params, max_batch=2, page_size=4,
                      max_seq=32),
        ServingEngine(model, params, max_batch=2, page_size=4,
                      num_pages=8, max_seq=32))
    # a live sequence holds 6 of the decode worker's 8 pages: staging
    # the 3-page transfer must fail over, not evict live KV
    busy = pair.decode.cache.alloc_slot(21)
    h = pair.start_handoff(dataclasses.replace(req,
                                               prompt=list(req.prompt)))
    assert h is None
    assert pair.stats["handoff_fallbacks"] == 1
    assert not np.any(pair.prefill.cache._export_pins)
    pair.check_invariants()
    pair.decode.cache.free_slot(busy)
    [fin] = pair.decode.run([(0, dataclasses.replace(
        req, prompt=list(req.prompt)))])
    assert fin.tokens == gold.tokens
    pair.check_invariants()


def test_disagg_validation():
    """Mismatched page geometry / codec / prefix caching is refused up
    front - silently copying pages between incompatible pools would
    corrupt KV."""
    class _Stub:
        def __init__(self, page_size=4, kv_codec="fp",
                     prefix_caching=True):
            self.page_size = page_size
            self.kv_codec = kv_codec
            self.prefix_caching = prefix_caching
    with pytest.raises(ValueError, match="page_size"):
        DisaggPair(_Stub(page_size=4), _Stub(page_size=8))
    with pytest.raises(ValueError, match="kv_codec"):
        DisaggPair(_Stub(kv_codec="fp"), _Stub(kv_codec="int8"))
    with pytest.raises(ValueError, match="prefix_caching"):
        DisaggPair(_Stub(), _Stub(prefix_caching=False))
