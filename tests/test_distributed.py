"""Distributed semantics on 8 fake CPU devices (subprocess: the device
count must be fixed before jax initializes, so these tests shell out)."""
import os
import subprocess
import sys

import pytest

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = REPO_SRC
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_sharded_train_step_matches_single_device():
    out = _run("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.model import build_model
from repro.optim import build_optimizer
from repro.optim.schedule import constant
from repro.runtime.trainer import make_train_step
from repro.parallel import sharding as sh

cfg = get_config("qwen3-1.7b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
opt = build_optimizer(cfg, constant(1e-2))
step = make_train_step(model, opt)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 32)))}
carry = {"params": params, "opt_state": opt.init(params)}

# single device
_, m1 = jax.jit(step)(carry, batch)

# 4x2 mesh, sharded
mesh = jax.make_mesh((4, 2), ("data", "model"))
sh.set_context(mesh, sh.TRAIN_RULES)
shapes, logical = model.shape_and_logical()
pspec = sh.tree_specs(logical, shapes, sh.TRAIN_RULES, mesh)
psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                   is_leaf=lambda x: isinstance(x, P))
with mesh:
    params_s = jax.device_put(params, psh)
    carry_s = {"params": params_s, "opt_state": opt.init(params_s)}
    batch_s = jax.device_put(batch, NamedSharding(mesh, P("data", None)))
    _, m2 = jax.jit(step)(carry_s, batch_s)
print("LOSS", float(m1["loss"]), float(m2["loss"]))
assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-3, (m1, m2)
print("OK")
""")
    assert "OK" in out


def test_kv_sharded_decode_matches_replicated():
    """Sequence-sharded KV cache decode == replicated decode (the paper's
    multi-KV-block parallelism at mesh level, XLA-merged)."""
    out = _run("""
import jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.kernels import ops
mesh = jax.make_mesh((1, 8), ("data", "model"))
rng = np.random.default_rng(0)
q = jnp.asarray(rng.standard_normal((2, 1, 8, 64)), jnp.bfloat16)
kc = jnp.asarray(rng.standard_normal((2, 512, 2, 64)), jnp.bfloat16)
vc = jnp.asarray(rng.standard_normal((2, 512, 2, 64)), jnp.bfloat16)
ref = np.asarray(ops.decode_attention(q, kc, vc, impl="fa2", kv_len=400).astype(jnp.float32))
with mesh:
    f = jax.jit(lambda q, k, v: ops.decode_attention(q, k, v, impl="fa2", kv_len=400),
        in_shardings=(NamedSharding(mesh, P()),
                      NamedSharding(mesh, P(None, "model", None, None)),
                      NamedSharding(mesh, P(None, "model", None, None))),
        out_shardings=NamedSharding(mesh, P()))
    got = np.asarray(f(q, kc, vc).astype(jnp.float32))
print("ERR", np.abs(got - ref).max())
assert np.abs(got - ref).max() < 2e-3
print("OK")
""")
    assert "OK" in out


def test_shardmap_decode_merge_matches_reference():
    """shard_map KV-split decode + explicit log-domain ACC merge (Eq. 16):
    the paper's cascaded merge as a cluster collective."""
    out = _run("""
import jax, numpy as np, jax.numpy as jnp, functools
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.parallel.collectives import shard_map
from repro.kernels import decode as dk
from repro.core import reference as cref

mesh = jax.make_mesh((8,), ("kv",))
rng = np.random.default_rng(0)
BH, G, S, D = 4, 4, 1024, 64
q = jnp.asarray(rng.standard_normal((BH, G, D)), jnp.bfloat16)
k = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.bfloat16)
v = jnp.asarray(rng.standard_normal((BH, S, D)), jnp.bfloat16)

def local_partial(q, k, v):
    # pure-jnp partial per shard (kernel path equivalently validated in
    # test_kernels); here we exercise the collective merge itself.
    from repro.kernels import ref as kref
    o, m, l = kref.ref_decode_partial(q, k, v)
    og = jax.lax.all_gather(o, "kv")            # (P, BH, G, D)
    mg = jax.lax.all_gather(m, "kv")
    lg = jax.lax.all_gather(l, "kv")
    om, mm, lm = dk.merge_partials(og, mg, lg, use_hfa=True)
    return dk.finalize_decode(om, lm, use_hfa=True)

f = shard_map(local_partial, mesh=mesh,
              in_specs=(P(), P(None, "kv", None), P(None, "kv", None)),
              out_specs=P(), check_vma=False)
got = np.asarray(jax.jit(f)(q, k, v))
ref = np.asarray(cref.exact_attention(q, k, v))
print("ERR", np.abs(got - ref).max())
assert np.abs(got - ref).max() < 0.05
print("OK")
""")
    assert "OK" in out


def test_shardmap_local_write_decode_attention():
    """parallel/collectives.py: local ring write + partial FAU + ACC merge
    must equal write-then-attend on one device (the §Perf mechanism)."""
    out = _run("""
import jax, numpy as np, jax.numpy as jnp
from repro.parallel.collectives import shardmap_decode_attention
from repro.kernels import ops
mesh = jax.make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
B, S, H, HKV, D = 4, 512, 8, 2, 64
q = jnp.asarray(rng.standard_normal((B,1,H,D)), jnp.bfloat16)
kn = jnp.asarray(rng.standard_normal((B,1,HKV,D)), jnp.bfloat16)
vn = jnp.asarray(rng.standard_normal((B,1,HKV,D)), jnp.bfloat16)
ck = jnp.asarray(rng.standard_normal((B,S,HKV,D)), jnp.bfloat16)
cv = jnp.asarray(rng.standard_normal((B,S,HKV,D)), jnp.bfloat16)
for pos in (0, 300, 511):
    with mesh:
        out, nk, nv = jax.jit(lambda *a: shardmap_decode_attention(
            *a, mesh=mesh, batch_axes=("data",), use_hfa=False))(
            q, kn, vn, ck, cv, jnp.int32(pos))
    ck2 = ck.at[:, pos].set(kn[:, 0]); cv2 = cv.at[:, pos].set(vn[:, 0])
    ref = ops.decode_attention(q, ck2, cv2, impl="fa2", kv_len=pos+1)
    err = float(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max())
    assert err < 2e-3, (pos, err)
    assert bool(jnp.all(nk[:, pos] == kn[:, 0]))
print("OK")
""")
    assert "OK" in out


def test_checkpoint_elastic_reshard():
    """Save under one sharding, restore under another mesh (elastic)."""
    out = _run("""
import tempfile, jax, numpy as np, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.checkpoint import save, restore
tree = {"w": jnp.arange(64.0).reshape(8, 8)}
d = tempfile.mkdtemp()
mesh1 = jax.make_mesh((8,), ("data",))
t1 = jax.device_put(tree, NamedSharding(mesh1, P("data", None)))
save(d, 1, t1)
mesh2 = jax.make_mesh((2, 4), ("data", "model"))
sh2 = {"w": NamedSharding(mesh2, P("model", "data"))}
got, step = restore(d, None, tree, sh2)
assert got["w"].sharding == sh2["w"]
np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))
print("OK")
""")
    assert "OK" in out


def test_small_dryrun_cell_on_8_devices():
    """The dry-run machinery works end-to-end on a small mesh."""
    out = _run("""
import jax, json
from repro.configs import get_config
from repro.launch.specs import build_cell
cfg = get_config("qwen3-1.7b").reduced()
mesh = jax.make_mesh((4, 2), ("data", "model"))
for shape in ("train_4k", "decode_32k"):
    import dataclasses
    from repro.launch import specs
    mode, seq, batch = specs.SHAPES[shape]
    specs.SHAPES[shape] = (mode, 256, 8)   # shrink for the test
    fn, args, in_sh, out_sh, meta = build_cell(cfg, shape, mesh)
    with mesh:
        compiled = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh).lower(*args).compile()
    assert compiled.memory_analysis().temp_size_in_bytes > 0
    specs.SHAPES[shape] = (mode, seq, batch)
print("OK")
""")
    assert "OK" in out
