"""FIX16 logarithmic-number-system datapath from the H-FA paper (Sec. IV-V).

Implements, bit-accurately and jit-safely:

  * ``quant_scorediff``  - Eq. (14b/c): clamp natural-domain score
    differences to [-15, 0], multiply by log2(e), quantize to FIX16 (9.7).
  * ``blinn_log2``       - Eq. (18): float -> fixed-point log2 magnitude by
    reinterpreting the BFloat16 exponent/mantissa bits (Blinn's trick),
    i.e. log2|v| ~= E.M - bias.
  * ``exp2_neg``         - Eq. (19): 2^{-D} = (2^{-f}) >> p via an 8-segment
    piecewise-linear LUT (coefficients fitted offline, quantized Q1.15).
  * ``lns_add``          - Eq. (10)+(17): sum of two signed log-domain
    numbers using max + Mitchell's approximation
    log2(1 +- 2^{-|A-B|}) ~= +- 2^{-|A-B|}.
  * ``lns_to_bf16``      - Eq. (22): fixed-point log back to BFloat16,
    |x| = 2^I * (1+F) (inverse Blinn / bit packing).

LNS numbers are (sign, raw) pairs: ``sign`` in {0,1}, ``raw`` holds
log2|x| * 2^7 on a float32 *rail*.  In the default configuration every
value on the rail is integer-valued, so the emulation is bit-identical to a
two's-complement int16 datapath (float32 is exact for |x| < 2^24); the
Pallas datapath kernel implements the same spec in int32 and is tested for
exact equality.  The float rail exists so the Table-III ablations
(``LNSConfig``) can selectively disable each approximation:

  exact_quant    - keep score diffs / corrections at full precision
  exact_mitchell - true log2(1 +- x) instead of Mitchell's +-x, and true
                   log2 instead of Blinn's bit trick
  exact_pwl      - true 2^{-f} instead of the 8-segment PWL

``raw <= LOG_ZERO`` encodes x == 0.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.numerics import (
    BF16_BIAS,
    FIX_MAX,
    FIX_MIN,
    FRAC_BITS,
    FRAC_ONE,
    LOG_ZERO,
    bf16_bits,
)

LOG2E = float(np.log2(np.e))
# Natural-domain score differences below -15 contribute e^-15 ~ 3e-7 and are
# clamped (paper Sec. IV-B).
DIFF_CLAMP_NAT = -15.0

_NUM_SEGMENTS = 8
_COEF_FRAC_BITS = 15  # Q1.15 LUT coefficients


def _fit_pwl_exp2() -> tuple[np.ndarray, np.ndarray]:
    """Least-squares fit of 2^{-f} on 8 uniform segments of [0, 1).

    Mirrors the pwlf-style fitting used in the paper; coefficients are
    quantized to Q1.15 so the hardware LUT stays pure fixed point.
    """
    slopes = np.zeros(_NUM_SEGMENTS)
    intercepts = np.zeros(_NUM_SEGMENTS)
    for seg in range(_NUM_SEGMENTS):
        f = np.linspace(seg / _NUM_SEGMENTS, (seg + 1) / _NUM_SEGMENTS, 257)
        y = 2.0 ** (-f)
        a, b = np.polyfit(f, y, 1)
        slopes[seg] = a
        intercepts[seg] = b
    scale = 1 << _COEF_FRAC_BITS
    return (
        np.round(slopes * scale).astype(np.float32),
        np.round(intercepts * scale).astype(np.float32),
    )


_PWL_A, _PWL_B = _fit_pwl_exp2()
PWL_SLOPES_Q15 = tuple(float(x) for x in _PWL_A)
PWL_INTERCEPTS_Q15 = tuple(float(x) for x in _PWL_B)


def _lut8(seg: jax.Array, table: tuple[float, ...]) -> jax.Array:
    """8-way select chain with literal coefficients (the hardware LUT mux).

    Uses scalar constants only, so it traces inside Pallas kernel bodies
    without captured-array constants.
    """
    segf = seg.astype(jnp.float32)
    out = jnp.full_like(segf, table[0])
    for i in range(1, _NUM_SEGMENTS):
        out = jnp.where(segf >= i, table[i], out)
    return out


@dataclasses.dataclass(frozen=True)
class LNSConfig:
    """Ablation switches for the three approximation sources (Table III)."""

    exact_quant: bool = False
    exact_mitchell: bool = False
    exact_pwl: bool = False

    @property
    def tag(self) -> str:
        parts = []
        if self.exact_quant:
            parts.append("exact-quant")
        if self.exact_mitchell:
            parts.append("exact-mitchell")
        if self.exact_pwl:
            parts.append("exact-pwl")
        return "+".join(parts) if parts else "full"


DEFAULT = LNSConfig()
EXACT = LNSConfig(exact_quant=True, exact_mitchell=True, exact_pwl=True)


def _round_rail(x: jax.Array, cfg: LNSConfig) -> jax.Array:
    """Round a rail value to the 7-fraction-bit grid unless quant is ablated."""
    if cfg.exact_quant:
        return x
    return jnp.round(x)


def clamp_rail(raw: jax.Array) -> jax.Array:
    """Saturate to the FIX16 range (works for float rail too)."""
    return jnp.clip(raw, FIX_MIN, FIX_MAX)


def quant_scorediff(diff_nat: jax.Array, cfg: LNSConfig = DEFAULT) -> jax.Array:
    """Eq. (14b/c): quantize a non-positive natural-domain score diff.

    Returns the rail value of ``diff * log2(e)``; handles -inf via the clamp.
    """
    diff = jnp.clip(diff_nat.astype(jnp.float32), DIFF_CLAMP_NAT, 0.0)
    return _round_rail(diff * LOG2E * FRAC_ONE, cfg)


def blinn_log2(v: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Eq. (18): BF16 -> (sign, rail log2 magnitude) via bit reinterpretation.

    raw = (bits & 0x7FFF) - (bias << 7); v == 0 maps to LOG_ZERO.
    """
    bits = bf16_bits(v)
    sign = jnp.right_shift(bits, 15) & 1
    mag = jnp.bitwise_and(bits, 0x7FFF)
    raw = (mag - (BF16_BIAS << FRAC_BITS)).astype(jnp.float32)
    raw = jnp.where(mag == 0, float(LOG_ZERO), raw)
    return sign.astype(jnp.int32), clamp_rail(raw)


def exact_log2(v: jax.Array, cfg: LNSConfig = DEFAULT) -> tuple[jax.Array, jax.Array]:
    """Ablation counterpart of blinn_log2 (true log2, then rail rounding)."""
    vf = v.astype(jnp.float32)
    sign = (vf < 0).astype(jnp.int32)
    mag = jnp.abs(vf)
    raw = _round_rail(jnp.log2(jnp.maximum(mag, 1e-38)) * FRAC_ONE, cfg)
    raw = jnp.where(mag == 0, float(LOG_ZERO), raw)
    return sign, clamp_rail(raw)


def lns_from_bf16(v: jax.Array, cfg: LNSConfig = DEFAULT) -> tuple[jax.Array, jax.Array]:
    """Float -> LNS. Blinn's trick *is* a Mitchell approximation (Eq. 18)."""
    if cfg.exact_mitchell:
        return exact_log2(v, cfg)
    return blinn_log2(v)


def pwl_exp2_frac(f_rail: jax.Array, cfg: LNSConfig = DEFAULT) -> jax.Array:
    """2^{-f} for f = f_rail/128 in [0,1), on the fraction rail ([64,128]).

    8-segment PWL LUT indexed by the top 3 fraction bits (Eq. 19).
    """
    if cfg.exact_pwl:
        g = 2.0 ** (-(f_rail / FRAC_ONE)) * FRAC_ONE
        return _round_rail(g, cfg)
    seg = jnp.clip(jnp.floor(f_rail / (FRAC_ONE / _NUM_SEGMENTS)), 0,
                   _NUM_SEGMENTS - 1)
    a = _lut8(seg, PWL_SLOPES_Q15)
    b = _lut8(seg, PWL_INTERCEPTS_Q15)
    # g_q15 = a*f + b with f = f_rail/128; hardware: (a*f7 >> 7) + b.
    g_q15 = jnp.floor(a * f_rail / FRAC_ONE) + b
    # Round from Q1.15 down to the 7-bit fraction rail (round-half-up, as a
    # truncating adder-with-carry-in would).
    down = 1 << (_COEF_FRAC_BITS - FRAC_BITS)
    g7 = jnp.floor((g_q15 + down // 2) / down)
    if cfg.exact_quant:
        return g_q15 / down
    return g7


def exp2_neg(raw_d: jax.Array, cfg: LNSConfig = DEFAULT) -> jax.Array:
    """2^{-D} for non-negative rail D, result on the fraction rail.

    Split D = p + f (integer/fraction): 2^{-D} = 2^{-f} >> p  (Eq. 19).
    """
    p = jnp.floor(raw_d / FRAC_ONE)
    f = raw_d - p * FRAC_ONE
    g = pwl_exp2_frac(f, cfg)
    shifted = g * (2.0 ** (-jnp.minimum(p, 60.0)))
    if cfg.exact_quant:
        return shifted
    # Hardware right shift truncates.
    return jnp.floor(shifted)


def lns_add(
    sign_a: jax.Array,
    raw_a: jax.Array,
    sign_b: jax.Array,
    raw_b: jax.Array,
    cfg: LNSConfig = DEFAULT,
) -> tuple[jax.Array, jax.Array]:
    """Eq. (10) + (17): signed LNS addition c = a + b.

    a = (-1)^{sign_a} 2^{raw_a/128}, likewise b. Returns (sign_c, raw_c).
    """
    a_is_zero = raw_a <= LOG_ZERO
    b_is_zero = raw_b <= LOG_ZERO

    big = jnp.maximum(raw_a, raw_b)
    d = jnp.abs(raw_a - raw_b)
    same_sign = sign_a == sign_b

    if cfg.exact_mitchell:
        x = 2.0 ** (-(d / FRAC_ONE))
        corr_pos = _round_rail(jnp.log2(1.0 + x) * FRAC_ONE, cfg)
        xm = jnp.minimum(x, 1.0 - 2.0 ** -24)
        corr_neg = _round_rail(-jnp.log2(1.0 - xm) * FRAC_ONE, cfg)
    else:
        corr = exp2_neg(d, cfg)  # Mitchell: log2(1 +- 2^{-D}) ~= +- 2^{-D}
        corr_pos = corr
        corr_neg = corr

    raw_c = jnp.where(same_sign, big + corr_pos, big - corr_neg)
    # Sign follows the larger-magnitude operand; ties (B >= A) take B (14d).
    sign_c = jnp.where(raw_a > raw_b, sign_a, sign_b)

    # Zero-operand bypasses.
    raw_c = jnp.where(a_is_zero, raw_b, raw_c)
    sign_c = jnp.where(a_is_zero, sign_b, sign_c)
    raw_c = jnp.where(b_is_zero, jnp.where(a_is_zero, float(LOG_ZERO), raw_a), raw_c)
    sign_c = jnp.where(b_is_zero, jnp.where(a_is_zero, 0, sign_a), sign_c)

    # Exact cancellation (same magnitude, opposite sign) -> zero.
    cancel = (~same_sign) & (d == 0) & ~a_is_zero & ~b_is_zero
    raw_c = jnp.where(cancel, float(LOG_ZERO), raw_c)
    sign_c = jnp.where(cancel, 0, sign_c)

    return sign_c.astype(jnp.int32), clamp_rail(raw_c)


def lns_to_bf16(sign: jax.Array, raw: jax.Array,
                cfg: LNSConfig = DEFAULT) -> jax.Array:
    """Eq. (22): (sign, rail log2 magnitude) -> BFloat16.

    |x| = 2^I * (1+F), the inverse Mitchell/Blinn reconstruction.  For
    integer rail values this equals the hardware bit-packing
    (sign | (I+bias)<<7 | F*128) exactly, including saturation semantics:
    underflow flushes to zero, overflow saturates to the max finite BF16.
    With ``exact_mitchell`` the true 2^{raw/128} is used instead (ablation).
    """
    i_part = jnp.floor(raw / FRAC_ONE)
    f_part = raw / FRAC_ONE - i_part
    is_zero = raw <= LOG_ZERO
    underflow = (i_part + BF16_BIAS) <= 0
    overflow = (i_part + BF16_BIAS) >= 255
    i_safe = jnp.clip(i_part, 1 - BF16_BIAS, 254 - BF16_BIAS)
    if cfg.exact_mitchell:
        mag = jnp.exp2(i_safe + f_part)
    else:
        mag = jnp.exp2(i_safe) * (1.0 + f_part)
    mag = jnp.where(underflow | is_zero, 0.0, mag)
    max_finite = 2.0 ** 127 * (1.0 + 127.0 / 128.0)
    mag = jnp.where(overflow & ~is_zero, max_finite, mag)
    out = jnp.where(sign == 1, -mag, mag)
    return out.astype(jnp.bfloat16)


def lns_value_f32(sign: jax.Array, raw: jax.Array) -> jax.Array:
    """Debug helper: value under *true* log semantics, 2^{raw/128}."""
    mag = jnp.where(raw <= LOG_ZERO, 0.0, jnp.exp2(raw / FRAC_ONE))
    return jnp.where(sign == 1, -mag, mag)


def lns_value_hw(sign: jax.Array, raw: jax.Array) -> jax.Array:
    """Value under *hardware* semantics, 2^I * (1+F) in float32.

    This is the consistent way to read the rail: Blinn's forward conversion
    (Eq. 18) and this inverse cancel exactly, so pure products/quotients are
    exact in the datapath and only the LNS-add correction term carries
    Mitchell error.
    """
    i_part = jnp.floor(raw / FRAC_ONE)
    f_part = raw / FRAC_ONE - i_part
    mag = jnp.exp2(i_part) * (1.0 + f_part)
    mag = jnp.where(raw <= LOG_ZERO, 0.0, mag)
    return jnp.where(sign == 1, -mag, mag)
