"""Bit-accurate H-FA attention emulation (paper Sec. IV-V).

This is the datapath-faithful model of the proposed hardware: attention
scores, running maxima and score differences in BFloat16 floating point;
the fused (l, o) accumulation, cross-block ACC merging and the final
normalization entirely in the FIX16 logarithmic domain of
:mod:`repro.core.lns`.

Public entry points:

  * ``hfa_attention``            - full H-FA attention for a KV span
                                   (streaming FAU, Alg. 2 + Eq. 14).
  * ``hfa_partial``              - FAU partial triplet (m, sign, rawlog)
                                   without the final LogDiv.
  * ``acc_merge``                - log-domain ACC block merge (Eq. 16).
  * ``hfa_blockparallel``        - Fig. 2: p parallel FAU blocks + cascaded
                                   ACC merge + LogDiv.
  * ``logdiv``                   - Eq. (15) + (22): o/l via fixed-point
                                   subtraction, then back to BFloat16.

The streaming state follows Eq. (12): O_i = [l_i, o_i] with V_i = [1, v_i],
kept as (sign, raw) LNS tensors of width d+1.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import lns
from repro.core.numerics import LOG_ZERO, to_bf16

NEG_INF = -1e30


class HFAPartial(NamedTuple):
    """Partial FAU state: float max + LNS fused accumulator O = [l, o]."""

    m: jax.Array        # (..., Lq)       float32 (carries BF16 values)
    sign: jax.Array     # (..., Lq, d+1)  int32 {0,1}
    raw: jax.Array      # (..., Lq, d+1)  FIX16 rail (float32, integer-valued)


def _empty_state(batch_shape: tuple[int, ...], d: int) -> HFAPartial:
    return HFAPartial(
        m=jnp.full(batch_shape, NEG_INF, jnp.float32),
        sign=jnp.zeros(batch_shape + (d + 1,), jnp.int32),
        raw=jnp.full(batch_shape + (d + 1,), float(LOG_ZERO), jnp.float32),
    )


def hfa_partial(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None = None,
    mask: jax.Array | None = None,
    cfg: lns.LNSConfig = lns.DEFAULT,
    init: HFAPartial | None = None,
    kv_offset: int = 0,
) -> HFAPartial:
    """Stream one KV span through the FAU (Alg. 2 with Eq. 14 updates).

    Args:
      q: (..., Lq, d) queries. k, v: (..., Lkv, d).
      mask: optional (..., Lq, Lkv) boolean; masked keys are skipped exactly
        (the hardware simply does not clock them in).
      init: carry in a previous partial state (used by the streaming server).
      kv_offset: global index of k[...,0,:] (for causal masks built here).
    """
    d = q.shape[-1]
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    lkv = k.shape[-2]
    batch_shape = q.shape[:-2] + (q.shape[-2],)

    state = init if init is not None else _empty_state(batch_shape, d)

    # Scores for the whole span in BF16 (the FP half of the hybrid datapath).
    s_all = jnp.einsum("...qd,...kd->...qk", q.astype(jnp.float32),
                       k.astype(jnp.float32)) * scale_v
    s_all = to_bf16(s_all).astype(jnp.float32)  # (..., Lq, Lkv)

    if mask is None:
        valid_all = jnp.ones(s_all.shape, bool)
    else:
        valid_all = jnp.broadcast_to(mask, s_all.shape)

    # Move the key axis first for the streaming scan.
    s_seq = jnp.moveaxis(s_all, -1, 0)            # (Lkv, ..., Lq)
    valid_seq = jnp.moveaxis(valid_all, -1, 0)    # (Lkv, ..., Lq)
    v_seq = jnp.moveaxis(v.astype(jnp.bfloat16), -2, 0)  # (Lkv, ..., d)

    def body(carry: HFAPartial, inputs):
        s_i, valid_i, v_i = inputs
        m_prev, sgn_prev, raw_prev = carry

        m_new = jnp.maximum(m_prev, s_i)
        live = valid_i & (m_new > NEG_INF / 2)

        dm = m_prev - m_new                     # <= 0, -inf on first hit
        ds = s_i - m_new                        # <= 0
        q_dm = lns.quant_scorediff(dm, cfg)     # Eq. (14b)
        q_ds = lns.quant_scorediff(ds, cfg)     # Eq. (14c)

        # A: rescaled previous accumulator.
        a_raw = lns.clamp_rail(raw_prev + q_dm[..., None])
        # Rescaling zero stays zero.
        a_raw = jnp.where(raw_prev <= LOG_ZERO, float(LOG_ZERO), a_raw)

        # B: incoming V_i = [1, v_i] in LNS plus the exp term (Eq. 14c).
        ones = jnp.ones(v_i.shape[:-1] + (1,), v_i.dtype)
        v_ext = jnp.concatenate([ones, v_i], axis=-1)      # (..., d+1)
        sgn_v, raw_v = lns.lns_from_bf16(v_ext, cfg)
        # Broadcast over the query axis: v_ext is (..., d+1) -> (..., 1, d+1)
        sgn_v = sgn_v[..., None, :]
        raw_v = raw_v[..., None, :]
        b_raw = lns.clamp_rail(raw_v + q_ds[..., None])
        b_raw = jnp.where(raw_v <= LOG_ZERO, float(LOG_ZERO), b_raw)
        sgn_b = jnp.broadcast_to(sgn_v, sgn_prev.shape)
        b_raw = jnp.broadcast_to(b_raw, raw_prev.shape)

        sgn_new, raw_new = lns.lns_add(sgn_prev, a_raw, sgn_b, b_raw, cfg)

        keep = ~live
        m_out = jnp.where(keep, m_prev, m_new)
        sgn_out = jnp.where(keep[..., None], sgn_prev, sgn_new)
        raw_out = jnp.where(keep[..., None], raw_prev, raw_new)
        return HFAPartial(m_out, sgn_out, raw_out), None

    state, _ = jax.lax.scan(body, state, (s_seq, valid_seq, v_seq))
    return state


def logdiv(state: HFAPartial, cfg: lns.LNSConfig = lns.DEFAULT) -> jax.Array:
    """Eq. (15)+(22): attention = o_N / l_N as LNS subtraction -> BFloat16."""
    raw_l = state.raw[..., :1]
    sgn_l = state.sign[..., :1]
    raw_o = state.raw[..., 1:]
    sgn_o = state.sign[..., 1:]
    raw_attn = lns.clamp_rail(raw_o - raw_l)
    sgn_attn = jnp.bitwise_xor(sgn_o, sgn_l)
    empty = (raw_l <= LOG_ZERO) | (raw_o <= LOG_ZERO)
    raw_attn = jnp.where(empty, float(LOG_ZERO), raw_attn)
    return lns.lns_to_bf16(sgn_attn, raw_attn, cfg)


def acc_merge(a: HFAPartial, b: HFAPartial,
              cfg: lns.LNSConfig = lns.DEFAULT) -> HFAPartial:
    """Eq. (16): log-domain ACC merge of two partial FAU triplets."""
    m_n = jnp.maximum(a.m, b.m)
    q_da = lns.quant_scorediff(a.m - m_n, cfg)
    q_db = lns.quant_scorediff(b.m - m_n, cfg)
    a_raw = lns.clamp_rail(a.raw + q_da[..., None])
    a_raw = jnp.where(a.raw <= LOG_ZERO, float(LOG_ZERO), a_raw)
    b_raw = lns.clamp_rail(b.raw + q_db[..., None])
    b_raw = jnp.where(b.raw <= LOG_ZERO, float(LOG_ZERO), b_raw)
    sgn, raw = lns.lns_add(a.sign, a_raw, b.sign, b_raw, cfg)
    # If one side never saw a key its m is -inf; max() recovers the other.
    return HFAPartial(m_n, sgn, raw)


def hfa_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    cfg: lns.LNSConfig = lns.DEFAULT,
) -> jax.Array:
    """Full H-FA attention for one KV span (single FAU)."""
    mask = None
    if causal:
        lq, lkv = q.shape[-2], k.shape[-2]
        qi = jnp.arange(lq)[:, None]
        kj = jnp.arange(lkv)[None, :]
        mask = kj <= qi + (lkv - lq)
    state = hfa_partial(q, k, v, scale=scale, mask=mask, cfg=cfg)
    return logdiv(state, cfg)


def hfa_blockparallel(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    num_blocks: int,
    causal: bool = False,
    scale: float | None = None,
    cfg: lns.LNSConfig = lns.DEFAULT,
) -> jax.Array:
    """Fig. 2: p parallel FAU blocks + cascaded log-domain ACC merge."""
    lkv = k.shape[-2]
    assert lkv % num_blocks == 0, (lkv, num_blocks)
    span = lkv // num_blocks
    lq = q.shape[-2]
    parts = []
    for i in range(num_blocks):
        sl = slice(i * span, (i + 1) * span)
        mask = None
        if causal:
            qi = jnp.arange(lq)[:, None]
            kj = jnp.arange(i * span, (i + 1) * span)[None, :]
            mask = kj <= qi + (lkv - lq)
        parts.append(hfa_partial(q, k[..., sl, :], v[..., sl, :],
                                 scale=scale, mask=mask, cfg=cfg))
    acc = parts[0]
    for p in parts[1:]:
        acc = acc_merge(acc, p, cfg)
    return logdiv(acc, cfg)
