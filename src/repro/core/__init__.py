"""Core H-FA contribution: LNS datapath + hybrid float/log FlashAttention."""
from repro.core import hfa, lns, numerics, reference  # noqa: F401
