"""Shared float<->bit helpers used by the LNS datapath emulation.

Everything here operates on jnp arrays and is jit-safe. The bit layouts
follow IEEE BFloat16: 1 sign | 8 exponent (bias 127) | 7 mantissa bits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BF16_BIAS = 127
BF16_MANT_BITS = 7
BF16_EXP_BITS = 8

# FIX16 log-domain format from the paper: 9 integer bits, 7 fraction bits,
# two's complement.  We carry the *raw* integer (value * 2^7) in int32 for
# headroom and clamp to the int16 range at every datapath boundary.
FRAC_BITS = 7
FRAC_ONE = 1 << FRAC_BITS  # 128
FIX_MAX = (1 << 15) - 1    # 32767
FIX_MIN = -(1 << 15)       # -32768
LOG_ZERO = FIX_MIN         # encoding of log2(0) = -inf in the datapath


def bf16_bits(x: jax.Array) -> jax.Array:
    """Bitcast a bfloat16 array to uint16 bit patterns (returned as int32)."""
    x = x.astype(jnp.bfloat16)
    return jax.lax.bitcast_convert_type(x, jnp.uint16).astype(jnp.int32)


def bits_bf16(bits: jax.Array) -> jax.Array:
    """Bitcast uint16 patterns (given as int32) back to bfloat16."""
    b = jnp.bitwise_and(bits, 0xFFFF).astype(jnp.uint16)
    return jax.lax.bitcast_convert_type(b, jnp.bfloat16)


def to_bf16(x: jax.Array) -> jax.Array:
    """Round to bfloat16 (round-to-nearest-even, what the HW datapath sees)."""
    return x.astype(jnp.bfloat16)


def clamp_fix16(raw: jax.Array) -> jax.Array:
    """Saturate a raw fixed-point int32 value to the FIX16 range."""
    return jnp.clip(raw, FIX_MIN, FIX_MAX)
