"""Pure-jnp reference attention algorithms (paper Sec. II).

These are the float oracles every other implementation is tested against:

  * ``exact_attention``  - softmax(QK^T * scale) V in float32.
  * ``lazy_attention``   - Alg. 1: two-pass lazy-softmax-division.
  * ``fa2_attention``    - Alg. 2: FlashAttention-2 single-pass streaming
    with delayed division (the paper's baseline 'FA-2' semantics).
  * ``merge_blocks``     - Eq. (1): combine partial (m, l, o) triplets from
    disjoint KV blocks.

All take Q (..., Lq, d), K/V (..., Lkv, d) with any leading batch/head dims,
and support an optional causal mask and explicit score scale.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class PartialAttn(NamedTuple):
    """Per-query partial attention state (m, l, o) for one KV block."""

    m: jax.Array  # (..., Lq)        running max score
    l: jax.Array  # (..., Lq)        running sum of exponentials
    o: jax.Array  # (..., Lq, d)     unnormalized output accumulator


def _scores(q: jax.Array, k: jax.Array, scale: float) -> jax.Array:
    return jnp.einsum("...qd,...kd->...qk", q.astype(jnp.float32),
                      k.astype(jnp.float32)) * scale


def _causal_mask(lq: int, lkv: int, offset: int | None = None) -> jax.Array:
    """Causal mask where query i attends to keys j <= i + offset."""
    if offset is None:
        offset = lkv - lq
    qi = jnp.arange(lq)[:, None]
    kj = jnp.arange(lkv)[None, :]
    return kj <= qi + offset


def exact_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
) -> jax.Array:
    """Dense softmax attention in float32 (the gold reference)."""
    d = q.shape[-1]
    scale = (1.0 / d ** 0.5) if scale is None else scale
    s = _scores(q, k, scale)
    if causal:
        mask = _causal_mask(q.shape[-2], k.shape[-2])
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("...qk,...kd->...qd", p, v.astype(jnp.float32))


def lazy_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
) -> jax.Array:
    """Alg. 1: two-pass attention with lazy softmax division."""
    d = q.shape[-1]
    scale = (1.0 / d ** 0.5) if scale is None else scale
    s = _scores(q, k, scale)
    if causal:
        mask = _causal_mask(q.shape[-2], k.shape[-2])
        s = jnp.where(mask, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)          # pass 1: global max
    f = jnp.exp(s - m)                              # pass 2: accumulate
    o = jnp.einsum("...qk,...kd->...qd", f, v.astype(jnp.float32))
    ell = jnp.sum(f, axis=-1, keepdims=True)
    return o / ell


def fa2_partial(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None = None,
    mask: jax.Array | None = None,
    causal: bool = False,
    kv_offset: int = 0,
    q_offset: int | None = None,
    block: int = 128,
) -> PartialAttn:
    """Alg. 2 inner loop over one KV span, returning the (m, l, o) triplet.

    Streams KV in blocks of ``block`` with the online max/rescale updates
    (lines 4-6 of Alg. 2).  Causality is applied per block from iota (never
    materializing an Lq x Lkv mask - required for the 32k/500k shapes);
    ``kv_offset`` is the global index of k[...,0,:].  ``mask``
    ((..., Lq, Lkv) boolean) remains available for irregular patterns in
    tests.
    """
    d = q.shape[-1]
    scale = (1.0 / d ** 0.5) if scale is None else scale
    lq, lkv = q.shape[-2], k.shape[-2]
    qf = q.astype(jnp.float32)
    batch_shape = q.shape[:-2] + (lq,)

    nblk = (lkv + block - 1) // block
    pad = nblk * block - lkv
    if pad:
        kp = jnp.pad(k, [(0, 0)] * (k.ndim - 2) + [(0, pad), (0, 0)])
        vp = jnp.pad(v, [(0, 0)] * (v.ndim - 2) + [(0, pad), (0, 0)])
        if mask is not None:
            mask = jnp.pad(mask, [(0, 0)] * (mask.ndim - 1) + [(0, pad)])
    else:
        kp, vp = k, v
    kv_valid_len = lkv

    q_ids = None
    if causal:
        # Global query rows: default = suffix alignment within this span.
        if q_offset is None:
            q_offset = kv_offset + lkv - lq
        q_ids = q_offset + jnp.arange(lq)

    def body(carry, blk):
        m_prev, l_prev, o_prev = carry
        ib, kb, vb, maskb = blk
        s = jnp.einsum("...qd,...kd->...qk", qf, kb.astype(jnp.float32)) * scale
        kv_ids = kv_offset + ib * block + jnp.arange(block)
        valid = kv_ids < (kv_offset + kv_valid_len)
        if causal:
            valid = valid[None, :] & (kv_ids[None, :] <= q_ids[:, None])
        if maskb is not None:
            valid = valid & maskb
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        # Guard fully-masked blocks: m stays NEG_INF, nothing accumulates.
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))    # e^{m_{i-1}-m_i}
        p = jnp.exp(s - m_new[..., None])                    # e^{s_i - m_i}
        p = jnp.where(valid & (m_new != NEG_INF)[..., None], p, 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        o_new = (o_prev * alpha[..., None]
                 + jnp.einsum("...qk,...kd->...qd", p, vb.astype(jnp.float32)))
        return (m_new, l_new, o_new), None

    def to_blocks(x):
        shp = x.shape[:-2] + (nblk, block, x.shape[-1])
        return jnp.moveaxis(x.reshape(shp), -3, 0)

    kb = to_blocks(kp)
    vb = to_blocks(vp)
    if mask is not None:
        mshp = mask.shape[:-1] + (nblk, block)
        mb = jnp.moveaxis(mask.reshape(mshp), -2, 0)
    else:
        mb = None

    init = (
        jnp.full(batch_shape, NEG_INF, jnp.float32),
        jnp.zeros(batch_shape, jnp.float32),
        jnp.zeros(batch_shape + (d,), jnp.float32),
    )
    xs = (jnp.arange(nblk), kb, vb, mb) if mb is not None else \
         (jnp.arange(nblk), kb, vb)
    if mb is None:
        (m, l, o), _ = jax.lax.scan(
            lambda c, b: body(c, (b[0], b[1], b[2], None)), init, xs)
    else:
        (m, l, o), _ = jax.lax.scan(body, init, xs)
    return PartialAttn(m, l, o)


def fa2_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    block: int = 128,
) -> jax.Array:
    """Alg. 2: FlashAttention-2 with delayed softmax division."""
    part = fa2_partial(q, k, v, scale=scale, causal=causal, block=block)
    return part.o / part.l[..., None]


def merge_blocks(a: PartialAttn, b: PartialAttn) -> PartialAttn:
    """Eq. (1): merge two partial triplets from disjoint KV blocks."""
    m = jnp.maximum(a.m, b.m)
    ea = jnp.exp(a.m - m)
    eb = jnp.exp(b.m - m)
    l = a.l * ea + b.l * eb
    o = a.o * ea[..., None] + b.o * eb[..., None]
    return PartialAttn(m, l, o)


def merge_many(parts: list[PartialAttn]) -> PartialAttn:
    """Cascaded ACC merge (Fig. 2 vertical pipeline)."""
    acc = parts[0]
    for p in parts[1:]:
        acc = merge_blocks(acc, p)
    return acc


def blockparallel_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    num_blocks: int,
    causal: bool = False,
    scale: float | None = None,
) -> jax.Array:
    """Fig. 2: split KV into ``num_blocks`` FAU blocks, merge with ACC units."""
    lkv = k.shape[-2]
    assert lkv % num_blocks == 0, (lkv, num_blocks)
    span = lkv // num_blocks
    parts = []
    for i in range(num_blocks):
        sl = slice(i * span, (i + 1) * span)
        # Global-row causality: queries are the suffix of the FULL span.
        parts.append(fa2_partial(
            q, k[..., sl, :], v[..., sl, :], scale=scale, causal=causal,
            kv_offset=i * span, q_offset=lkv - q.shape[-2]))
    merged = merge_many(parts)
    return merged.o / merged.l[..., None]
