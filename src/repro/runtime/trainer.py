"""Fault-tolerant training driver.

``make_train_step`` builds the pure step function (loss -> grad ->
optional int8 error-feedback gradient compression -> optimizer), with
gradient-accumulation microbatching via ``lax.scan``.

``Trainer`` owns the loop: periodic atomic checkpoints (async), automatic
restore-and-restart after failures (including injected ones, for tests), a
step-time watchdog for straggler detection, and deterministic data resume
(the pipeline is addressed by step, so restart at step N replays exactly
batch N - no iterator state).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import DataPipeline
from repro.optim import build_optimizer, compression
from repro.optim.schedule import warmup_cosine


def make_train_step(model, opt, *, microbatches: int = 1,
                    grad_compression: bool = False, unroll: bool = False):
    """Returns step(carry, batch) -> (carry, metrics).

    carry = {params, opt_state, [grad_error]}.  ``batch`` leaves have the
    global batch leading; with microbatching they are reshaped to
    (M, B/M, ...) and grads accumulated with a scan (or a Python loop when
    ``unroll`` - used by the dry-run cost probes, since HLO cost analysis
    counts loop bodies once).
    """

    def loss_fn(params, mb):
        return model.loss(params, mb)

    def step(carry, batch):
        params = carry["params"]
        if microbatches == 1:
            (_, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def split(x):
                return x.reshape((microbatches, x.shape[0] // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)

            def acc(c, mb):
                (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                return jax.tree.map(jnp.add, c, (g, m)), None

            zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                  params)
            zero_m = {"nll": 0.0, "loss": 0.0, "load_balance": 0.0,
                      "router_z": 0.0}
            zero_m = jax.tree.map(jnp.float32, zero_m)
            if unroll:
                c = (zero_g, zero_m)
                for i in range(microbatches):
                    c, _ = acc(c, jax.tree.map(lambda x: x[i], mbs))
                grads, metrics = c
            else:
                (grads, metrics), _ = jax.lax.scan(acc, (zero_g, zero_m), mbs)
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            metrics = jax.tree.map(lambda m: m / microbatches, metrics)

        new_carry = dict(carry)
        if grad_compression:
            grads, new_err = compression.compress_gradients(
                grads, carry["grad_error"])
            new_carry["grad_error"] = new_err
        params, opt_state = opt.update(grads, carry["opt_state"], params)
        new_carry["params"] = params
        new_carry["opt_state"] = opt_state
        gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                             for g in jax.tree.leaves(grads)))
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return new_carry, metrics

    return step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    peak_lr: float = 3e-4
    warmup: int = 10
    seq_len: int = 128
    global_batch: int = 8
    seed: int = 0
    straggler_threshold: float = 10.0  # x median step time -> flagged
    max_restarts: int = 3
    async_ckpt: bool = True
    grad_compression: bool = False


class Trainer:
    """Single-host driver with the multi-host control flow in place."""

    def __init__(self, model, tcfg: TrainerConfig, donate: bool = True):
        self.model = model
        self.tcfg = tcfg
        self.pipeline = DataPipeline.for_config(
            model.cfg, tcfg.seq_len, tcfg.global_batch, tcfg.seed)
        sched = warmup_cosine(tcfg.peak_lr, tcfg.warmup, tcfg.steps)
        self.opt = build_optimizer(model.cfg, sched)
        step_fn = make_train_step(
            model, self.opt, microbatches=model.cfg.microbatches,
            grad_compression=tcfg.grad_compression)
        self.step_fn = jax.jit(
            step_fn, donate_argnums=(0,) if donate else ())
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep,
                                      async_save=tcfg.async_ckpt)
        self.metrics_log: list[dict] = []
        self.events: list[str] = []

    def _init_carry(self, key):
        params = self.model.init(key)
        carry = {"params": params, "opt_state": self.opt.init(params)}
        if self.tcfg.grad_compression:
            carry["grad_error"] = compression.init_error(params)
        return carry

    def run(self, *, fail_at: dict[int, Exception] | None = None) -> dict:
        """Train with auto-restart.  ``fail_at`` injects failures (tests)."""
        tcfg = self.tcfg
        fail_at = dict(fail_at or {})
        restarts = 0
        carry = self._init_carry(jax.random.PRNGKey(tcfg.seed))
        start = 0
        try:
            carry, start = self.ckpt.restore_latest(carry)
            self.events.append(f"resumed from step {start}")
        except FileNotFoundError:
            pass

        step = start
        times: list[float] = []
        while step < tcfg.steps:
            try:
                if step in fail_at:
                    exc = fail_at.pop(step)
                    raise exc
                batch = self.pipeline.batch(step)
                batch = jax.tree.map(jnp.asarray, batch)
                t0 = time.perf_counter()
                carry, metrics = self.step_fn(carry, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
                dt = time.perf_counter() - t0
                # Straggler watchdog: in multi-host this aborts the step
                # group and triggers redistribution; here we record it.
                if times and dt > tcfg.straggler_threshold * (
                        sorted(times)[len(times) // 2]):
                    self.events.append(f"straggler at step {step}: {dt:.3f}s")
                times.append(dt)
                metrics["step"] = step
                metrics["step_time"] = dt
                self.metrics_log.append(metrics)
                step += 1
                if step % tcfg.ckpt_every == 0 or step == tcfg.steps:
                    self.ckpt.save(step, carry)
            except (FloatingPointError, RuntimeError) as e:
                restarts += 1
                self.events.append(f"failure at step {step}: {e!r}")
                if restarts > tcfg.max_restarts:
                    raise
                try:
                    carry = self._init_carry(jax.random.PRNGKey(tcfg.seed))
                    carry, step = self.ckpt.restore_latest(carry)
                    self.events.append(f"restarted from step {step}")
                except FileNotFoundError:
                    carry = self._init_carry(jax.random.PRNGKey(tcfg.seed))
                    step = 0
                    self.events.append("restarted from scratch")
        self.ckpt.wait()
        return {"final_step": step, "restarts": restarts,
                "metrics": self.metrics_log, "events": self.events}
