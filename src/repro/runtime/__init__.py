"""Training runtime: step factories + fault-tolerant driver."""
from repro.runtime.trainer import Trainer, make_train_step  # noqa: F401
