"""Fault-tolerant checkpointing: atomic, async, elastic-reshard on restore."""
from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointManager, restore, save)
