"""Sharded, atomic, async checkpointing with elastic re-shard on restore.

Format: a directory ``step_<N>/`` containing ``arrays.npz`` (flattened
pytree leaves keyed by path) + ``manifest.json`` (step, keys, shapes,
dtypes).  Writes go to ``step_<N>.tmp`` and are ``os.replace``d into place:
a crash mid-write never corrupts the latest checkpoint (fault-tolerance
requirement).  ``CheckpointManager`` adds async background saves, a
retention policy, and latest-step discovery.

Elastic restore: leaves are loaded on host then ``jax.device_put`` with
the *target* sharding - restoring a 256-chip checkpoint onto a 512-chip
(or 8-chip test) mesh re-shards transparently.

Multi-host posture: only process 0 writes (``jax.process_index()``), all
hosts read; on a real cluster the npz would be per-host shards - the
single-file layout keeps the offline container simple and is isolated
behind this module's API.
"""
from __future__ import annotations

import json
import os
import queue
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz cannot store bf16; f32 is
            arr = arr.astype(np.float32)  # lossless and restore re-casts
        flat[key] = arr
    return flat


def save(directory: str, step: int, tree: Any) -> str:
    """Atomic checkpoint write. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if jax.process_index() != 0:
        return final
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "keys": sorted(flat),
        "shapes": {k: list(v.shape) for k, v in flat.items()},
        "dtypes": {k: str(v.dtype) for k, v in flat.items()},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def restore(directory: str, step: int | None, target: Any,
            shardings: Any | None = None) -> tuple[Any, int]:
    """Restore into the structure of ``target``; re-shards if ``shardings``
    (a matching tree of NamedSharding) is given. Returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as data:
        flat = {k: data[k] for k in data.files}

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                    if shardings is not None else [None] * len(leaves_with_path))
    out = []
    for (pathk, leaf), shard in zip(leaves_with_path, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pathk)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key].astype(leaf.dtype) if hasattr(leaf, "dtype") else flat[key]
        if shard is not None:
            arr = jax.device_put(arr, shard)   # elastic re-shard
        out.append(arr)
    return treedef.unflatten(out), step


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(n.split("_")[1]) for n in os.listdir(directory)
             if n.startswith("step_") and not n.endswith(".tmp")]
    return max(steps) if steps else None


class CheckpointManager:
    """Async saves + retention. ``save`` returns immediately; the previous
    pending save is awaited first (single background writer)."""

    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._queue: queue.Queue = queue.Queue(maxsize=1)
        self._worker = None
        self._error: Exception | None = None

    def _run(self, step, host_tree):
        try:
            save(self.directory, step, host_tree)
            self._gc()
        except Exception as e:  # surfaced on next wait()
            self._error = e

    def save(self, step: int, tree: Any):
        self.wait()
        # Snapshot to host memory before returning to the training loop.
        host_tree = jax.tree.map(np.asarray, tree)
        if not self.async_save:
            self._run(step, host_tree)
            return
        self._worker = threading.Thread(target=self._run,
                                        args=(step, host_tree), daemon=True)
        self._worker.start()

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, target, shardings=None):
        self.wait()
        return restore(self.directory, None, target, shardings)
