"""Data pipeline: deterministic, shardable, resumable synthetic LM data."""
from repro.data.pipeline import DataPipeline  # noqa: F401
