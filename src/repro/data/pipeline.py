"""Deterministic synthetic LM data pipeline.

Design goals (cluster posture):
  * *Stateless addressing*: batch(step, shard, num_shards) is a pure
    function of (seed, step, shard) via counter-based RNG (Philox) - any
    worker can regenerate any batch, which is what makes checkpoint-resume
    and elastic re-sharding trivial (no iterator state to save).
  * *Shardable*: each data-parallel rank materializes only its slice.
  * *Structured tokens*: a small Markov-chain "language" (not iid uniform)
    so perplexity actually decreases during the example training runs.

For the stub modality frontends, ``frames``/``patches`` embeddings are
generated the same way.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    family: str = "dense"
    d_model: int = 0
    enc_seq: int = 0
    n_patches: int = 0

    def __post_init__(self):
        # A fixed random Markov chain over a small state space projected
        # into the vocab: learnable structure with long-range repetition.
        rng = np.random.default_rng(self.seed)
        self._states = 64
        raw = rng.random((self._states, self._states)) ** 4
        self._trans = raw / raw.sum(1, keepdims=True)
        self._proj = rng.integers(0, self.vocab_size,
                                  size=(self._states,), dtype=np.int64)

    def _rng(self, step: int, shard: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.Philox(key=self.seed, counter=(step << 20) + shard))

    def batch(self, step: int, shard: int = 0, num_shards: int = 1) -> dict:
        """Batch slice for one data shard at one step (pure function)."""
        assert self.global_batch % num_shards == 0
        b = self.global_batch // num_shards
        rng = self._rng(step, shard)
        states = rng.integers(0, self._states, size=(b,))
        seq = np.empty((b, self.seq_len), dtype=np.int64)
        # Vectorized Markov rollout.
        cum = np.cumsum(self._trans, axis=1)
        for t in range(self.seq_len):
            seq[:, t] = self._proj[states]
            u = rng.random((b, 1))
            states = (u < cum[states]).argmax(axis=1)
        out = {"tokens": seq.astype(np.int32)}
        if self.family == "encdec":
            out["frames"] = rng.standard_normal(
                (b, self.enc_seq, self.d_model)).astype(np.float32)
        if self.family == "vlm":
            out["patches"] = rng.standard_normal(
                (b, self.n_patches, self.d_model)).astype(np.float32)
        return out

    @classmethod
    def for_config(cls, cfg, seq_len: int, global_batch: int, seed: int = 0):
        return cls(vocab_size=cfg.vocab_size, seq_len=seq_len,
                   global_batch=global_batch, seed=seed, family=cfg.family,
                   d_model=cfg.d_model, enc_seq=cfg.enc_seq,
                   n_patches=cfg.n_patches)
