"""Shared neural-net layers (pure JAX, no framework deps).

Every ``*_init`` returns ``(params, logical)`` - two parallel pytrees, the
second holding tuples of logical axis names consumed by
:mod:`repro.parallel.sharding`.  ``*_apply`` functions are pure.

Attention dispatches to the H-FA / FA-2 kernel stack via
:mod:`repro.kernels.ops` - the paper's contribution is a first-class layer
here, selected per-config with ``attn_impl``.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops


def _init_dense(key, shape, scale=None, dtype=jnp.float32):
    scale = 1.0 / math.sqrt(shape[0]) if scale is None else scale
    return jax.random.normal(key, shape, dtype) * scale


# ---------------------------------------------------------------- norms
def rmsnorm_init(d: int, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}, {"scale": ("embed",)}


def rmsnorm_apply(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(d: int, dtype=jnp.float32):
    return ({"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)},
            {"scale": ("embed",), "bias": ("embed",)})


def layernorm_apply(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------- embeddings
def embedding_init(key, vocab: int, d: int, dtype=jnp.float32):
    w = jax.random.normal(key, (vocab, d), dtype) * 0.02
    return {"emb": w}, {"emb": ("vocab", "fsdp")}


def embedding_lookup(p, ids):
    return jnp.take(p["emb"], ids, axis=0)


def sinusoidal_pos(seq: int, d: int, offset=0) -> jax.Array:
    """Sinusoidal position embeddings; ``offset`` may be traced (decode)."""
    pos = (jnp.arange(seq, dtype=jnp.float32) + offset)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10000.0, dim / d)
    half = jnp.stack([jnp.sin(angle), jnp.cos(angle)], axis=-1)
    return half.reshape(seq, d)


# ---------------------------------------------------------------- RoPE
def rope_apply(x: jax.Array, positions: jax.Array, theta: float = 10000.0):
    """Rotary embedding. x: (B, S, H, dh), positions: (B, S) or (S,)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- attention
def attention_init(key, cfg, dtype=jnp.float32, cross: bool = False):
    """GQA attention params. cfg needs d_model, n_heads, n_kv_heads, d_head,
    qkv_bias, qk_norm."""
    d, h, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": _init_dense(ks[0], (d, h, dh), 1.0 / math.sqrt(d), dtype),
        "wk": _init_dense(ks[1], (d, hkv, dh), 1.0 / math.sqrt(d), dtype),
        "wv": _init_dense(ks[2], (d, hkv, dh), 1.0 / math.sqrt(d), dtype),
        "wo": _init_dense(ks[3], (h, dh, d), 1.0 / math.sqrt(h * dh), dtype),
    }
    l = {
        "wq": ("fsdp", "heads", "head_dim"),
        "wk": ("fsdp", "kv_heads", "head_dim"),
        "wv": ("fsdp", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "fsdp"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((hkv, dh), dtype)
        p["bv"] = jnp.zeros((hkv, dh), dtype)
        l["bq"] = ("heads", "head_dim")
        l["bk"] = ("kv_heads", "head_dim")
        l["bv"] = ("kv_heads", "head_dim")
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
        l["q_norm"] = ("head_dim",)
        l["k_norm"] = ("head_dim",)
    return p, l


def _head_rmsnorm(scale, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def attention_apply(
    p,
    x: jax.Array,                    # (B, S, d_model)
    cfg,
    *,
    positions: jax.Array | None = None,
    kv_input: jax.Array | None = None,   # cross-attention source
    cache: dict[str, jax.Array] | None = None,
    cache_pos: jax.Array | int | None = None,
    causal: bool = True,
    attn_impl: str | None = None,
    page_state: dict[str, jax.Array] | None = None,
):
    """Returns (out (B,S,d_model), new_cache).

    ``cache`` is either a dense ring {"k", "v"} or a paged block pool
    {"k_pages", "v_pages"}; the paged form additionally needs
    ``page_state`` = {"page_table" (B, J), "seq_lens" (B,)} from the
    serving engine (seq_lens[b] == 0 marks a free slot).
    """
    b, s, _ = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    impl = attn_impl or cfg.attn_impl
    src = x if kv_input is None else kv_input

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = _head_rmsnorm(p["q_norm"], q)
        k = _head_rmsnorm(p["k_norm"], k)
    if cfg.pos_emb == "rope" and kv_input is None:
        if positions is None:
            base = 0 if cache_pos is None else cache_pos
            positions = base + jnp.arange(s)
            if positions.ndim == 1:
                positions = jnp.broadcast_to(positions[None], (b, s))
        q = rope_apply(q, positions, cfg.rope_theta)
        k = rope_apply(k, positions, cfg.rope_theta)

    if cache is not None and "k_pages" in cache and kv_input is None:
        return _paged_attention(p, q, k, v, cfg, cache, page_state,
                                impl=impl, causal=causal, x_dtype=x.dtype)

    new_cache = cache
    if cache is not None and kv_input is None:
        # Decode / incremental: write into the ring at cache_pos.
        pos = cache_pos if cache_pos is not None else 0
        if s == 1 and cfg.serve_attn == "shardmap_merge":
            # Paper's multi-KV-block ACC merge across the "model" axis:
            # local ring write + partial FAU + log-domain merge.
            from repro.parallel import collectives, sharding
            mesh = sharding._ACTIVE["mesh"]
            if mesh is not None and "model" in mesh.shape and \
                    cache["k"].shape[1] % mesh.shape["model"] == 0:
                out, ck, cv = collectives.shardmap_decode_attention(
                    q, k, v, cache["k"], cache["v"],
                    jnp.asarray(pos, jnp.int32), mesh=mesh,
                    use_hfa=impl.startswith("hfa"))
                out = jnp.einsum("bshk,hkd->bsd", out,
                                 p["wo"].astype(x.dtype))
                return out, {"k": ck, "v": cv}
        if s == 1:
            # Select-based write: elementwise, so it PRESERVES the cache's
            # sequence sharding (a dynamic-update-slice at a traced position
            # on a sharded dim makes the SPMD partitioner all-gather the
            # whole ring).  Costs a full cache rewrite in HBM bytes -
            # addressed by the shard_map local-write path in §Perf.
            hit = (jnp.arange(cache["k"].shape[1]) == pos)[None, :, None, None]
            ck = jnp.where(hit, k.astype(cache["k"].dtype), cache["k"])
            cv = jnp.where(hit, v.astype(cache["v"].dtype), cache["v"])
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        if s == 1:
            out = kops.decode_attention(q, ck, cv, impl=_decode_impl(impl),
                                        kv_len=pos + 1)
            out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
            return out, new_cache
        # Fresh prefill (pos == 0): attend causally within the chunk itself;
        # the cache is storage only.  Continued chunked prefill (pos > 0)
        # must go through decode steps (documented limitation).

    out = kops.multihead_attention(q, k, v, impl=impl, causal=causal,
                                   block_q=cfg.attn_block,
                                   block_kv=cfg.attn_block)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return out, new_cache


def _paged_attention(p, q, k, v, cfg, cache, page_state, *, impl, causal,
                     x_dtype):
    """Attention against a paged block-pool KV cache (serving path).

    Decode: append the new token's K/V at seq_lens[b] through the page
    table, then run the paged decode kernel / jnp gather path over each
    slot's pages.  Chunked prefill (page_state carries "start_pos"):
    scatter the chunk's K/V at positions start_pos[b].. (padding rows
    dropped, so shared copy-on-write pages stay intact), then attend the
    chunk causally against everything materialized for its sequence -
    shared prefix pages, earlier chunks, and the chunk itself.  Legacy
    fresh prefill (no "start_pos": whole prompt at position 0 - a
    1-token prompt is still a prefill): the chunk attends causally to
    itself - the pages are storage only - and K/V land at positions
    0..S-1 of each row's page table; padded prefill tails are later
    masked by seq_lens, and are overwritten in place by later appends.

    Tensor parallel (page_state carries a "mesh" with a "model" axis of
    size > 1): the pools are KV-head-sharded over the mesh and every
    branch routes through the shard_map cascaded-ACC-merge path
    (:func:`repro.parallel.collectives.shardmap_paged_attention`) -
    each shard scatters/attends its local heads and only the tiny
    (m, l, o~) triplets cross the interconnect.
    """
    from repro.kernels import page_codec
    from repro.kernels import paged_decode as paged_k
    from repro.kernels import paged_prefill as paged_pf_k
    assert page_state is not None, "paged cache requires page_state"
    pt = page_state["page_table"]
    mesh = page_state.get("mesh")
    codec = page_codec.get_codec(page_state.get("codec"))
    # The fp codec's read path is kept on codec=None so the raw-pool
    # kernels/fallbacks run byte-for-byte unchanged (fp stays bit-exact
    # to the pre-codec pool); encode_write is already the identity.
    rcodec = None if codec.name == "fp" else codec
    if mesh is not None and (mesh.shape.get("model", 1) > 1
                             or mesh.shape.get("data", 1) > 1):
        from repro.parallel import collectives
        if page_state.get("verify", False):
            mode, la, lb = ("verify", page_state["seq_lens"],
                            page_state["chunk_lens"])
        elif not page_state.get("prefill", False):
            sl = page_state["seq_lens"]
            mode, la, lb = "decode", sl, jnp.zeros_like(sl)
        elif "start_pos" in page_state:
            mode, la, lb = ("prefill", page_state["start_pos"],
                            page_state["chunk_lens"])
        else:
            # Legacy whole-prompt fresh prefill: positions 0..L-1, all
            # rows written in full (padded tails masked by seq_lens).
            b_, l_ = q.shape[0], q.shape[1]
            mode = "prefill"
            la = jnp.zeros((b_,), jnp.int32)
            lb = jnp.full((b_,), l_, jnp.int32)
        out, new_pools = collectives.shardmap_paged_attention(
            q, k, v, cache, pt, la, lb,
            mesh=mesh, mode=mode, impl=_decode_impl(impl), codec=codec)
    elif page_state.get("verify", False):
        # Speculative multi-token verify: scatter the K step tokens at
        # positions seq_lens[b].. (rows past chunk_lens are dropped, so
        # shared pages stay intact), then score all K positions in one
        # page-table walk.  K == 1 degenerates to the decode path.
        sl = page_state["seq_lens"]
        cl = page_state["chunk_lens"]
        new_pools = page_codec.encode_write(
            paged_pf_k.write_chunk_kv, codec, cache, k, v, pt, sl, cl)
        out = kops.paged_verify_attention(
            q, new_pools["k_pages"], new_pools["v_pages"], pt, sl, cl,
            impl=_decode_impl(impl), codec=rcodec,
            k_scales=new_pools.get("k_scale"),
            v_scales=new_pools.get("v_scale"))
    elif not page_state.get("prefill", False):
        sl = page_state["seq_lens"]
        new_pools = page_codec.encode_write(
            paged_k.append_kv, codec, cache, k, v, pt, sl)
        kv_lens = jnp.where(sl > 0, sl + 1, 0)
        out = kops.paged_decode_attention(
            q, new_pools["k_pages"], new_pools["v_pages"], pt, kv_lens,
            impl=_decode_impl(impl), codec=rcodec,
            k_scales=new_pools.get("k_scale"),
            v_scales=new_pools.get("v_scale"))
    elif "start_pos" in page_state:
        sp = page_state["start_pos"]
        cl = page_state["chunk_lens"]
        new_pools = page_codec.encode_write(
            paged_pf_k.write_chunk_kv, codec, cache, k, v, pt, sp, cl)
        out = kops.paged_prefill_attention(
            q, new_pools["k_pages"], new_pools["v_pages"], pt, sp, cl,
            impl=_decode_impl(impl), codec=rcodec,
            k_scales=new_pools.get("k_scale"),
            v_scales=new_pools.get("v_scale"))
    else:
        # Legacy fresh prefill: pages are storage only - attention runs
        # on the raw chunk, so the codec only affects later reads.
        new_pools = page_codec.encode_write(
            paged_k.write_prefill_kv, codec, cache, k, v, pt)
        out = kops.multihead_attention(q, k, v, impl=impl, causal=causal,
                                       block_q=cfg.attn_block,
                                       block_kv=cfg.attn_block)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x_dtype))
    return out, new_pools


def _decode_impl(impl: str) -> str:
    # Pallas prefill kernels pair with their decode counterparts.
    return {"fa2": "fa2", "exact": "fa2", "hfa": "hfa_pallas",
            "fa2_pallas": "fa2_pallas", "hfa_pallas": "hfa_pallas",
            "hfa_datapath": "hfa_pallas"}.get(impl, "fa2")


# ---------------------------------------------------------------- MLPs
def swiglu_init(key, d: int, ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    p = {
        "wg": _init_dense(ks[0], (d, ff), dtype=dtype),
        "wu": _init_dense(ks[1], (d, ff), dtype=dtype),
        "wd": _init_dense(ks[2], (ff, d), dtype=dtype),
    }
    l = {"wg": ("fsdp", "mlp"), "wu": ("fsdp", "mlp"), "wd": ("mlp", "fsdp")}
    return p, l


def swiglu_apply(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(x.dtype))
    y = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", y, p["wd"].astype(x.dtype))


def gelu_mlp_init(key, d: int, ff: int, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    p = {"wi": _init_dense(ks[0], (d, ff), dtype=dtype),
         "bi": jnp.zeros((ff,), dtype),
         "wo": _init_dense(ks[1], (ff, d), dtype=dtype),
         "bo": jnp.zeros((d,), dtype)}
    l = {"wi": ("fsdp", "mlp"), "bi": ("mlp",),
         "wo": ("mlp", "fsdp"), "bo": ("embed",)}
    return p, l


def gelu_mlp_apply(p, x):
    y = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype)) + p["bi"].astype(x.dtype)
    y = jax.nn.gelu(y.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsf,fd->bsd", y, p["wo"].astype(x.dtype)) + p["bo"].astype(x.dtype)
