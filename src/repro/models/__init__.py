"""Model zoo: shared layers + family builders for the assigned archs."""
