"""Model facade: init/apply/caches/loss for every assigned family.

``build_model(cfg)`` returns an ``LM`` whose methods are pure functions
suitable for jit/pjit:

  init(key) -> params
  shape_and_logical() -> (ShapeDtypeStruct tree, logical-axes tree)
  apply(params, batch, train=True) -> (logits, aux)
  loss(params, batch) -> (scalar, metrics)
  init_cache(params_or_shapes, batch, max_seq, enc_out=None) -> cache
  decode_step(params, cache, tokens) -> (logits, new_cache)

Batch dicts per family:
  dense/moe/ssm/hybrid: {"tokens": (B,S) int32}
  vlm:    {"tokens": (B,S), "patches": (B,P,d_model)}   (stub frontend)
  encdec: {"tokens": (B,S), "frames": (B,T,d_model)}    (stub frontend)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import transformer as T
from repro.parallel.sharding import constrain

AUX_COEF = {"load_balance": 0.01, "router_z": 0.001}


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------- init
    def _init(self, key):
        cfg = self.cfg
        pdt = _dtype(cfg.param_dtype)
        ks = jax.random.split(key, 8)
        p: dict[str, Any] = {}
        l: dict[str, Any] = {}
        p["embed"], l["embed"] = L.embedding_init(ks[0], cfg.padded_vocab,
                                                  cfg.d_model, pdt)
        cross = cfg.family == "encdec"
        p["layers"], l["layers"] = T.stack_init(ks[1], cfg, pdt, cross=cross)
        p["final_norm"], l["final_norm"] = T._norm_init(cfg, pdt)
        if not cfg.tie_embeddings:
            p["lm_head"] = jax.random.normal(
                ks[2], (cfg.d_model, cfg.padded_vocab), pdt) * 0.02
            l["lm_head"] = ("fsdp", "vocab")
        if cfg.pos_emb == "learned":
            p["pos_emb"] = jax.random.normal(
                ks[3], (cfg.max_seq, cfg.d_model), pdt) * 0.02
            l["pos_emb"] = ("seq", "embed")
        if cross:
            enc_cfg = self._enc_cfg()
            p["enc_layers"], l["enc_layers"] = T.stack_init(
                ks[4], enc_cfg, pdt, cross=False)
            p["enc_norm"], l["enc_norm"] = T._norm_init(enc_cfg, pdt)
        return p, l

    def _enc_cfg(self):
        import dataclasses
        return dataclasses.replace(
            self.cfg, family="dense", n_layers=self.cfg.n_enc_layers,
            pos_emb="sinusoidal", n_experts=0, attn_every=0)

    def init(self, key):
        return self._init(key)[0]

    def shape_and_logical(self):
        captured = {}

        def f(key):
            p, l = self._init(key)
            captured["l"] = l
            return p

        shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
        return shapes, captured["l"]

    # ------------------------------------------------------------ forward
    def _embed_in(self, params, tokens, cdt, pos0=0):
        cfg = self.cfg
        x = L.embedding_lookup(params["embed"], tokens).astype(cdt)
        if cfg.pos_emb == "learned":
            pe = jax.lax.dynamic_slice_in_dim(
                params["pos_emb"], pos0, tokens.shape[1], axis=0)
            x = x + pe.astype(cdt)[None]
        elif cfg.pos_emb == "sinusoidal":
            x = x + L.sinusoidal_pos(tokens.shape[1], cfg.d_model,
                                     pos0).astype(cdt)[None]
        return x

    def _encode(self, params, frames, cdt):
        """Whisper encoder over precomputed (stub) frame embeddings."""
        cfg = self._enc_cfg()
        x = frames.astype(cdt)
        x = x + L.sinusoidal_pos(x.shape[1], cfg.d_model).astype(cdt)[None]
        x, _, _ = T.stack_apply(params["enc_layers"], x, cfg, causal=False)
        return T._norm_apply(cfg, params["enc_norm"], x)

    def apply(self, params, batch, train: bool = True):
        cfg = self.cfg
        cdt = _dtype(cfg.compute_dtype)
        tokens = batch["tokens"]
        enc_caches = None

        if cfg.family == "encdec":
            enc_out = self._encode(params, batch["frames"], cdt)
            _, enc_caches = T.stack_init_cache(
                cfg, tokens.shape[0], 0, cdt, cross=True, enc_out=enc_out,
                params=params["layers"])
            x = self._embed_in(params, tokens, cdt)
        elif cfg.family == "vlm":
            patches = batch["patches"].astype(cdt)     # (B, P, d) stub
            tok = self._embed_in(params, tokens, cdt)
            x = jnp.concatenate([patches, tok], axis=1)
        else:
            x = self._embed_in(params, tokens, cdt)

        x = constrain(x, ("batch", "seq", "embed"))
        x, _, aux = T.stack_apply(params["layers"], x, cfg,
                                  enc_caches=enc_caches, causal=True)
        x = T._norm_apply(cfg, params["final_norm"], x)
        if cfg.family == "vlm":
            x = x[:, batch["patches"].shape[1]:]       # logits on text only
        logits = self._head(params, x)
        return logits, aux

    def _head(self, params, x):
        cfg = self.cfg
        w = (params["embed"]["emb"].T if cfg.tie_embeddings
             else params["lm_head"])
        logits = jnp.einsum("bsd,dv->bsv", x, w.astype(x.dtype))
        # vocab sharding takes priority over SP on the seq dim here: the
        # f32 loss intermediates are V/16-sharded instead.
        return constrain(logits, ("batch", None, "vocab"))

    # ------------------------------------------------------------- loss
    def loss(self, params, batch):
        logits, aux = self.apply(params, batch)
        tokens = batch["tokens"]
        lg = logits[:, :-1].astype(jnp.float32)
        tg = tokens[:, 1:]
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, tg[..., None], axis=-1)[..., 0]
        mask = (tg >= 0).astype(jnp.float32)
        nll = jnp.sum((lse - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        total = nll
        metrics = {"nll": nll}
        for k, v in aux.items():
            coef = AUX_COEF.get(k, 0.0)
            total = total + coef * v
            metrics[k] = v
        metrics["loss"] = total
        return total, metrics

    # ------------------------------------------------------------- decode
    def init_cache(self, params, batch: int, max_seq: int, enc_out=None):
        cfg = self.cfg
        cdt = _dtype(cfg.compute_dtype)
        cross = cfg.family == "encdec"
        caches, enc_caches = T.stack_init_cache(
            cfg, batch, max_seq, cdt, cross=cross, enc_out=enc_out,
            params=params["layers"] if cross else None)
        cache = {"layers": caches, "pos": jnp.int32(0)}
        if enc_caches is not None:
            cache["enc"] = enc_caches
        return cache

    def prefill(self, params, cache, tokens, prefix_embeds=None):
        """Write a prompt into the cache; logits for its last position.

        Must be called at cache position 0 (fresh prefill).  For VLM,
        ``prefix_embeds`` (B, P, d) are concatenated before the tokens.
        """
        cfg = self.cfg
        cdt = _dtype(cfg.compute_dtype)
        x = self._embed_in(params, tokens, cdt, pos0=0)
        if prefix_embeds is not None:
            x = jnp.concatenate([prefix_embeds.astype(cdt), x], axis=1)
        x = constrain(x, ("batch", "seq", "embed"))
        x, new_caches, _ = T.stack_apply(
            params["layers"], x, cfg, caches=cache["layers"],
            cache_pos=0, enc_caches=cache.get("enc"), causal=True)
        x = T._norm_apply(cfg, params["final_norm"], x[:, -1:])
        logits = self._head(params, x)
        out = dict(cache)
        out["layers"] = new_caches
        n_prefix = 0 if prefix_embeds is None else prefix_embeds.shape[1]
        out["pos"] = cache["pos"] + tokens.shape[1] + n_prefix
        return logits, out

    def decode_step(self, params, cache, tokens):
        """One token: tokens (B, 1) -> (logits (B,1,V), new cache)."""
        cfg = self.cfg
        cdt = _dtype(cfg.compute_dtype)
        pos = cache["pos"]
        x = self._embed_in(params, tokens, cdt, pos0=pos)
        x = constrain(x, ("batch", None, "embed"))
        x, new_caches, _ = T.stack_apply(
            params["layers"], x, cfg, caches=cache["layers"], cache_pos=pos,
            enc_caches=cache.get("enc"), causal=True)
        x = T._norm_apply(cfg, params["final_norm"], x)
        logits = self._head(params, x)
        out = dict(cache)
        out["layers"] = new_caches
        out["pos"] = pos + 1
        return logits, out


    # ------------------------------------------------ paged decode (serving)
    def init_paged_cache(self, num_pages: int, page_size: int, mesh=None,
                         codec: str = "fp"):
        """Shared block-pool KV caches for continuous-batching decode.

        Unlike :meth:`init_cache` there is no per-slot ``max_seq``
        reservation: all slots draw pages from one pool via the
        engine-owned page table.  RoPE-positioned attention-only stacks
        (the positions come from per-slot seq_lens, not a global
        cache_pos; learned/sinusoidal embeddings would need per-slot
        embed offsets).

        With ``mesh`` (a "model" axis of size tp > 1) the pools are
        placed KV-head-sharded over the mesh: every shard keeps the full
        page layout but only ``n_kv_heads / tp`` heads, so per-shard
        pool HBM shrinks by tp while the host page tables (and all the
        refcount/COW/prefix-cache bookkeeping) stay replicated.

        ``codec`` selects the page codec ("fp" | "int8" | "log16", see
        :mod:`repro.kernels.page_codec`): the pools take the codec's
        storage dtype and quantized codecs add f32 scale sidecar pools;
        the same NamedSharding placement covers every leaf (scale
        sidecars share the data pools' rank and Hkv axis).
        """
        cfg = self.cfg
        assert cfg.pos_emb == "rope", (
            "paged serving requires rope positions, got %r" % cfg.pos_emb)
        cdt = _dtype(cfg.compute_dtype)
        layers = T.stack_init_paged_cache(cfg, num_pages, page_size, cdt,
                                          codec=codec)
        tp = 1 if mesh is None else int(mesh.shape.get("model", 1))
        if tp > 1:
            if cfg.n_kv_heads % tp or cfg.n_heads % tp:
                raise ValueError(
                    f"paged TP requires heads divisible by tp: "
                    f"n_kv_heads={cfg.n_kv_heads}, n_heads={cfg.n_heads}, "
                    f"tp={tp}")
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            # Stacked pools are (groups, P, page, Hkv, dh): head axis 3.
            sh = NamedSharding(mesh, P(None, None, None, "model", None))
            layers = jax.device_put(layers, sh)
        return layers

    def paged_prefill(self, params, layers, tokens, page_table,
                      last_pos=None, start_pos=None, mesh=None,
                      codec: str = "fp", return_all_logits: bool = False):
        """Prefill sequences into paged KV storage.

        tokens: (B, L) token rows padded to a common length L.
        page_table: (B, J) rows with pages allocated for the positions
        being written.
        last_pos: optional (B,) int32 - each row's last *real* position
        within ``tokens``; when given, the LM head runs only there and
        logits are (B, 1, V) (the padded-vocab projection over every
        padded position is the dominant prefill cost at full scale).
        Without it, logits cover all positions: (B, L, V).
        start_pos: optional (B,) int32 - *chunked* prefill: row b is a
        chunk of ``last_pos[b] + 1`` real tokens starting at absolute
        position ``start_pos[b]`` (pos > 0 resumes a paused or
        budget-bounded prefill).  The chunk attends causally against all
        KV already written for its sequence (shared prefix pages +
        earlier chunks + itself); padding rows are never written.
        Requires ``last_pos``.  Without it, the legacy whole-prompt
        fresh prefill at position 0 runs (padded tail KV is masked by
        seq_lens and overwritten by later appends).
        mesh: optional tensor-parallel mesh (a "model" axis > 1 routes
        attention through the KV-head-sharded cascaded-ACC-merge path).
        return_all_logits: keep logits at every position even when
        ``last_pos`` is given - the prompt-logprobs path pays the full
        (B, L, V) projection to score each prompt token.
        Returns (logits, new layer caches).
        """
        cfg = self.cfg
        cdt = _dtype(cfg.compute_dtype)
        x = self._embed_in(params, tokens, cdt, pos0=0)
        x = constrain(x, ("batch", "seq", "embed"))
        if start_pos is None:
            positions = None
            ps = {"page_table": page_table, "prefill": True, "mesh": mesh,
                  "codec": codec,
                  "seq_lens": jnp.zeros((tokens.shape[0],), jnp.int32)}
        else:
            assert last_pos is not None, "chunked prefill needs last_pos"
            # Positions reach attention via `positions`, which only RoPE
            # consumes; learned/sinusoidal embeds would need a per-row
            # embedding offset (pos0 is scalar) and silently misplace
            # any chunk at start_pos > 0.
            assert cfg.pos_emb == "rope", (
                "chunked paged prefill requires rope positions, got %r"
                % cfg.pos_emb)
            start_pos = start_pos.astype(jnp.int32)
            positions = start_pos[:, None] + jnp.arange(
                tokens.shape[1], dtype=jnp.int32)[None]
            ps = {"page_table": page_table, "prefill": True, "mesh": mesh,
                  "codec": codec, "start_pos": start_pos,
                  "chunk_lens": last_pos.astype(jnp.int32) + 1}
        x, new_layers, _ = T.stack_apply(
            params["layers"], x, cfg, positions=positions, caches=layers,
            cache_pos=0, page_state=ps, causal=True)
        if last_pos is not None and not return_all_logits:
            x = jnp.take_along_axis(x, last_pos[:, None, None].astype(
                jnp.int32), axis=1)
        x = T._norm_apply(cfg, params["final_norm"], x)
        return self._head(params, x), new_layers

    def paged_verify_step(self, params, layers, tokens, page_table,
                          seq_lens, chunk_lens, mesh=None,
                          codec: str = "fp"):
        """K-token speculative verify step across every slot.

        tokens: (B, K) input tokens per slot - the carry token followed
        by up to K-1 drafted continuations, landing at positions
        ``seq_lens[b] + i``.  chunk_lens: (B,) int32 real input count
        per slot (0 = free / mid-prefill slot: nothing is written and
        its logits are garbage to be ignored; rows at i >= chunk_lens
        are likewise garbage).  Writes KV for the real inputs and
        returns (logits (B, K, V), new layer caches) - the logits at
        every verify position, scored in one paged-attention call.
        With K == 1 this is exactly :meth:`paged_decode_step`.
        """
        cfg = self.cfg
        cdt = _dtype(cfg.compute_dtype)
        x = self._embed_in(params, tokens, cdt, pos0=0)
        x = constrain(x, ("batch", None, "embed"))
        seq_lens = seq_lens.astype(jnp.int32)
        positions = seq_lens[:, None] + jnp.arange(
            tokens.shape[1], dtype=jnp.int32)[None]
        ps = {"page_table": page_table, "seq_lens": seq_lens, "mesh": mesh,
              "codec": codec,
              "chunk_lens": chunk_lens.astype(jnp.int32), "verify": True}
        x, new_layers, _ = T.stack_apply(
            params["layers"], x, cfg, positions=positions, caches=layers,
            page_state=ps, causal=True)
        x = T._norm_apply(cfg, params["final_norm"], x)
        return self._head(params, x), new_layers

    def paged_decode_step(self, params, layers, tokens, page_table,
                          seq_lens, mesh=None, codec: str = "fp"):
        """One continuous-batching decode step across every slot.

        tokens: (B, 1) next input token per slot; seq_lens: (B,) int32
        current length per slot (0 = free slot: its write is dropped and
        its logits are garbage to be ignored).  Appends each active
        token's KV at position seq_lens[b] and returns
        (logits (B, 1, V), new layer caches).
        """
        cfg = self.cfg
        cdt = _dtype(cfg.compute_dtype)
        x = self._embed_in(params, tokens, cdt, pos0=0)
        x = constrain(x, ("batch", None, "embed"))
        ps = {"page_table": page_table, "seq_lens": seq_lens, "mesh": mesh,
              "codec": codec}
        x, new_layers, _ = T.stack_apply(
            params["layers"], x, cfg, positions=seq_lens[:, None],
            caches=layers, page_state=ps, causal=True)
        x = T._norm_apply(cfg, params["final_norm"], x)
        return self._head(params, x), new_layers


def build_model(cfg: ModelConfig) -> LM:
    return LM(cfg)
