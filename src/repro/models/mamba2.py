"""Mamba-2 (SSD, state-space duality) mixer layer - arXiv:2405.21060.

Chunked SSD forward for training/prefill (sub-quadratic: intra-chunk
matmul + inter-chunk state recurrence via lax.scan) and a constant-memory
single-token decode step.  Separate z/x/B/C/dt projections keep every
tensor axis cleanly shardable (d_inner and heads over "model").

Note (DESIGN.md Arch-applicability): Mamba has no softmax, so the paper's
H-FA technique does not apply inside this mixer.  The inter-chunk state
pass reuses the same carry/merge structure as the attention block-merge,
but in linear domain.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import _init_dense, rmsnorm_apply


def mamba_init(key, cfg, dtype=jnp.float32):
    d = cfg.d_model
    din = cfg.m_expand * d
    h = din // cfg.m_headdim
    gn = cfg.m_ngroups * cfg.m_dstate
    cw = cfg.m_conv
    ks = jax.random.split(key, 9)
    p = {
        "wz": _init_dense(ks[0], (d, din), dtype=dtype),
        "wx": _init_dense(ks[1], (d, din), dtype=dtype),
        "wB": _init_dense(ks[2], (d, gn), dtype=dtype),
        "wC": _init_dense(ks[3], (d, gn), dtype=dtype),
        "wdt": _init_dense(ks[4], (d, h), dtype=dtype),
        "conv_x": _init_dense(ks[5], (cw, din), 1.0 / math.sqrt(cw), dtype),
        "conv_B": _init_dense(ks[6], (cw, gn), 1.0 / math.sqrt(cw), dtype),
        "conv_C": _init_dense(ks[7], (cw, gn), 1.0 / math.sqrt(cw), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "norm": jnp.ones((din,), dtype),
        "wo": _init_dense(ks[8], (din, d), 1.0 / math.sqrt(din), dtype),
    }
    l = {
        "wz": ("fsdp", "mamba_inner"), "wx": ("fsdp", "mamba_inner"),
        "wB": ("fsdp", "mamba_state"), "wC": ("fsdp", "mamba_state"),
        "wdt": ("fsdp", "mamba_heads"),
        "conv_x": ("conv", "mamba_inner"), "conv_B": ("conv", "mamba_state"),
        "conv_C": ("conv", "mamba_state"),
        "A_log": ("mamba_heads",), "D": ("mamba_heads",),
        "dt_bias": ("mamba_heads",),
        "norm": ("mamba_inner",),
        "wo": ("mamba_inner", "fsdp"),
    }
    return p, l


def _causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. x: (B,S,C), w: (W,C). Returns (y, new_state).

    ``state`` is the trailing (W-1,C) window from the previous call (decode).
    """
    bw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], bw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # (B, S+W-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(bw))
    new_state = xp[:, -(bw - 1):, :] if bw > 1 else pad
    return y, new_state


def ssd_chunked(u, dA, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    u:  (B,S,H,P) dt-weighted inputs
    dA: (B,S,H)   log-decay increments (<= 0)
    Bm: (B,S,H,N) input maps;  Cm: (B,S,H,N) output maps
    Returns y (B,S,H,P) and the final state (B,H,N,P).
    """
    b, s, h, pdim = u.shape
    n = Bm.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    def ck(x):
        return x.reshape((b, nc, chunk) + x.shape[2:])

    uc, dAc, Bc, Cc = ck(u), ck(dA), ck(Bm), ck(Cm)
    cs = jnp.cumsum(dAc, axis=2)                         # (B,nc,Q,H)
    # Intra-chunk (the 'attention-like' quadratic-in-Q term).
    att = jnp.einsum("bcqhn,bcthn->bchqt", Cc, Bc)
    ldiff = cs[:, :, :, None, :] - cs[:, :, None, :, :]  # (B,nc,Q,T,H)
    ldiff = jnp.moveaxis(ldiff, -1, 2)                   # (B,nc,H,Q,T)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    # Mask BEFORE exp: above the diagonal ldiff is positive and exp would
    # overflow, poisoning gradients through the where.
    decay = jnp.exp(jnp.where(causal, ldiff, -1e9))
    y_intra = jnp.einsum("bchqt,bcthp->bcqhp", att * decay, uc)

    # Per-chunk outgoing state and total decay.
    dte = jnp.exp(cs[:, :, -1:, :] - cs)                 # decay to chunk end
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", dte, Bc, uc)
    tot = jnp.exp(cs[:, :, -1, :])                       # (B,nc,H)

    # Inter-chunk recurrence.
    def step(hstate, inp):
        st, tt = inp
        out = hstate
        hstate = hstate * tt[..., None, None] + st
        return hstate, out

    init = jnp.zeros((b, h, n, pdim), jnp.float32)
    final, h_in = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(tot, 1, 0).astype(jnp.float32)))
    h_in = jnp.moveaxis(h_in, 0, 1)                      # state entering chunk

    y_inter = jnp.einsum("bcqhn,bchnp->bcqhp", Cc,
                         h_in.astype(Cc.dtype)) * jnp.exp(cs)[..., None]
    y = (y_intra + y_inter).reshape(b, s, h, pdim)
    return y, final


def mamba_apply(p, x, cfg, *, state=None, chunk: int | None = None):
    """x: (B,S,d_model). state: None (train) or dict {ssm, conv_x/B/C}.

    Returns (out, new_state).  With ``state`` given and S small (decode),
    runs the recurrent step; otherwise the chunked scan.
    """
    b, s, d = x.shape
    din = cfg.m_expand * d
    h = din // cfg.m_headdim
    pdim = cfg.m_headdim
    n = cfg.m_dstate
    dt_limit = (1e-3, 1e2)

    z = jnp.einsum("bsd,de->bse", x, p["wz"].astype(x.dtype))
    xr = jnp.einsum("bsd,de->bse", x, p["wx"].astype(x.dtype))
    Br = jnp.einsum("bsd,de->bse", x, p["wB"].astype(x.dtype))
    Cr = jnp.einsum("bsd,de->bse", x, p["wC"].astype(x.dtype))
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"].astype(x.dtype))

    cs = {} if state is None else state
    xr, cx = _causal_conv(xr, p["conv_x"].astype(x.dtype), cs.get("conv_x"))
    Br, cB = _causal_conv(Br, p["conv_B"].astype(x.dtype), cs.get("conv_B"))
    Cr, cC = _causal_conv(Cr, p["conv_C"].astype(x.dtype), cs.get("conv_C"))
    xr = jax.nn.silu(xr.astype(jnp.float32))
    Br = jax.nn.silu(Br.astype(jnp.float32))
    Cr = jax.nn.silu(Cr.astype(jnp.float32))

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    dt = jnp.clip(dt, dt_limit[0], dt_limit[1])          # (B,S,H)
    A = -jnp.exp(p["A_log"])                             # (H,)
    dA = dt * A                                          # (B,S,H), <= 0

    xh = xr.reshape(b, s, h, pdim)
    u = xh * dt[..., None]
    # ngroups == 1: broadcast B/C across heads.
    Bm = jnp.broadcast_to(Br.reshape(b, s, 1, n), (b, s, h, n))
    Cm = jnp.broadcast_to(Cr.reshape(b, s, 1, n), (b, s, h, n))

    if state is not None and s == 1:
        hst = cs["ssm"]                                   # (B,H,N,P)
        decay = jnp.exp(dA[:, 0])                         # (B,H)
        upd = jnp.einsum("bhn,bhp->bhnp", Bm[:, 0], u[:, 0])
        hst = hst * decay[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", Cm[:, 0], hst)[:, None]
        new_state = {"ssm": hst, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    else:
        ch = chunk or min(cfg.m_chunk, s)
        y, hst = ssd_chunked(u, dA, Bm, Cm, ch)
        new_state = {"ssm": hst, "conv_x": cx, "conv_B": cB, "conv_C": cC}

    y = y + xh.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, din)
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rmsnorm_apply({"scale": p["norm"]}, y.astype(x.dtype))
    out = jnp.einsum("bse,ed->bsd", y, p["wo"].astype(x.dtype))
    return out, new_state


def mamba_init_state(cfg, batch: int, dtype=jnp.float32):
    d = cfg.d_model
    din = cfg.m_expand * d
    h = din // cfg.m_headdim
    gn = cfg.m_ngroups * cfg.m_dstate
    cw = cfg.m_conv
    return {
        "ssm": jnp.zeros((batch, h, cfg.m_dstate, cfg.m_headdim), jnp.float32),
        "conv_x": jnp.zeros((batch, cw - 1, din), dtype),
        "conv_B": jnp.zeros((batch, cw - 1, gn), dtype),
        "conv_C": jnp.zeros((batch, cw - 1, gn), dtype),
    }
