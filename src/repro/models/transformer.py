"""Block assembly + scan-over-layers for every assigned family.

A *block* = mixer (attention | mamba) + FFN (dense | moe | none), pre-norm
residual.  Layers are stacked along a leading "layers" axis and executed
with ``lax.scan`` over *periods*: the repeating pattern unit (1 layer for
homogeneous stacks, 8 for Jamba's 1:7 hybrid period).  Scanning keeps the
compiled HLO O(period) instead of O(depth) - essential for the 512-device
dry-run compiles - and is the standard PP-ready layout.

Caches: attention layers carry (k, v) rings; mamba layers carry
(ssm, conv_*) states; whisper decoder layers add precomputed cross (k, v).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models import layers as L
from repro.models import mamba2, moe
from repro.parallel.sharding import constrain


def _norm_init(cfg, dtype):
    if cfg.norm_type == "layernorm":
        return L.layernorm_init(cfg.d_model, dtype)
    return L.rmsnorm_init(cfg.d_model, dtype)


def _norm_apply(cfg, p, x):
    if cfg.norm_type == "layernorm":
        return L.layernorm_apply(p, x, cfg.norm_eps)
    return L.rmsnorm_apply(p, x, cfg.norm_eps)


def _ffn_init(key, cfg, kind, dtype):
    if kind == "moe":
        return moe.moe_init(key, cfg, dtype)
    if kind == "dense":
        if cfg.mlp_type == "gelu":
            return L.gelu_mlp_init(key, cfg.d_model, cfg.d_ff, dtype)
        return L.swiglu_init(key, cfg.d_model, cfg.d_ff, dtype)
    return {}, {}


def _ffn_apply(p, x, cfg, kind):
    if kind == "moe":
        return moe.moe_apply(p, x, cfg)
    if kind == "dense":
        if cfg.mlp_type == "gelu":
            return L.gelu_mlp_apply(p, x), {}
        return L.swiglu_apply(p, x), {}
    return jnp.zeros_like(x), {}


# ------------------------------------------------------------------ block
def block_init(key, cfg, kind: str, ffn_kind: str, dtype, cross: bool = False):
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {}
    l: dict[str, Any] = {}
    p["norm1"], l["norm1"] = _norm_init(cfg, dtype)
    if kind == "attn":
        p["mixer"], l["mixer"] = L.attention_init(ks[0], cfg, dtype)
    else:
        p["mixer"], l["mixer"] = mamba2.mamba_init(ks[0], cfg, dtype)
    if cross:
        p["norm_x"], l["norm_x"] = _norm_init(cfg, dtype)
        p["cross"], l["cross"] = L.attention_init(ks[1], cfg, dtype, cross=True)
    if ffn_kind != "none":
        p["norm2"], l["norm2"] = _norm_init(cfg, dtype)
        p["ffn"], l["ffn"] = _ffn_init(ks[2], cfg, ffn_kind, dtype)
    return p, l


def block_apply(p, x, cfg, *, kind: str, ffn_kind: str,
                positions=None, cache=None, cache_pos=None,
                enc_cache=None, causal: bool = True, page_state=None):
    """Returns (x, new_cache, aux_losses)."""
    aux: dict[str, jax.Array] = {}
    h = _norm_apply(cfg, p["norm1"], x)
    if kind == "attn":
        if cache is None:
            attn_cache = None
        elif "k_pages" in cache:
            # Codec pools carry per-page scale sidecars next to the data
            # pools; they ride the same per-layer cache dict.
            attn_cache = {key: cache[key]
                          for key in ("k_pages", "v_pages",
                                      "k_scale", "v_scale")
                          if key in cache}
        else:
            attn_cache = {"k": cache["k"], "v": cache["v"]}
        y, new_attn_cache = L.attention_apply(
            p["mixer"], h, cfg, positions=positions, cache=attn_cache,
            cache_pos=cache_pos, causal=causal, page_state=page_state)
        new_cache = dict(cache) if cache is not None else None
        if new_attn_cache is not None and new_cache is not None:
            new_cache.update(new_attn_cache)
    else:
        y, new_state = mamba2.mamba_apply(
            p["mixer"], h, cfg, state=cache)
        new_cache = new_state if cache is not None else None
    x = x + y

    if "cross" in p and enc_cache is not None:
        hx = _norm_apply(cfg, p["norm_x"], x)
        y = _cross_attention(p["cross"], hx, cfg, enc_cache)
        x = x + y

    if ffn_kind != "none":
        h = _norm_apply(cfg, p["norm2"], x)
        y, aux = _ffn_apply(p["ffn"], h, cfg, ffn_kind)
        x = x + y
    x = constrain(x, ("batch", "seq", "embed"))
    return x, new_cache, aux


def _cross_attention(p, x, cfg, enc_cache):
    """Cross-attention against precomputed encoder (k, v)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    out = kops.multihead_attention(q, enc_cache["ck"].astype(x.dtype),
                                   enc_cache["cv"].astype(x.dtype),
                                   impl=cfg.attn_impl, causal=False)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def cross_kv(p, enc_out, cfg):
    """Precompute cross-attention (k, v) from encoder output (serve path)."""
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + p["bk"].astype(enc_out.dtype)
        v = v + p["bv"].astype(enc_out.dtype)
    return {"ck": k, "cv": v}


# ------------------------------------------------------------------ stack
def period_pattern(cfg) -> tuple[list[str], list[str], int]:
    """(mixer kinds, ffn kinds, period length)."""
    kinds = cfg.layer_kinds()
    ffns = cfg.ffn_kinds()
    n = cfg.n_layers
    for p in range(1, n + 1):
        if n % p:
            continue
        if (kinds == kinds[:p] * (n // p)) and (ffns == ffns[:p] * (n // p)):
            return kinds[:p], ffns[:p], p
    return kinds, ffns, n


def stack_init(key, cfg, dtype, cross: bool = False):
    """Init all layers stacked by period: params[f'l{i}'] has leading
    (n_groups,) axis."""
    kinds, ffns, period = period_pattern(cfg)
    groups = cfg.n_layers // period

    def one_group(k):
        ks = jax.random.split(k, period)
        p, l = {}, {}
        for i in range(period):
            p[f"l{i}"], l[f"l{i}"] = block_init(
                ks[i], cfg, kinds[i], ffns[i], dtype, cross=cross)
        return p, l

    keys = jax.random.split(key, groups)
    p0, l0 = one_group(keys[0])
    if groups == 1:
        stacked = jax.tree.map(lambda a: a[None], p0)
    else:
        stacked = jax.vmap(lambda k: one_group(k)[0])(keys)
    logical = jax.tree.map(
        lambda axes: ("layers",) + axes,
        l0, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return stacked, logical


def stack_apply(params, x, cfg, *, positions=None, caches=None,
                cache_pos=None, enc_caches=None, causal=True,
                dropout_rng=None, page_state=None):
    """Scan over layer groups. caches/enc_caches are stacked (groups, ...).

    ``page_state`` ({"page_table", "seq_lens"}, shared by every layer) is
    closed over rather than scanned - all layers of one step read the
    same tables.

    Returns (x, new_caches, aux_sum).
    """
    kinds, ffns, period = period_pattern(cfg)

    def body(carry, scanned):
        x, aux_acc = carry
        gp, gcache, genc = scanned
        new_gcache = {} if gcache is not None else None
        for i in range(period):
            cache_i = gcache[f"l{i}"] if gcache is not None else None
            enc_i = genc[f"l{i}"] if genc is not None else None
            x, nc, aux = block_apply(
                gp[f"l{i}"], x, cfg, kind=kinds[i], ffn_kind=ffns[i],
                positions=positions, cache=cache_i, cache_pos=cache_pos,
                enc_cache=enc_i, causal=causal, page_state=page_state)
            if new_gcache is not None:
                new_gcache[f"l{i}"] = nc
            for k, v in aux.items():
                aux_acc[k] = aux_acc.get(k, 0.0) + v
        return (x, aux_acc), new_gcache

    if cfg.remat != "none":
        body = jax.checkpoint(body, prevent_cse=False)

    init_aux = {"load_balance": jnp.float32(0.0), "router_z": jnp.float32(0.0)}
    if cfg.scan_layers:
        (x, aux), new_caches = jax.lax.scan(
            body, (x, init_aux), (params, caches, enc_caches))
        return x, new_caches, aux

    # Unrolled execution (used by the dry-run cost probes: while-loop bodies
    # are counted once by HLO cost analysis, so probes unroll instead).
    groups = jax.tree.leaves(params)[0].shape[0]
    carry = (x, init_aux)
    outs = []
    for g in range(groups):
        take = lambda t: (None if t is None
                          else jax.tree.map(lambda a: a[g], t))
        carry, yc = body(carry, (take(params), take(caches),
                                 take(enc_caches)))
        outs.append(yc)
    x, aux = carry
    new_caches = None
    if outs and outs[0] is not None:
        new_caches = jax.tree.map(lambda *a: jnp.stack(a), *outs)
    return x, new_caches, aux


def stack_init_paged_cache(cfg, num_pages: int, page_size: int, dtype,
                           codec: str = "fp"):
    """Paged block-pool caches, stacked (groups, P, page, Hkv, dh).

    One shared pool per layer; sequences address it through the
    engine-owned page table, so no per-slot ``max_seq`` is reserved.
    Attention-only stacks for now (Mamba/hybrid state is per-slot and
    dense; cross caches are tied to a fixed batch).

    ``codec`` selects the page codec (:mod:`repro.kernels.page_codec`):
    the data pools take the codec's storage dtype, and codecs with
    scales get f32 sidecar pools "k_scale"/"v_scale" of the same rank
    with trailing dim 1 - rank-matched so every page-table mechanism
    (scatter writers, COW copies, gathers, head sharding) treats scale
    leaves exactly like data leaves.
    """
    from repro.kernels import page_codec
    kinds, _, period = period_pattern(cfg)
    groups = cfg.n_layers // period
    assert all(k == "attn" for k in kinds), (
        "paged KV cache supports attention-only stacks, got %r" % (kinds,))
    c = page_codec.get_codec(codec)
    sdt = c.storage_dtype(dtype)

    def one_layer():
        shape = (groups, num_pages, page_size, cfg.n_kv_heads, cfg.d_head)
        leaves = {"k_pages": jnp.zeros(shape, sdt),
                  "v_pages": jnp.zeros(shape, sdt)}
        if c.has_scales:
            sshape = shape[:-1] + (1,)
            leaves["k_scale"] = jnp.zeros(sshape, jnp.float32)
            leaves["v_scale"] = jnp.zeros(sshape, jnp.float32)
        return leaves

    return {f"l{i}": one_layer() for i in range(period)}


def stack_init_cache(cfg, batch: int, max_seq: int, dtype, cross: bool = False,
                     enc_out=None, params=None):
    """Build stacked caches (groups-leading axis) for decode."""
    kinds, ffns, period = period_pattern(cfg)
    groups = cfg.n_layers // period

    def one_layer_cache(i):
        if kinds[i] == "attn":
            c = {
                "k": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.d_head),
                               dtype),
                "v": jnp.zeros((batch, max_seq, cfg.n_kv_heads, cfg.d_head),
                               dtype),
            }
        else:
            c = mamba2.mamba_init_state(cfg, batch, dtype)
        return c

    def stack_leaf(i):
        c = one_layer_cache(i)
        return jax.tree.map(lambda a: jnp.broadcast_to(a[None], (groups,) + a.shape), c)

    caches = {f"l{i}": stack_leaf(i) for i in range(period)}

    enc_caches = None
    if cross and enc_out is not None and params is not None:
        def group_cross(gp):
            return {f"l{i}": cross_kv(gp[f"l{i}"]["cross"], enc_out, cfg)
                    for i in range(period)}
        enc_caches = jax.vmap(group_cross, in_axes=0)(params) if groups > 1 \
            else jax.tree.map(lambda a: a[None], group_cross(
                jax.tree.map(lambda a: a[0], params)))
    return caches, enc_caches
