"""Mixture-of-Experts FFN: top-k router + capacity dispatch/combine.

GShard/Switch-style formulation generalized to top-k: tokens are routed to
their top-k experts, each expert processes at most C = ceil(T/E * cf * k)
tokens (overflow dropped, standard at scale), and outputs are combined with
the router weights.  The dispatch/combine einsums lower to all-to-all
resharding when experts are sharded over the "model" mesh axis (EP).

Aux losses: load-balancing (Switch) + router z-loss, returned for the
trainer to add.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import _init_dense


def moe_init(key, cfg, dtype=jnp.float32):
    e, d, ff = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    p = {
        "router": _init_dense(ks[0], (d, e), dtype=jnp.float32),
        "wg": _init_dense(ks[1], (e, d, ff), 1.0 / math.sqrt(d), dtype),
        "wu": _init_dense(ks[2], (e, d, ff), 1.0 / math.sqrt(d), dtype),
        "wd": _init_dense(ks[3], (e, ff, d), 1.0 / math.sqrt(ff), dtype),
    }
    l = {
        "router": ("fsdp", None),
        "wg": ("experts", "fsdp", "expert_mlp"),
        "wu": ("experts", "fsdp", "expert_mlp"),
        "wd": ("experts", "expert_mlp", "fsdp"),
    }
    return p, l


GROUP_SIZE = 4096  # tokens per dispatch group (GShard 'group' dimension)


def moe_apply(p, x: jax.Array, cfg) -> tuple[jax.Array, dict[str, jax.Array]]:
    """x: (B, S, d) -> (out, aux) with aux = {load_balance, router_z}.

    Tokens are split into groups of <= GROUP_SIZE with *per-group* capacity
    (GShard semantics): dispatch memory is O(G * g * E * C_g) with
    C_g = g/E * cf * k, instead of the quadratic-in-T naive form.  Groups
    map onto the data-parallel token sharding, experts onto "model" (EP);
    the dispatch/combine einsums then lower to all-to-alls.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    t = b * s
    # Bound the dense dispatch-einsum cost relative to the expert FFN cost:
    # dispatch ~ cf*g*d flops/token vs FFN ~ 6*k*d*ff, so keep g <~ 4*ff.
    auto = cfg.moe_group or min(GROUP_SIZE, 4 * max(cfg.d_ff, 128))
    g = min(auto, t)
    while t % g:
        g //= 2
    ng = t // g
    cap = max(int(math.ceil(g / e * cfg.capacity_factor * k)), k)

    xt = x.reshape(ng, g, d)
    logits = jnp.einsum("Ntd,de->Nte", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (N, g, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Slot of each (token, choice) inside its expert's capacity buffer.
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)    # (N, g, k, e)
    flatoh = onehot.reshape(ng, g * k, e)
    pos = jnp.cumsum(flatoh, axis=1) * flatoh - 1
    pos = jnp.max(pos, axis=-1).reshape(ng, g, k)
    keep = pos < cap

    slot_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                             dtype=xt.dtype)[..., :cap]       # (N, g, k, C)
    disp = jnp.einsum("Ntke,Ntkc->Ntec", onehot.astype(xt.dtype), slot_oh)
    comb = jnp.einsum("Ntk,Ntke,Ntkc->Ntec",
                      gate_vals.astype(xt.dtype) * keep.astype(xt.dtype),
                      onehot.astype(xt.dtype), slot_oh)

    expert_in = jnp.einsum("Ntec,Ntd->Necd", disp, xt)        # a2a under EP
    gact = jnp.einsum("Necd,edf->Necf", expert_in, p["wg"].astype(xt.dtype))
    uact = jnp.einsum("Necd,edf->Necf", expert_in, p["wu"].astype(xt.dtype))
    act = jax.nn.silu(gact.astype(jnp.float32)).astype(xt.dtype) * uact
    expert_out = jnp.einsum("Necf,efd->Necd", act, p["wd"].astype(xt.dtype))
    out = jnp.einsum("Ntec,Necd->Ntd", comb, expert_out)      # a2a back

    # Switch load-balance loss + router z-loss (per group, averaged).
    me = jnp.mean(probs, axis=1)                              # (N, e)
    ce = jnp.mean(jax.nn.one_hot(gate_idx[..., 0], e, dtype=jnp.float32),
                  axis=1)
    aux = {
        "load_balance": e * jnp.mean(jnp.sum(me * ce, axis=-1)),
        "router_z": jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2),
    }
    return out.reshape(b, s, d), aux
