"""Logical-axis sharding: MaxText-style rules -> PartitionSpec trees.

Parameters and activations are annotated with *logical* axis names
("vocab", "heads", "mlp", "batch", ...).  A rule table maps logical names
to mesh axes; unmapped names are replicated.  This indirection is what the
perf iterations tune: changing a rule re-shards the whole model without
touching layer code.

Rules honour divisibility: if a logical axis size does not divide the mesh
axis size, the rule silently falls back to replication for that tensor
axis (the standard GQA kv-head treatment: replicate when kv_heads < TP).
"""
from __future__ import annotations

from typing import Any, Mapping, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Default rule table.  "pod" and "data" both carry batch (DP across pods
# and within a pod); "model" carries TP/EP/SP; "fsdp" shards weight d_model
# dims over "data" (ZeRO-3/FSDP - parameters+optimizer state are fully
# sharded over the whole mesh, all-gathered per layer by XLA).
DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "kv_batch": ("pod", "data"),   # decode cache batch (may differ from activations)
    "seq": None,                # activations sequence dim (SP rule: "model")
    "kv_seq": "model",          # decode KV cache sequence sharding
    "embed": None,              # activations d_model (replicated)
    "fsdp": "data",             # weight d_model dims
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "mamba_inner": "model",
    "mamba_heads": "model",
    "mamba_state": None,
    "layers": None,             # stacked scan axis
    "conv": None,
}

# Training enables sequence parallelism: the residual stream saved by the
# scan-over-layers remat is sharded over "model" on the sequence dim.
TRAIN_RULES = dict(DEFAULT_RULES, seq="model")

# Serving: decode KV caches shard their sequence dim over "model" (the
# paper's multi-KV-block parallelism promoted to the mesh, DESIGN.md §2)
# when kv_heads are not divisible by the model axis.
SERVE_RULES = dict(DEFAULT_RULES)


def spec_for(
    logical: Sequence[str | None],
    shape: Sequence[int] | None = None,
    rules: Mapping[str, Any] | None = None,
    mesh: Mesh | None = None,
) -> P:
    """Build a PartitionSpec from logical axis names.

    If ``shape`` and ``mesh`` are given, any mapping whose mesh-axis size
    does not divide the tensor-axis size degrades to replication.
    """
    rules = DEFAULT_RULES if rules is None else rules
    out = []
    used: set[str] = set()
    for i, name in enumerate(logical):
        axis = rules.get(name) if name is not None else None
        if axis is not None and mesh is not None:
            # Drop mesh axes that don't exist on this mesh (e.g. "pod" on
            # the single-pod mesh) or are already used by an earlier dim.
            sizes = axis if isinstance(axis, tuple) else (axis,)
            sizes = tuple(a for a in sizes
                          if a in mesh.shape and a not in used)
            axis = sizes if len(sizes) > 1 else (sizes[0] if sizes else None)
        if axis is not None and shape is not None and mesh is not None:
            sizes = axis if isinstance(axis, tuple) else (axis,)
            total = int(np.prod([mesh.shape[a] for a in sizes]))
            if shape[i] % total != 0:
                axis = None
        if axis is not None:
            used.update(axis if isinstance(axis, tuple) else (axis,))
        out.append(axis)
    # Trim trailing Nones (canonical form).
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_specs(
    logical_tree: Any,
    shape_tree: Any | None = None,
    rules: Mapping[str, Any] | None = None,
    mesh: Mesh | None = None,
) -> Any:
    """Map ``spec_for`` over a pytree of logical-axis tuples."""
    is_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    if shape_tree is None:
        return jax.tree.map(lambda l: spec_for(l, None, rules, mesh),
                            logical_tree, is_leaf=is_leaf)
    return jax.tree.map(
        lambda l, s: spec_for(l, s.shape, rules, mesh),
        logical_tree, shape_tree, is_leaf=is_leaf)


def tree_shardings(mesh: Mesh, spec_tree: Any) -> Any:
    """PartitionSpec tree -> NamedSharding tree."""
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P))


# Active sharding context: set by launchers before tracing so that
# ``constrain`` calls inside model code resolve logical names against the
# right mesh + rule table.  Without a context, constraints are no-ops
# (small single-device tests).
_ACTIVE: dict[str, Any] = {"mesh": None, "rules": None}


def set_context(mesh: Mesh | None, rules: Mapping[str, Any] | None):
    _ACTIVE["mesh"] = mesh
    _ACTIVE["rules"] = rules


def constrain(x: jax.Array, logical: Sequence[str | None],
              rules: Mapping[str, Any] | None = None,
              mesh: Mesh | None = None) -> jax.Array:
    """with_sharding_constraint by logical names (no-op without a context)."""
    mesh = mesh if mesh is not None else _ACTIVE["mesh"]
    rules = rules if rules is not None else _ACTIVE["rules"]
    if mesh is None:
        return x
    spec = spec_for(logical, x.shape, rules, mesh)
    # NamedSharding works both under a mesh context manager and in bare
    # eval_shape traces (cache-shape derivation).
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
