"""Distributed attention collectives: the paper's multi-KV-block merge
(Fig. 2 / Eq. 16) promoted to the mesh.

``shardmap_decode_attention`` serves one new token against a KV ring whose
*sequence* dim is sharded over the "model" axis:

  * each shard writes the new (k, v) row with a LOCAL dynamic-update-slice
    (a traced-index DUS on a sharded dim would force the SPMD partitioner
    to all-gather and rewrite the whole ring - the baseline's memory
    bottleneck, see EXPERIMENTS.md §Perf);
  * each shard computes a partial FAU triplet (o~, m, l) over its local
    window, exactly like one of the paper's block-FAUs;
  * the triplets (tiny: one d-vector per head) are all-gathered over the
    shard axis and merged with the log-domain ACC rule, optionally through
    the FIX16 quantized path (use_hfa).

Collective volume per token: P * (d+2) floats per head instead of the
full ring - this is the paper's cascaded-ACC architecture as an ICI
pattern.
"""
from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import decode as dk

try:                                     # jax >= 0.6
    from jax import shard_map as _shard_map_impl
except ImportError:                      # older jax: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map_impl


def shard_map(*args, **kwargs):
    """shard_map across jax versions: translate the ``check_vma`` kwarg
    to its pre-rename spelling ``check_rep`` when needed."""
    params = inspect.signature(_shard_map_impl).parameters
    if "check_vma" in kwargs and "check_vma" not in params:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map_impl(*args, **kwargs)


def shardmap_decode_attention(
    q: jax.Array,        # (B, 1, H, dh)
    k_new: jax.Array,    # (B, 1, Hkv, dh)
    v_new: jax.Array,    # (B, 1, Hkv, dh)
    cache_k: jax.Array,  # (B, S, Hkv, dh), S sharded over `axis`
    cache_v: jax.Array,
    pos: jax.Array,      # scalar int32: global write index
    *,
    mesh,
    axis: str = "model",
    batch_axes=("pod", "data"),
    use_hfa: bool = True,
    scale: float | None = None,
):
    """Returns (out (B,1,H,dh), new_cache_k, new_cache_v)."""
    b, _, h, dh = q.shape
    hkv = cache_k.shape[2]
    g = h // hkv
    n_shards = mesh.shape[axis]
    s_local = cache_k.shape[1] // n_shards
    batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
    bspec = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)

    def local(q, k_new, v_new, ck, cv, pos):
        bl = q.shape[0]  # local (batch-sharded) size
        idx = jax.lax.axis_index(axis)
        offset = idx * s_local
        local_pos = jnp.clip(pos - offset, 0, s_local - 1)
        hit = (pos >= offset) & (pos < offset + s_local)
        # Local write: plain DUS on the unsharded local ring.
        ck_w = jax.lax.dynamic_update_slice(
            ck, k_new.astype(ck.dtype), (0, local_pos, 0, 0))
        cv_w = jax.lax.dynamic_update_slice(
            cv, v_new.astype(cv.dtype), (0, local_pos, 0, 0))
        ck = jnp.where(hit, ck_w, ck)
        cv = jnp.where(hit, cv_w, cv)

        # Partial FAU over the local window [offset, offset + s_local).
        kv_len_local = jnp.clip(pos + 1 - offset, 0, s_local)
        qg = q.reshape(bl, hkv, g, dh)
        scale_v = (1.0 / dh ** 0.5) if scale is None else scale
        s = jnp.einsum("bhgd,bshd->bhgs", qg, ck,
                       preferred_element_type=jnp.float32) * scale_v
        mask = jnp.arange(s_local)[None, None, None, :] < kv_len_local
        s = jnp.where(mask, s, -1e30)
        m = jnp.max(s, axis=-1)
        if use_hfa:
            from repro.kernels import bitmath
            p = bitmath.exp2_hfa_rail(bitmath.quant_rail(
                jnp.minimum(s - m[..., None], 0.0)))
        else:
            p = jnp.exp(s - m[..., None])
        p = jnp.where(mask & (m != -1e30)[..., None], p, 0.0)
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhgs,bshd->bhgd", p.astype(q.dtype), cv,
                       preferred_element_type=jnp.float32)

        # ACC merge across shards (Eq. 16): gather the tiny triplets.
        og = jax.lax.all_gather(o, axis)
        mg = jax.lax.all_gather(m, axis)
        lg = jax.lax.all_gather(l, axis)
        om, mm, lm = dk.merge_partials(og, mg, lg, use_hfa=use_hfa)
        out = dk.finalize_decode(om, lm, use_hfa=use_hfa)
        return out.reshape(bl, 1, h, dh).astype(q.dtype), ck, cv

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec), P(bspec), P(bspec),
                  P(bspec, axis), P(bspec, axis), P()),
        out_specs=(P(bspec), P(bspec, axis), P(bspec, axis)),
        check_vma=False)
    return fn(q, k_new, v_new, cache_k, cache_v, pos)
