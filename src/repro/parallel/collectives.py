"""Distributed attention collectives: the paper's multi-KV-block merge
(Fig. 2 / Eq. 16) promoted to the mesh.

``shardmap_decode_attention`` serves one new token against a KV ring whose
*sequence* dim is sharded over the "model" axis:

  * each shard writes the new (k, v) row with a LOCAL dynamic-update-slice
    (a traced-index DUS on a sharded dim would force the SPMD partitioner
    to all-gather and rewrite the whole ring - the baseline's memory
    bottleneck, see EXPERIMENTS.md §Perf);
  * each shard computes a partial FAU triplet (o~, m, l) over its local
    window, exactly like one of the paper's block-FAUs;
  * the triplets (tiny: one d-vector per head) are all-gathered over the
    shard axis and merged with the log-domain ACC rule, optionally through
    the FIX16 quantized path (use_hfa).

Collective volume per token: P * (d+2) floats per head instead of the
full ring - this is the paper's cascaded-ACC architecture as an ICI
pattern.
"""
from __future__ import annotations

import functools
import inspect

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import decode as dk

try:                                     # jax >= 0.6
    from jax import shard_map as _shard_map_impl
except ImportError:                      # older jax: experimental home
    from jax.experimental.shard_map import shard_map as _shard_map_impl


def shard_map(*args, **kwargs):
    """shard_map across jax versions: translate the ``check_vma`` kwarg
    to its pre-rename spelling ``check_rep`` when needed."""
    params = inspect.signature(_shard_map_impl).parameters
    if "check_vma" in kwargs and "check_vma" not in params:
        kwargs["check_rep"] = kwargs.pop("check_vma")
    return _shard_map_impl(*args, **kwargs)


def shardmap_decode_attention(
    q: jax.Array,        # (B, 1, H, dh)
    k_new: jax.Array,    # (B, 1, Hkv, dh)
    v_new: jax.Array,    # (B, 1, Hkv, dh)
    cache_k: jax.Array,  # (B, S, Hkv, dh), S sharded over `axis`
    cache_v: jax.Array,
    pos: jax.Array,      # scalar int32: global write index
    *,
    mesh,
    axis: str = "model",
    batch_axes=("pod", "data"),
    use_hfa: bool = True,
    scale: float | None = None,
):
    """Returns (out (B,1,H,dh), new_cache_k, new_cache_v)."""
    b, _, h, dh = q.shape
    hkv = cache_k.shape[2]
    g = h // hkv
    n_shards = mesh.shape[axis]
    s_local = cache_k.shape[1] // n_shards
    batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
    bspec = batch_axes if len(batch_axes) > 1 else (
        batch_axes[0] if batch_axes else None)

    def local(q, k_new, v_new, ck, cv, pos):
        bl = q.shape[0]  # local (batch-sharded) size
        idx = jax.lax.axis_index(axis)
        offset = idx * s_local
        local_pos = jnp.clip(pos - offset, 0, s_local - 1)
        hit = (pos >= offset) & (pos < offset + s_local)
        # Local write: plain DUS on the unsharded local ring.
        ck_w = jax.lax.dynamic_update_slice(
            ck, k_new.astype(ck.dtype), (0, local_pos, 0, 0))
        cv_w = jax.lax.dynamic_update_slice(
            cv, v_new.astype(cv.dtype), (0, local_pos, 0, 0))
        ck = jnp.where(hit, ck_w, ck)
        cv = jnp.where(hit, cv_w, cv)

        # Partial FAU over the local window [offset, offset + s_local).
        kv_len_local = jnp.clip(pos + 1 - offset, 0, s_local)
        qg = q.reshape(bl, hkv, g, dh)
        scale_v = (1.0 / dh ** 0.5) if scale is None else scale
        s = jnp.einsum("bhgd,bshd->bhgs", qg, ck,
                       preferred_element_type=jnp.float32) * scale_v
        mask = jnp.arange(s_local)[None, None, None, :] < kv_len_local
        s = jnp.where(mask, s, -1e30)
        m = jnp.max(s, axis=-1)
        if use_hfa:
            from repro.kernels import bitmath
            p = bitmath.exp2_hfa_rail(bitmath.quant_rail(
                jnp.minimum(s - m[..., None], 0.0)))
        else:
            p = jnp.exp(s - m[..., None])
        p = jnp.where(mask & (m != -1e30)[..., None], p, 0.0)
        l = jnp.sum(p, axis=-1)
        o = jnp.einsum("bhgs,bshd->bhgd", p.astype(q.dtype), cv,
                       preferred_element_type=jnp.float32)

        # ACC merge across shards (Eq. 16): gather the tiny triplets.
        og = jax.lax.all_gather(o, axis)
        mg = jax.lax.all_gather(m, axis)
        lg = jax.lax.all_gather(l, axis)
        om, mm, lm = dk.merge_partials(og, mg, lg, use_hfa=use_hfa)
        out = dk.finalize_decode(om, lm, use_hfa=use_hfa)
        return out.reshape(bl, 1, h, dh).astype(q.dtype), ck, cv

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec), P(bspec), P(bspec),
                  P(bspec, axis), P(bspec, axis), P()),
        out_specs=(P(bspec), P(bspec, axis), P(bspec, axis)),
        check_vma=False)
    return fn(q, k_new, v_new, cache_k, cache_v, pos)


# ---------------------------------------------------------------- paged TP
def tp_shards(mesh, axis: str = "model") -> int:
    """Size of the tensor-parallel axis on ``mesh`` (1 = no TP)."""
    if mesh is None:
        return 1
    return int(mesh.shape.get(axis, 1))


def shardmap_paged_attention(
    q: jax.Array,        # (B, L, H, dh) decode L=1 / verify L=K / chunk L
    k_new: jax.Array,    # (B, L, Hkv, dh) this step's K/V to scatter
    v_new: jax.Array,    # (B, L, Hkv, dh)
    pools: dict,         # {"k_pages", "v_pages"[, "k_scale", "v_scale"]}
                         # each (P, page, Hkv, ·), Hkv sharded over `axis`
    page_table: jax.Array,  # (B, pages_per_seq) int32, replicated
    lens_a: jax.Array,   # (B,) int32: decode/verify seq_lens; prefill start
    lens_b: jax.Array,   # (B,) int32: verify/prefill chunk_lens; decode 0s
    *,
    mesh,
    mode: str,           # "decode" | "verify" | "prefill"
    impl: str = "fa2",
    axis: str = "model",
    data_axis: str = "data",
    scale: float | None = None,
    codec=None,          # page codec (name or PageCodec); None/"fp" = raw
):
    """Tensor-parallel paged attention: the cascaded ACC merge over a
    KV-head-sharded page pool.

    The paper's multi-KV-block merge (Fig. 2 / Eq. 16), already an ICI
    pattern for the dense ring (:func:`shardmap_decode_attention`),
    applied to the production paged pool:

      * the pools keep the *full* page layout on every shard but carry
        only ``Hkv / tp`` KV heads (page tables stay replicated, so host
        paging logic - refcounts, COW, prefix cache, rollback - is
        untouched);
      * each shard scatters its local heads' K/V (a LOCAL page-table
        write: no cross-shard traffic) and computes the partial block-FAU
        triplet (o~, m, l) over its local heads via the same
        :mod:`repro.kernels.ops` partials the single-shard path
        finalizes;
      * local triplets are padded to full head width with the merge's
        *neutral* element (o~=0, m=NEG_INF, l=0), all-gathered over the
        shard axis (tiny: tp * B * L * H * (dh + 2) floats vs the full
        KV pool), and merged with the log-domain ACC rule
        (:func:`repro.kernels.decode.merge_partials`; ``use_hfa``
        selects the FIX16/PWL rail) before one LogDiv finalize.

    Because a head's triplet is computed by exactly one shard and the
    ACC merge with the neutral element is an fp identity (the owning
    shard's rescale weight is exp(0) == 1, the neutral's l/o~ are
    exactly 0), the merged output is bit-equal to the single-shard
    finalize per head - which is what makes TP serving token-exact.

    With a page ``codec``, each shard encodes its local heads' K/V
    before the scatter (encode is elementwise per head, so shard-local
    encode == global encode) and the scale sidecar pools ride the same
    head-sharded spec as the data pools; decode-in-kernel happens inside
    the shard-local partials, so the sharded rail quantizes exactly like
    the single-shard one.

    Data parallelism (a ``data_axis`` of size dp > 1 on the mesh): the
    *slot* (batch) dim of q is additionally sharded over the data axis
    whenever ``B % dp == 0``, so a step's attention compute splits dp
    ways with ZERO new collectives.  The trick that keeps it bit-exact:
    every data shard applies the FULL batch's K/V scatter (k_new /
    page_table / lens stay replicated over "data"), so the pool
    replicas on each data shard evolve bit-identically - only the
    partials + merge run on the local batch slice (selected with
    ``axis_index(data_axis)``), and the outputs are reassembled on the
    batch dim.  A batch that dp does not divide (an odd chunked-prefill
    group) falls back to fully replicated compute for that call, which
    is the same arithmetic on every shard - still bit-exact, just not
    parallel.

    Returns (out (B, L, H, dh), new_pools) with the pools (and any scale
    sidecars) still KV-head-sharded (and replicated over the data axis).
    """
    from repro.kernels import ops as kops
    from repro.kernels import page_codec
    from repro.kernels import paged_decode as paged_k
    from repro.kernels import paged_prefill as paged_pf_k

    assert mode in ("decode", "verify", "prefill"), mode
    b, l_q, h, dh = q.shape
    hkv = pools["k_pages"].shape[2]
    g = h // hkv
    n = tp_shards(mesh, axis)
    assert hkv % n == 0, (
        f"paged TP needs kv_heads % tp == 0, got {hkv} % {n}")
    hkv_l = hkv // n
    use_hfa = impl.startswith("hfa")
    cod = page_codec.get_codec(codec)
    rcodec = None if cod.name == "fp" else cod
    dp = tp_shards(mesh, data_axis)
    # Batch-shard q over the data axis when it divides evenly; otherwise
    # every data shard runs the full batch (identical arithmetic - the
    # bit-exact fallback for odd prefill group sizes).
    shard_b = dp > 1 and b % dp == 0

    def local(q, k_new, v_new, pools, pt, la, lb):
        # q arrives head-sharded (and, with shard_b, batch-sharded):
        # (B/dp, L, H/n, dh) - heads are kv-major, so the head slice is
        # exactly this shard's hkv_l KV-head groups.
        idx = jax.lax.axis_index(axis)
        bl = q.shape[0]
        if shard_b:
            # Every data shard scatters the FULL batch (pool replicas
            # stay bit-identical - no collective needed to reconcile
            # them), but attends only its own batch slice.
            didx = jax.lax.axis_index(data_axis)
            pt_l = jax.lax.dynamic_slice_in_dim(pt, didx * bl, bl, 0)
            la_l = jax.lax.dynamic_slice_in_dim(la, didx * bl, bl, 0)
            lb_l = jax.lax.dynamic_slice_in_dim(lb, didx * bl, bl, 0)
        else:
            pt_l, la_l, lb_l = pt, la, lb
        if mode == "decode":
            pools = page_codec.encode_write(
                paged_k.append_kv, cod, pools, k_new, v_new, pt, la)
            kv_lens = jnp.where(la_l > 0, la_l + 1, 0)
            qg = q.reshape(bl, hkv_l, g, dh)
            o, m, l = kops.paged_decode_partials(
                qg, pools["k_pages"], pools["v_pages"], pt_l, kv_lens,
                impl=impl, scale=scale, codec=rcodec,
                k_scales=pools.get("k_scale"),
                v_scales=pools.get("v_scale"))
        elif mode == "verify":
            pools = page_codec.encode_write(
                paged_pf_k.write_chunk_kv, cod, pools, k_new, v_new, pt,
                la, lb)
            qg = jnp.swapaxes(q, 1, 2).reshape(bl, hkv_l, g, l_q, dh)
            o, m, l = kops.paged_verify_partials(
                qg, pools["k_pages"], pools["v_pages"], pt_l, la_l, lb_l,
                impl=impl, scale=scale, codec=rcodec,
                k_scales=pools.get("k_scale"),
                v_scales=pools.get("v_scale"))
        else:
            pools = page_codec.encode_write(
                paged_pf_k.write_chunk_kv, cod, pools, k_new, v_new, pt,
                la, lb)
            kv_lens = (la_l + lb_l).astype(jnp.int32)
            qg = jnp.swapaxes(q, 1, 2).reshape(bl, hkv_l, g, l_q, dh)
            o, m, l = kops.paged_prefill_partials(
                qg, pools["k_pages"], pools["v_pages"], pt_l, la_l,
                kv_lens,
                impl=impl, scale=scale, codec=rcodec,
                k_scales=pools.get("k_scale"),
                v_scales=pools.get("v_scale"))

        # Pad the local triplet to full head width with the neutral
        # element, so the gathered merge reconstitutes every head.
        o_f = jnp.zeros((bl, hkv) + o.shape[2:], o.dtype)
        m_f = jnp.full((bl, hkv) + m.shape[2:], dk.NEG_INF, m.dtype)
        l_f = jnp.zeros((bl, hkv) + l.shape[2:], l.dtype)
        off = idx * hkv_l
        o_f = jax.lax.dynamic_update_slice_in_dim(o_f, o, off, axis=1)
        m_f = jax.lax.dynamic_update_slice_in_dim(m_f, m, off, axis=1)
        l_f = jax.lax.dynamic_update_slice_in_dim(l_f, l, off, axis=1)

        # ACC merge across shards (Eq. 16): gather only the triplets
        # (over the model axis alone - data shards own disjoint batch
        # rows, so nothing crosses the data axis here).
        og = jax.lax.all_gather(o_f, axis)
        mg = jax.lax.all_gather(m_f, axis)
        lg = jax.lax.all_gather(l_f, axis)
        om, mm, lm = dk.merge_partials(og, mg, lg, use_hfa=use_hfa)
        out = dk.finalize_decode(om, lm, use_hfa=use_hfa)
        if mode == "decode":
            out = out.reshape(bl, 1, h, dh)
        else:
            # (B, Hkv, G, L, dh) -> (B, L, H, dh)
            out = jnp.swapaxes(out.reshape(bl, h, l_q, dh), 1, 2)
        return out.astype(q.dtype), pools

    # hspec is a pytree *prefix* for the pools dict: every pool leaf
    # (data or scale sidecar) is (P, page, Hkv, ·) with Hkv at axis 2.
    # Nothing names the data axis except q/out's batch dim: the pools
    # and the scatter operands stay replicated over "data" so every
    # data shard's pool replica evolves identically.
    hspec = P(None, None, axis, None)
    dspec = data_axis if shard_b else None
    qspec = P(dspec, None, axis, None)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(qspec, hspec, hspec, hspec, P(), P(), P()),
        out_specs=(P(dspec), hspec),
        check_vma=False)
    return fn(q, k_new, v_new, dict(pools), page_table,
              lens_a.astype(jnp.int32), lens_b.astype(jnp.int32))
