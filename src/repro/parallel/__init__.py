"""Distribution layer: logical-axis sharding rules + collective helpers."""
