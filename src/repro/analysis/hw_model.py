"""28nm op-inventory cost model of the FA-2 vs H-FA accelerator datapaths.

Offline reproduction of the paper's hardware evaluation (Figs. 6-8,
Table IV): no synthesis tools are available, so we model each datapath as
an inventory of arithmetic blocks with per-block 28nm area/energy
constants.  The inventory follows the paper's architecture exactly:

  FAU (Fig. 1/3): dot-product unit (BF16, shared by both designs), the
  running-max/score-diff float logic (shared), then either
    FA-2: 2 exp units + (2d+1) BF16 mult + (d+1) BF16 add + BF16 dividers
    H-FA: 2 quant units + Blinn bias-subtract + per-lane FIX16 LNS adder
          (2 adds, |A-B|, PWL mult+LUT+shift, final add) + LogDiv
          (fixed-point subtract + bit-pack)
  ACC (Fig. 2/4): the cross-block merge, same split.

Constants are calibrated once against the paper's reported d=64 design
point (Fig. 7: ~1.1 mm^2 with KV SRAM, 26.5%/23.4% average savings;
Table IV throughput 0.256 BF16-TFLOPs / 0.91 FIX16-TOPs for H-FA-1-4 at
500 MHz) and then *validated* at d=32 and d=128 - the cross-d trend is a
model output, not an input.  SRAM (KV buffers, N=1024 rows) is identical
for both designs, per the paper.
"""
from __future__ import annotations

import dataclasses

# ---- 28nm per-op area (um^2) and energy (pJ/op) -------------------------
# Calibrated once at the paper's d=64 design point (see module docstring).
AREA = {
    "bf16_mult": 450.0,
    "bf16_add": 520.0,     # alignment + normalization dominate
    "bf16_div": 2800.0,
    "bf16_cmp": 180.0,
    "exp_unit": 1500.0,    # range-reduce + PWL + shift, bf16
    "int16_add": 70.0,
    "int16_mult8": 240.0,  # 16x8 PWL slope multiplier
    "barrel16": 95.0,
    "lut_pwl": 95.0,       # 8-entry x 2 x 16b coefficients
    "quant": 190.0,        # mult-by-log2e (const) + clamp + round
    "bitpack": 20.0,
    "reg_bit": 3.2,
}
ENERGY = {  # pJ per operation at 0.9V 28nm
    "bf16_mult": 1.10,
    "bf16_add": 0.95,
    "bf16_div": 6.0,
    "bf16_cmp": 0.25,
    "exp_unit": 2.4,
    "int16_add": 0.13,
    "int16_mult8": 0.40,
    "barrel16": 0.11,
    "lut_pwl": 0.15,
    "quant": 0.22,
    "bitpack": 0.03,
    "reg_bit": 0.0022,
}
SRAM_AREA_PER_KB = 1600.0      # um^2 (CACTI 22nm scaled to 28nm, paper flow)
SRAM_PJ_PER_BIT = 0.055        # read energy
FREQ = 500e6
LEAKAGE_W_PER_MM2 = 0.018


@dataclasses.dataclass
class Inventory:
    """counts of each op per FAU cycle (steady state, one key/cycle)."""
    counts: dict[str, float]
    reg_bits: float

    def area_um2(self) -> float:
        a = sum(AREA[k] * v for k, v in self.counts.items())
        return a + AREA["reg_bit"] * self.reg_bits

    def energy_pj_per_cycle(self, activity: float = 1.0) -> float:
        e = sum(ENERGY[k] * v for k, v in self.counts.items())
        return activity * (e + ENERGY["reg_bit"] * self.reg_bits)


def shared_float_ops(d: int) -> dict[str, float]:
    """Dot product + max/score-diff logic - identical in both designs."""
    return {"bf16_mult": d, "bf16_add": d - 1 + 2, "bf16_cmp": 1}


def fau_fa2(d: int) -> Inventory:
    c = shared_float_ops(d)
    c["exp_unit"] = c.get("exp_unit", 0) + 2
    c["bf16_mult"] += 2 * (d + 1)      # o*alpha, v*beta (+ l lane)
    c["bf16_add"] += (d + 1)
    # Division happens once per query (d+1 divides over an N-cycle epoch):
    # two time-multiplexed divider pipelines suffice physically.
    c["bf16_div"] = 2
    r = (d + 2) * 16 + 32              # o, l, m registers
    return Inventory(c, r)


def fau_hfa(d: int) -> Inventory:
    c = shared_float_ops(d)
    lanes = d + 1
    c["quant"] = 2
    c["int16_add"] = lanes * (1 + 2 + 2 + 1 + 1)  # blinn sub, A/B, |A-B|, corr, final
    c["int16_mult8"] = lanes
    c["barrel16"] = lanes + 2          # PWL shift + 2 const shifters
    c["lut_pwl"] = lanes
    c["bitpack"] = lanes * 2           # to/from LNS (V in, attn out)
    r = lanes * 17 + 32
    return Inventory(c, r)


def acc_fa2(d: int) -> Inventory:
    return Inventory({"exp_unit": 2, "bf16_mult": 2 * (d + 1),
                      "bf16_add": (d + 1), "bf16_cmp": 1}, (d + 2) * 16)


def acc_hfa(d: int) -> Inventory:
    lanes = d + 1
    return Inventory({"quant": 2, "int16_add": lanes * 6,
                      "int16_mult8": lanes, "barrel16": lanes,
                      "lut_pwl": lanes, "bf16_cmp": 1}, lanes * 17 + 16)


def logdiv_hfa(d: int) -> Inventory:
    return Inventory({"int16_add": d, "bitpack": d}, 0)


def div_fa2(d: int) -> Inventory:
    return Inventory({"bf16_div": d}, 0)


def sram_kb(d: int, n_tokens: int = 1024) -> float:
    return n_tokens * d * 2 * 2 / 1024.0   # K+V, bf16


def accelerator(design: str, d: int, p_blocks: int = 4, n_q: int = 1):
    """Total area (mm^2) / power (W) for p parallel KV blocks, n_q queries."""
    if design == "fa2":
        fau, acc, fin = fau_fa2(d), acc_fa2(d), div_fa2(d)
    else:
        fau, acc, fin = fau_hfa(d), acc_hfa(d), logdiv_hfa(d)
    datapath = (fau.area_um2() * p_blocks + acc.area_um2() * p_blocks
                + fin.area_um2()) * n_q
    sram = sram_kb(d) * SRAM_AREA_PER_KB
    area_mm2 = (datapath + sram) / 1e6

    # Power: FAUs busy every cycle; ACC/div amortized over N/p-cycle epochs.
    epoch = 1024 / p_blocks
    dyn_pj = (fau.energy_pj_per_cycle() * p_blocks
              + acc.energy_pj_per_cycle() * p_blocks / epoch * 4
              + fin.energy_pj_per_cycle() / epoch) * n_q
    sram_pj = d * 2 * 16 * SRAM_PJ_PER_BIT * p_blocks * n_q  # K+V rows/cycle
    power_w = (dyn_pj + sram_pj) * 1e-12 * FREQ \
        + LEAKAGE_W_PER_MM2 * area_mm2
    return {"area_mm2": area_mm2, "power_w": power_w,
            "datapath_mm2": datapath / 1e6, "sram_mm2": sram / 1e6}


def savings_table(ds=(32, 64, 128), p_blocks: int = 4) -> list[dict]:
    rows = []
    for d in ds:
        fa = accelerator("fa2", d, p_blocks)
        hf = accelerator("hfa", d, p_blocks)
        rows.append({
            "d": d,
            "fa2_area_mm2": fa["area_mm2"], "hfa_area_mm2": hf["area_mm2"],
            "area_saving_%": 100 * (1 - hf["area_mm2"] / fa["area_mm2"]),
            "dp_area_saving_%": 100 * (1 - hf["datapath_mm2"]
                                       / fa["datapath_mm2"]),
            "fa2_power_w": fa["power_w"], "hfa_power_w": hf["power_w"],
            "power_saving_%": 100 * (1 - hf["power_w"] / fa["power_w"]),
        })
    return rows


def exec_time_model(n_tokens: int = 1024, d: int = 64,
                    blocks=(1, 2, 4, 8)) -> list[dict]:
    """Fig. 8: normalized execution time + area vs parallel KV blocks."""
    lat = {32: 19, 64: 20, 128: 21}.get(d, 20)
    base = None
    rows = []
    for p in blocks:
        cycles = n_tokens / p + lat + 5 * (p - 1)   # ACC pipeline merge
        area = accelerator("hfa", d, p)["area_mm2"]
        if base is None:
            base = (cycles, area)
        rows.append({"blocks": p, "cycles": cycles,
                     "time_norm": cycles / base[0],
                     "speedup": base[0] / cycles,
                     "area_mm2": area, "area_norm": area / base[1]})
    return rows


def throughput_table() -> list[dict]:
    """Table IV: H-FA-1-4 and H-FA-4-4 configs."""
    rows = []
    for name, n_q, p in (("H-FA-1-4", 1, 4), ("H-FA-4-4", 4, 4)):
        d = 64
        acc = accelerator("hfa", d, p, n_q)
        bf16_ops = (2 * d + 3) * p * n_q * FREQ            # dot + max/diffs
        fix_ops = (7 * (d + 1)) * p * n_q * FREQ            # LNS lanes
        rows.append({
            "config": name, "area_mm2": acc["area_mm2"],
            "power_w": acc["power_w"],
            "bf16_tflops": bf16_ops / 1e12,
            "fix16_tops": fix_ops / 1e12,
            "energy_eff_tops_w": (bf16_ops + fix_ops) / 1e12 / acc["power_w"],
            "area_eff_tops_mm2": (bf16_ops + fix_ops) / 1e12 / acc["area_mm2"],
        })
    return rows
