"""Roofline derivation + 28nm hardware cost models."""
