"""Three-term roofline from the dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute_s    = HLO_FLOPs_per_device   / peak_FLOP/s
    memory_s     = HLO_bytes_per_device   / HBM_bw
    collective_s = collective_bytes_per_device / link_bw

HLO numbers come from ``cost_corrected.per_step`` in each dry-run artifact
(cost probes fix the while-loop undercount, see launch/dryrun.py).  All
values are per-device on the partitioned module; multiplying by chip count
gives cluster totals, so the task-spec form HLO/(chips*peak) is identical.

MODEL_FLOPS = 6*N*D (train) or 2*N*D (inference), N = active params
(MoE: top-k experts only), D = tokens processed in the step.  The ratio
MODEL/HLO exposes remat recompute, attention windows, MoE dispatch and
replicated-compute waste.

Hardware constants (task spec): TPU v5e-class chip, 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../experiments/artifacts/dryrun")
HBM_BYTES = 16 * 2 ** 30  # v5e-class per-chip budget


def model_flops(cfg, mode: str, seq: int, batch: int) -> float:
    """Analytic useful FLOPs per step (global, all chips)."""
    n_active = cfg.active_param_count()
    # Embedding lookup has no matmul flops; the LM head does and is already
    # inside param_count via lm_head.
    emb = cfg.padded_vocab * cfg.d_model
    n_active = max(n_active - emb, 1)
    if mode == "train":
        tokens = batch * seq
        return 6.0 * n_active * tokens
    if mode == "prefill":
        tokens = batch * seq
        return 2.0 * n_active * tokens
    return 2.0 * n_active * batch  # decode: one token per sequence


def _suggest(dom: str, rec: dict) -> str:
    mode = rec.get("mode", "?")
    if dom == "collective":
        return ("overlap weight all-gathers with compute / move FSDP gather "
                "off the critical path (or pre-shard weights for serving)")
    if dom == "memory":
        if mode == "decode":
            return ("select-based cache write rewrites the whole ring; "
                    "shard_map local-index write + log-domain merge "
                    "(paper ACC) removes it")
        return ("reduce remat recompute reads / fuse elementwise chains / "
                "bf16 the loss intermediates")
    return "compute-bound: raise useful-FLOPs ratio (less remat, less dispatch)"


def analyze(artifact_dir: str | None = None) -> list[dict]:
    """Read all single-pod artifacts and derive the roofline rows."""
    from repro.configs import get_config
    from repro.launch.specs import SHAPES

    artifact_dir = artifact_dir or ARTIFACT_DIR
    rows = []
    for path in sorted(glob.glob(os.path.join(artifact_dir, "*__single.json"))):
        rec = json.load(open(path))
        row = {"arch": rec["arch"], "shape": rec["shape"],
               "status": rec["status"]}
        if rec["status"] == "skipped":
            row["reason"] = rec.get("reason", "")
            rows.append(row)
            continue
        if rec["status"] != "ok":
            row["reason"] = (rec.get("reason") or "")[-200:]
            rows.append(row)
            continue
        cfg = get_config(rec["arch"])
        mode, seq, batch = SHAPES[rec["shape"]]
        devices = rec["devices"]
        per = rec.get("cost_corrected", {}).get("per_step")
        if per:
            # The 2-point probe fit can extrapolate a metric negative when
            # XLA optimizes the 2-group module differently; clamp to the
            # larger probe as the floor.
            p2 = rec["cost_corrected"].get("probe_2group", {})
            per = {k: max(v, p2.get(k, 0.0)) for k, v in per.items()}
        else:
            per = dict(rec.get("cost", {}))
            per["collective_bytes"] = rec["collectives"]["total_bytes"]
            row["cost_source"] = "uncorrected"
        flops = per.get("flops", 0.0)
        byts = per.get("bytes accessed", 0.0)
        colls = per.get("collective_bytes", 0.0)
        compute_s = flops / PEAK_FLOPS
        memory_s = byts / HBM_BW
        coll_s = colls / LINK_BW
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": coll_s}
        dom = max(terms, key=terms.get)
        mf = model_flops(cfg, mode, seq, batch)
        step_s = max(terms.values())
        mfu = (mf / devices / PEAK_FLOPS) / step_s if step_s > 0 else 0.0
        row.update({
            "mode": mode,
            "compute_s": compute_s,
            "memory_s": memory_s,
            "collective_s": coll_s,
            "dominant": dom,
            "model_flops_global": mf,
            "hlo_flops_device": flops,
            "useful_ratio": mf / devices / flops if flops else 0.0,
            "roofline_fraction": mfu,
            "peak_device_gib": rec["memory"]["peak_per_device_bytes"] / 2**30,
            "fits_hbm": rec["memory"]["peak_per_device_bytes"] <= HBM_BYTES,
            "suggestion": _suggest(dom, rec),
        })
        rows.append(row)
    return rows


def to_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO | roofline frac | GiB/dev | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"{r['status']} | - | - | - | {r.get('reason','')[:80]} |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3f} | "
            f"{r['memory_s']:.3f} | {r['collective_s']:.3f} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']*100:.1f}% | "
            f"{r['peak_device_gib']:.1f} | {r['suggestion'][:70]} |")
    return "\n".join(lines)


def main():
    rows = analyze()
    md = to_markdown(rows)
    out = os.path.join(os.path.dirname(ARTIFACT_DIR), "..", "roofline.md")
    os.makedirs(os.path.dirname(os.path.abspath(out)), exist_ok=True)
    with open(out, "w") as f:
        f.write("# Roofline (single-pod 16x16, v5e-class constants)\n\n"
                + md + "\n")
    print(md)


if __name__ == "__main__":
    main()
