"""Public jit'd attention ops: impl dispatch, GQA plumbing, padding.

``multihead_attention`` is what the model layer calls.  It accepts
(B, Lq, H, d) queries and (B, Lkv, Hkv, d) keys/values (Hkv | H), handles
GQA head grouping, pads sequence lengths up to block multiples, dispatches
to the chosen implementation and unpads.

Implementations:
  exact          dense softmax reference (f32)
  fa2            blocked jnp FlashAttention-2 (Alg. 2)
  hfa            bit-accurate H-FA emulation (slow; tests/small models)
  fa2_pallas     baseline Pallas TPU kernel
  hfa_pallas     hybrid float/log Pallas TPU kernel (the paper's H-FA)
  hfa_datapath   per-element LNS Pallas kernel (validation only)

On CPU the Pallas kernels run in interpret mode automatically.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hfa as core_hfa
from repro.core import reference
from repro.kernels import decode as decode_k
from repro.kernels import fa2 as fa2_k
from repro.kernels import hfa as hfa_k
from repro.kernels import hfa_datapath as dp_k
from repro.kernels import paged_decode as paged_k
from repro.kernels import paged_prefill as paged_pf_k
from repro.kernels import paged_verify as paged_v_k

IMPLS = ("exact", "fa2", "hfa", "fa2_pallas", "hfa_pallas", "hfa_datapath")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int) -> tuple[jax.Array, int]:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


def _gqa_expand(k: jax.Array, hq: int) -> jax.Array:
    """Repeat KV heads to match H query heads: (B, L, Hkv, d) -> (B, L, H, d)."""
    hkv = k.shape[2]
    if hkv == hq:
        return k
    assert hq % hkv == 0, (hq, hkv)
    return jnp.repeat(k, hq // hkv, axis=2)


# ---- differentiable Pallas attention ------------------------------------
# The forward runs the Pallas kernel.  For fa2 the backward is the
# handwritten Pallas FA-2 backward (kernels/fa2_bwd.py, using the saved
# logsumexp residual).  For hfa the backward differentiates the
# op-matched jnp oracle (ref.py) - the cotangent then follows the same
# quantized numerics the kernel computed (STE, see bitmath).
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _pallas_attention(q3, k3, v3, impl, causal, block_q, block_kv,
                      kv_len, q_offset):
    from repro.kernels import fa2 as fa2_k
    from repro.kernels import hfa as hfa_k
    interpret = not _on_tpu()
    fn = fa2_k.fa2_pallas if impl == "fa2_pallas" else hfa_k.hfa_pallas
    return fn(q3, k3, v3, causal=causal, block_q=block_q, block_kv=block_kv,
              kv_len=kv_len, q_offset=q_offset, interpret=interpret)


def _oracle(q3, k3, v3, impl, causal, block_kv, kv_len, q_offset):
    from repro.core import reference
    from repro.kernels import ref as kref
    km = k3[:, :kv_len]
    vm = v3[:, :kv_len]
    if impl == "fa2_pallas":
        part = reference.fa2_partial(q3, km, vm, causal=causal,
                                     q_offset=q_offset if causal else None,
                                     block=block_kv)
        return part.o / part.l[..., None]
    return kref.ref_hfa_mxu_padded(q3, km, vm, causal=causal,
                                   block_kv=block_kv, q_offset=q_offset)


def _pallas_attention_fwd(q3, k3, v3, impl, causal, block_q, block_kv,
                          kv_len, q_offset):
    from repro.kernels import fa2 as fa2_k
    interpret = not _on_tpu()
    if impl == "fa2_pallas":
        out, lse = fa2_k.fa2_pallas(
            q3, k3, v3, causal=causal, block_q=block_q, block_kv=block_kv,
            kv_len=kv_len, q_offset=q_offset, interpret=interpret,
            return_lse=True)
        return out, (q3, k3, v3, out, lse)
    out = _pallas_attention(q3, k3, v3, impl, causal, block_q, block_kv,
                            kv_len, q_offset)
    return out, (q3, k3, v3, None, None)


def _pallas_attention_bwd(impl, causal, block_q, block_kv, kv_len, q_offset,
                          res, g):
    q3, k3, v3, o3, lse = res
    if impl == "fa2_pallas":
        from repro.kernels import fa2_bwd
        dq, dk, dv = fa2_bwd.fa2_backward(
            q3, k3, v3, o3, g, lse, causal=causal,
            block_q=block_q, block_kv=block_kv, kv_len=kv_len,
            q_offset=q_offset, interpret=not _on_tpu())
        return dq, dk, dv
    _, vjp = jax.vjp(
        lambda q, k, v: _oracle(q, k, v, impl, causal, block_kv, kv_len,
                                q_offset), q3, k3, v3)
    dq, dk, dv = vjp(g.astype(jnp.float32))
    return (dq.astype(q3.dtype), dk.astype(k3.dtype), dv.astype(v3.dtype))


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


def multihead_attention(
    q: jax.Array,   # (B, Lq, H, d)
    k: jax.Array,   # (B, Lkv, Hkv, d)
    v: jax.Array,   # (B, Lkv, Hkv, d)
    *,
    impl: str = "fa2",
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
) -> jax.Array:
    """Multi-head attention returning (B, Lq, H, d) in q.dtype."""
    assert impl in IMPLS, impl
    b, lq, h, d = q.shape
    _, lkv, hkv, _ = k.shape

    k = _gqa_expand(k, h)
    v = _gqa_expand(v, h)

    # (B, H, L, d) layout for the core/batched refs.
    qh = jnp.swapaxes(q, 1, 2)
    kh = jnp.swapaxes(k, 1, 2)
    vh = jnp.swapaxes(v, 1, 2)

    if impl == "exact":
        out = reference.exact_attention(qh, kh, vh, causal=causal, scale=scale)
    elif impl == "fa2":
        out = reference.fa2_attention(qh, kh, vh, causal=causal, scale=scale,
                                      block=min(block_kv, lkv))
    elif impl == "hfa":
        out = core_hfa.hfa_attention(qh, kh, vh, causal=causal, scale=scale)
    else:
        interpret = not _on_tpu()
        q3 = qh.reshape(b * h, lq, d)
        k3 = kh.reshape(b * h, lkv, d)
        v3 = vh.reshape(b * h, lkv, d)
        if impl == "hfa_datapath":
            out = dp_k.hfa_datapath_pallas(q3, k3, v3, causal=causal,
                                           scale=scale, interpret=interpret)
        else:
            assert scale is None, "pallas impls use the default 1/sqrt(d)"
            q3, lq0 = _pad_to(q3, 1, block_q)
            k3, lkv0 = _pad_to(k3, 1, block_kv)
            v3, _ = _pad_to(v3, 1, block_kv)
            out = _pallas_attention(q3, k3, v3, impl, causal,
                                    block_q, block_kv, lkv0, lkv0 - lq0)
            out = out[:, :lq0]
        out = out.reshape(b, h, lq, d)

    return jnp.swapaxes(out, 1, 2).astype(q.dtype)


def decode_attention(
    q: jax.Array,        # (B, 1, H, d) single new token
    k_cache: jax.Array,  # (B, S, Hkv, d)
    v_cache: jax.Array,  # (B, S, Hkv, d)
    *,
    impl: str = "fa2",
    scale: float | None = None,
    kv_len: jax.Array | int | None = None,
    block_kv: int = 128,
) -> jax.Array:
    """Single-token decode attention against a KV cache.

    Uses the grouped-GQA partial kernel + merge/LogDiv for Pallas impls;
    jnp streaming otherwise.  ``kv_len`` masks unwritten cache slots (may
    be a traced scalar for the jnp paths).
    """
    b, one, h, d = q.shape
    assert one == 1
    _, s_len, hkv, _ = k_cache.shape
    g = h // hkv
    use_hfa = impl.startswith("hfa")

    if impl in ("fa2_pallas", "hfa_pallas") and isinstance(kv_len, (int, type(None))):
        interpret = not _on_tpu()
        kvl = s_len if kv_len is None else int(kv_len)
        qg = q.reshape(b, h, d).reshape(b, hkv, g, d).reshape(b * hkv, g, d)
        k3 = jnp.swapaxes(k_cache, 1, 2).reshape(b * hkv, s_len, d)
        v3 = jnp.swapaxes(v_cache, 1, 2).reshape(b * hkv, s_len, d)
        k3, _ = _pad_to(k3, 1, block_kv)
        v3, _ = _pad_to(v3, 1, block_kv)
        o, m, l = decode_k.decode_partial_pallas(
            qg, k3, v3, scale=scale, block_kv=block_kv, kv_len=kvl,
            use_hfa=use_hfa, interpret=interpret)
        out = decode_k.finalize_decode(o, l, use_hfa=use_hfa)
        return out.reshape(b, hkv, g, d).reshape(b, 1, h, d).astype(q.dtype)

    # jnp path (supports traced kv_len): grouped-GQA masked attention.
    qg = q.reshape(b, hkv, g, d)                        # (B, Hkv, G, d)
    out = _decode_jnp_grouped(qg, k_cache, v_cache, kv_len,
                              scale=scale, use_hfa=use_hfa,
                              acc_dtype=q.dtype)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def _decode_jnp_grouped(qg, k_cache, v_cache, kv_len, *, scale, use_hfa,
                        acc_dtype):
    """Grouped-GQA single-token decode: the L == 1 case of
    :func:`_prefill_jnp_grouped` (the single query sits at position
    ``kv_len - 1``, so the causal mask degenerates to ``< kv_len``).

    No head repeat and no f32 cache copy: the score/PV einsums read the
    bf16 ring directly with f32 accumulation - essential for the
    32k/500k sequence-sharded caches.  ``kv_len`` masks unwritten cache
    slots; it may be None, a (traced) scalar, or a per-sequence (B,)
    vector (the paged/continuous-batching case, where a 0 entry marks a
    free slot and yields a zero row).

    qg: (B, Hkv, G, d); k_cache/v_cache: (B, S, Hkv, d).
    Returns (B, Hkv, G, d) float32.
    """
    b = qg.shape[0]
    s_len = k_cache.shape[1]
    if kv_len is None:
        kvl = jnp.full((b,), s_len, jnp.int32)
    else:
        kvl = jnp.broadcast_to(jnp.asarray(kv_len, jnp.int32), (b,))
    out = _prefill_jnp_grouped(qg[:, :, :, None, :], k_cache, v_cache,
                               kvl[:, None] - 1, kvl, scale=scale,
                               use_hfa=use_hfa, acc_dtype=acc_dtype)
    return out[:, :, :, 0, :]


def _prefill_jnp_partial(qg, k_cache, v_cache, q_pos, kv_lens, *, scale,
                         use_hfa, acc_dtype):
    """Grouped-GQA chunked-prefill *partial* attention (block-FAU form).

    Same math as the Pallas kernels' triplet contract: per query row,
    ``m`` is the running max, ``p = exp(s - m)`` (or the FIX16 PWL rail
    under ``use_hfa``), ``l = sum(p)``, ``o~ = p @ V`` unnormalized.
    Returning the triplet instead of the normalized output is what lets
    a tensor-parallel shard contribute its local heads/pages to the
    log-domain ACC merge (Eq. 16) - and the single-shard path finalizes
    the *same* triplet, so sharded and unsharded decode are bit-equal
    per head.

    Fully-masked rows (free slots / padding) come back as the merge's
    *neutral* triplet (o~=0, m=NEG_INF, l=0): their pages may hold junk
    (donated buffers), and even with p == 0 the PV einsum turns NaN/Inf
    into 0 * NaN = NaN, so dead rows are forced to zero explicitly.

    qg: (B, Hkv, G, L, d); k_cache/v_cache: (B, S, Hkv, d);
    q_pos: (B, L) absolute position per chunk row; kv_lens: (B,) valid
    KV length.
    Returns (o~ (B, Hkv, G, L, d) f32, m (B, Hkv, G, L), l (B, Hkv, G, L)).
    """
    b, _, _, _, d = qg.shape
    s_len = k_cache.shape[1]
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    s = jnp.einsum("bhgld,bshd->bhgls", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale_v
    kv_ids = jnp.arange(s_len, dtype=jnp.int32)
    mask = (kv_ids[None, None, :] <= q_pos[:, :, None]) & \
        (kv_ids[None, None, :] < kv_lens.astype(jnp.int32)[:, None, None])
    s = jnp.where(mask[:, None, None, :, :], s, decode_k.NEG_INF)
    live = jnp.any(mask, axis=-1)                              # (B, L)
    m = jnp.max(s, axis=-1)
    if use_hfa:
        from repro.kernels import bitmath
        p = bitmath.exp2_hfa_rail(bitmath.quant_rail(s - m[..., None]))
    else:
        p = jnp.exp(s - m[..., None])
    # Masked positions: exp underflows to 0 for live rows, but a dead
    # row has s == m == NEG_INF, so exp(0) == 1 - zero them explicitly.
    p = jnp.where(mask[:, None, None, :, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgls,bshd->bhgld", p.astype(acc_dtype), v_cache,
                   preferred_element_type=jnp.float32)
    o = jnp.where(live[:, None, None, :, None], o, 0.0)
    return o, m, l


def _prefill_jnp_grouped(qg, k_cache, v_cache, q_pos, kv_lens, *, scale,
                         use_hfa, acc_dtype):
    """Grouped-GQA chunked-prefill attention over a gathered dense view:
    the partial block-FAU triplet (:func:`_prefill_jnp_partial`)
    finalized with LogDiv / float divide.  Full-width softmax per query
    row in f32 - the result is independent of how the prompt was cut
    into chunks, which is what makes chunked prefill token-exact.

    Returns (B, Hkv, G, L, d) float32.
    """
    o, m, l = _prefill_jnp_partial(qg, k_cache, v_cache, q_pos, kv_lens,
                                   scale=scale, use_hfa=use_hfa,
                                   acc_dtype=acc_dtype)
    return decode_k.finalize_decode(o, l, use_hfa=use_hfa)


# ---- paged attention: partial triplets ----------------------------------
# Each function returns the block-FAU triplet (o~, m, l) over whatever KV
# heads the pools it was handed contain.  The public ops below finalize
# the triplet directly; the tensor-parallel shard_map path
# (:mod:`repro.parallel.collectives`) calls the same partials on each
# shard's local heads and merges the gathered triplets with the
# log-domain ACC rule instead - so sharded and unsharded serving share
# one set of numerics.
#
# ``codec`` / ``k_scales`` / ``v_scales`` select a page codec
# (:mod:`repro.kernels.page_codec`): the Pallas kernels decode each page
# tile inside the loop (scales streamed via the same scalar-prefetch
# index map), and the jnp fallbacks decode the gathered dense view with
# the *same* codec.decode - codec=None is the raw fp pool, bit-exact to
# the pre-codec path.

def _gathered_kv(k_pages, v_pages, page_table, codec, k_scales, v_scales):
    """Dense per-sequence KV view for the jnp fallbacks, codec-decoded."""
    k_cache = paged_k.gather_pages(k_pages, page_table)
    v_cache = paged_k.gather_pages(v_pages, page_table)
    if codec is not None:
        ks = None if k_scales is None else \
            paged_k.gather_pages(k_scales, page_table)
        vs = None if v_scales is None else \
            paged_k.gather_pages(v_scales, page_table)
        k_cache = codec.decode(k_cache, ks)
        v_cache = codec.decode(v_cache, vs)
    return k_cache, v_cache


def paged_decode_partials(
    qg: jax.Array,          # (B, Hkv, G, d) grouped queries
    k_pages: jax.Array,     # (P, page, Hkv, d)
    v_pages: jax.Array,     # (P, page, Hkv, d)
    page_table: jax.Array,  # (B, pages_per_seq) int32
    kv_lens: jax.Array,     # (B,) int32; 0 marks a free slot
    *,
    impl: str = "fa2",
    scale: float | None = None,
    force_pallas: bool = False,
    codec=None,
    k_scales: jax.Array | None = None,
    v_scales: jax.Array | None = None,
):
    """Paged decode partial triplet: (o~ (B,Hkv,G,d), m/l (B,Hkv,G))."""
    b = qg.shape[0]
    use_hfa = impl.startswith("hfa")
    if force_pallas or (_on_tpu() and impl in ("fa2_pallas", "hfa_pallas")):
        return paged_k.paged_decode_partial_pallas(
            qg, k_pages, v_pages, page_table, kv_lens, scale=scale,
            use_hfa=use_hfa, interpret=not _on_tpu(), codec=codec,
            k_scales=k_scales, v_scales=v_scales)
    k_cache, v_cache = _gathered_kv(k_pages, v_pages, page_table, codec,
                                    k_scales, v_scales)
    kvl = jnp.broadcast_to(jnp.asarray(kv_lens, jnp.int32), (b,))
    o, m, l = _prefill_jnp_partial(qg[:, :, :, None, :], k_cache, v_cache,
                                   kvl[:, None] - 1, kvl, scale=scale,
                                   use_hfa=use_hfa, acc_dtype=qg.dtype)
    return o[:, :, :, 0, :], m[..., 0], l[..., 0]


def paged_prefill_partials(
    qg: jax.Array,          # (B, Hkv, G, L, d) grouped chunk queries
    k_pages: jax.Array,     # (P, page, Hkv, d)
    v_pages: jax.Array,     # (P, page, Hkv, d)
    page_table: jax.Array,  # (B, pages_per_seq) int32
    start_pos: jax.Array,   # (B,) int32 chunk start position
    kv_lens: jax.Array,     # (B,) int32 valid KV length (start + chunk)
    *,
    impl: str = "fa2",
    scale: float | None = None,
    force_pallas: bool = False,
    codec=None,
    k_scales: jax.Array | None = None,
    v_scales: jax.Array | None = None,
):
    """Paged chunked-prefill partial triplet: shapes (B,Hkv,G,L,[d])."""
    use_hfa = impl.startswith("hfa")
    if force_pallas or (_on_tpu() and impl in ("fa2_pallas", "hfa_pallas")):
        return paged_pf_k.paged_prefill_partial_pallas(
            qg, k_pages, v_pages, page_table, start_pos, kv_lens,
            scale=scale, use_hfa=use_hfa, interpret=not _on_tpu(),
            codec=codec, k_scales=k_scales, v_scales=v_scales)
    k_cache, v_cache = _gathered_kv(k_pages, v_pages, page_table, codec,
                                    k_scales, v_scales)
    l = qg.shape[3]
    q_pos = start_pos.astype(jnp.int32)[:, None] + \
        jnp.arange(l, dtype=jnp.int32)[None]
    return _prefill_jnp_partial(qg, k_cache, v_cache, q_pos, kv_lens,
                                scale=scale, use_hfa=use_hfa,
                                acc_dtype=qg.dtype)


def paged_verify_partials(
    qg: jax.Array,          # (B, Hkv, G, K, d) grouped verify queries
    k_pages: jax.Array,     # (P, page, Hkv, d)
    v_pages: jax.Array,     # (P, page, Hkv, d)
    page_table: jax.Array,  # (B, pages_per_seq) int32
    seq_lens: jax.Array,    # (B,) int32 pre-step KV length; 0 = free slot
    chunk_lens: jax.Array,  # (B,) int32 real input count this step
    *,
    impl: str = "fa2",
    scale: float | None = None,
    force_pallas: bool = False,
    codec=None,
    k_scales: jax.Array | None = None,
    v_scales: jax.Array | None = None,
):
    """Paged speculative-verify partial triplet: shapes (B,Hkv,G,K,[d])."""
    use_hfa = impl.startswith("hfa")
    if force_pallas or (_on_tpu() and impl in ("fa2_pallas", "hfa_pallas")):
        return paged_v_k.paged_verify_partial_pallas(
            qg, k_pages, v_pages, page_table, seq_lens, chunk_lens,
            scale=scale, use_hfa=use_hfa, interpret=not _on_tpu(),
            codec=codec, k_scales=k_scales, v_scales=v_scales)
    k_cache, v_cache = _gathered_kv(k_pages, v_pages, page_table, codec,
                                    k_scales, v_scales)
    kw = qg.shape[3]
    sl = seq_lens.astype(jnp.int32)
    q_pos = sl[:, None] + jnp.arange(kw, dtype=jnp.int32)[None]
    kv_lens = sl + chunk_lens.astype(jnp.int32)
    return _prefill_jnp_partial(qg, k_cache, v_cache, q_pos, kv_lens,
                                scale=scale, use_hfa=use_hfa,
                                acc_dtype=qg.dtype)


def paged_prefill_attention(
    q: jax.Array,           # (B, L, H, d) one prefill chunk per sequence
    k_pages: jax.Array,     # (P, page, Hkv, d) shared block pool
    v_pages: jax.Array,     # (P, page, Hkv, d)
    page_table: jax.Array,  # (B, pages_per_seq) int32
    start_pos: jax.Array,   # (B,) int32 chunk start position
    chunk_lens: jax.Array,  # (B,) int32 real (unpadded) chunk length
    *,
    impl: str = "fa2",
    scale: float | None = None,
    force_pallas: bool = False,
    codec=None,
    k_scales: jax.Array | None = None,
    v_scales: jax.Array | None = None,
) -> jax.Array:
    """Chunked-prefill attention against a paged KV cache.

    The chunk's K/V must already be scattered into the pools
    (:func:`repro.kernels.paged_prefill.write_chunk_kv`); queries then
    attend causally to KV positions ``<= start_pos[b] + i``.  On TPU the
    paged-prefill Pallas kernel walks the page table with scalar
    prefetch and finalizes with LogDiv for the H-FA impls; elsewhere a
    jnp path gathers the pages into a dense view (the CPU CI path).
    ``force_pallas`` pins the kernel (interpret mode off-TPU) for
    parity tests.
    """
    b, l, h, d = q.shape
    hkv = k_pages.shape[2]
    g = h // hkv
    use_hfa = impl.startswith("hfa")
    kv_lens = (start_pos + chunk_lens).astype(jnp.int32)
    # (B, L, H, d) -> (B, Hkv, G, L, d): heads are kv-major (GQA repeat).
    qg = jnp.swapaxes(q, 1, 2).reshape(b, hkv, g, l, d)
    o, m, ell = paged_prefill_partials(
        qg, k_pages, v_pages, page_table, start_pos, kv_lens, impl=impl,
        scale=scale, force_pallas=force_pallas, codec=codec,
        k_scales=k_scales, v_scales=v_scales)
    out = decode_k.finalize_decode(o, ell, use_hfa=use_hfa)
    # (B, Hkv, G, L, d) -> (B, L, H, d)
    return jnp.swapaxes(out.reshape(b, h, l, d), 1, 2).astype(q.dtype)


def paged_decode_attention(
    q: jax.Array,           # (B, 1, H, d) single new token per slot
    k_pages: jax.Array,     # (P, page, Hkv, d) shared block pool
    v_pages: jax.Array,     # (P, page, Hkv, d)
    page_table: jax.Array,  # (B, pages_per_seq) int32
    kv_lens: jax.Array,     # (B,) int32; 0 marks a free slot
    *,
    impl: str = "fa2",
    scale: float | None = None,
    force_pallas: bool = False,
    codec=None,
    k_scales: jax.Array | None = None,
    v_scales: jax.Array | None = None,
) -> jax.Array:
    """Continuous-batching decode attention against a paged KV cache.

    On TPU the paged Pallas kernel streams pages straight from HBM via
    the page table (scalar prefetch) and finalizes with LogDiv for the
    H-FA impls.  Elsewhere (or for non-Pallas impls) a jnp path gathers
    the sequence's pages into a dense view and reuses the grouped decode
    math - same numerics, XLA-compiled, which is also what the CPU CI
    exercises end-to-end.  ``force_pallas`` pins the kernel (interpret
    mode off-TPU) for parity tests.
    """
    b, one, h, d = q.shape
    assert one == 1
    hkv = k_pages.shape[2]
    g = h // hkv
    use_hfa = impl.startswith("hfa")
    qg = q.reshape(b, h, d).reshape(b, hkv, g, d)
    o, m, l = paged_decode_partials(qg, k_pages, v_pages, page_table,
                                    kv_lens, impl=impl, scale=scale,
                                    force_pallas=force_pallas, codec=codec,
                                    k_scales=k_scales, v_scales=v_scales)
    out = decode_k.finalize_decode(o, l, use_hfa=use_hfa)
    return out.reshape(b, 1, h, d).astype(q.dtype)


def paged_verify_attention(
    q: jax.Array,           # (B, K, H, d) K verify tokens per slot
    k_pages: jax.Array,     # (P, page, Hkv, d) shared block pool
    v_pages: jax.Array,     # (P, page, Hkv, d)
    page_table: jax.Array,  # (B, pages_per_seq) int32
    seq_lens: jax.Array,    # (B,) int32 pre-step KV length; 0 = free slot
    chunk_lens: jax.Array,  # (B,) int32 real input count this step
    *,
    impl: str = "fa2",
    scale: float | None = None,
    force_pallas: bool = False,
    codec=None,
    k_scales: jax.Array | None = None,
    v_scales: jax.Array | None = None,
) -> jax.Array:
    """Multi-query speculative-verify attention against a paged KV cache.

    The step's K tokens (carry + drafts) must already be scattered into
    the pools at positions ``seq_lens[b]..``; query row i attends
    causally to KV ``<= seq_lens[b] + i`` (and ``< seq_lens[b] +
    chunk_lens[b]``), so all K positions are scored in one page-table
    walk.  With K == 1 this computes exactly
    :func:`paged_decode_attention` on the post-append cache.  On TPU the
    dedicated verify kernel walks the table with scalar prefetch;
    elsewhere the jnp gather path reuses the grouped chunk math (same
    numerics as the decode path, which is what makes k-step spec decode
    token-exact).  Rows at ``i >= chunk_lens[b]`` are garbage the caller
    ignores; ``chunk_lens[b] == 0`` rows come back zero.
    """
    b, kw, h, d = q.shape
    hkv = k_pages.shape[2]
    g = h // hkv
    use_hfa = impl.startswith("hfa")
    qg = jnp.swapaxes(q, 1, 2).reshape(b, hkv, g, kw, d)
    o, m, l = paged_verify_partials(
        qg, k_pages, v_pages, page_table, seq_lens, chunk_lens, impl=impl,
        scale=scale, force_pallas=force_pallas, codec=codec,
        k_scales=k_scales, v_scales=v_scales)
    out = decode_k.finalize_decode(o, l, use_hfa=use_hfa)
    # (B, Hkv, G, K, d) -> (B, K, H, d)
    return jnp.swapaxes(out.reshape(b, h, kw, d), 1, 2).astype(q.dtype)
