"""Pluggable page codecs for the paged KV block pool.

The serving engine's KV pools were "one array per layer in the compute
dtype".  A :class:`PageCodec` generalizes that layout into encode-on-
write / decode-in-kernel: the pools hold *encoded* pages in the codec's
storage dtype, an optional per-page **scale sidecar** rides next to them
(same (P, page, Hkv, ·) rank, trailing dim 1, so every page-table
mechanism - scatter writers, COW ``copy_pages``, ``gather_pages``, the
TP ``NamedSharding`` placement - applies to scale leaves unchanged),
and the paged kernels dequantize inside the tile loop right after the
page DMA.

Codecs:

  fp     identity - pages stored in the compute dtype, no sidecar.
         Bit-exact to the pre-codec pool; the default.
  int8   per-page absmax int8.  One f32 scale per token row per KV head
         (a row-granular refinement of per-page absmax: appending one
         token never re-encodes the page's other rows, so decode-append
         stays a pure scatter).  decode = data * scale.
  log16  FIX16 log-domain pages on the H-FA rail (paper Sec. IV-V).
         ``lns.blinn_log2`` quantizes each element to the (sign, rail)
         pair and the two are bit-packed as ``sign<<15 | (rail +
         bias<<7)`` - which is exactly the BFloat16 bit layout (Eq. 18
         and Eq. 22 are inverses), so dequant on the hfa rail is a
         bitcast: the page IS the log-domain operand.  No sidecar;
         bytes halve vs an fp32 pool and drift is bounded by bf16
         rounding of the source values.

Byte accounting lives here too (:meth:`PageCodec.bytes_per_row` /
:func:`bytes_per_token`), so ``serving.engine`` and the benchmark
scoreboard derive slots-at-equal-pool-bytes from one source of truth.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lns
from repro.core.numerics import BF16_BIAS, FRAC_BITS, LOG_ZERO

CODECS = ("fp", "int8", "log16")

_SCALE_DTYPE = jnp.float32


class PageCodec:
    """Encode-on-write / decode-in-kernel page transform.

    ``encode(x)`` maps compute-dtype values ``(..., d)`` to
    ``(data, scales)`` where ``data`` has :meth:`storage_dtype` and
    ``scales`` is ``(..., 1)`` f32 (or None when :attr:`has_scales` is
    False).  ``decode(data, scales)`` is the f32 inverse; it must be
    cheap enough to run inside a Pallas tile loop (the jnp fallback
    paths call the identical function on gathered pages, so kernel and
    fallback agree by construction).
    """

    name: str = "?"
    has_scales: bool = False

    def storage_dtype(self, ref_dtype):
        raise NotImplementedError

    def encode(self, x: jax.Array):
        raise NotImplementedError

    def decode(self, data: jax.Array, scales: jax.Array | None):
        raise NotImplementedError

    def bytes_per_row(self, d: int, ref_dtype) -> int:
        """Stored bytes for one token row of one KV head (d elements
        plus this codec's share of the scale sidecar)."""
        raise NotImplementedError


class FpCodec(PageCodec):
    """Identity codec: today's pool, bit-exact."""

    name = "fp"
    has_scales = False

    def storage_dtype(self, ref_dtype):
        return ref_dtype

    def encode(self, x):
        return x, None

    def decode(self, data, scales):
        return data.astype(jnp.float32)

    def bytes_per_row(self, d, ref_dtype):
        return d * jnp.dtype(ref_dtype).itemsize


class Int8Codec(PageCodec):
    """Per-page absmax int8 with a per-row f32 scale sidecar."""

    name = "int8"
    has_scales = True

    def storage_dtype(self, ref_dtype):
        return jnp.int8

    def encode(self, x):
        xf = x.astype(jnp.float32)
        amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
        scale = amax / 127.0
        safe = jnp.where(scale > 0, scale, 1.0)
        data = jnp.clip(jnp.round(xf / safe), -127, 127).astype(jnp.int8)
        return data, scale.astype(_SCALE_DTYPE)

    def decode(self, data, scales):
        return data.astype(jnp.float32) * scales.astype(jnp.float32)

    def bytes_per_row(self, d, ref_dtype):
        return d + jnp.dtype(_SCALE_DTYPE).itemsize


class Log16Codec(PageCodec):
    """FIX16 log-domain pages: Blinn-quantized (sign, rail) bit-packs.

    Encode runs the paper's Eq. 18 (``lns.blinn_log2``) and packs the
    pair as ``sign << 15 | (rail + BF16_BIAS << FRAC_BITS)`` in uint16.
    That packing coincides with the BFloat16 bit pattern (the Eq. 22
    inverse is exact for integer rail values), so decode is a bitcast -
    on the hfa rail the stored page is already the log-domain operand
    and dequantization costs one type reinterpretation.
    """

    name = "log16"
    has_scales = False

    def storage_dtype(self, ref_dtype):
        return jnp.uint16

    def encode(self, x):
        sign, raw = lns.blinn_log2(x)
        mag = raw + (BF16_BIAS << FRAC_BITS)
        mag = jnp.clip(mag, 0, 0x7FFF)
        mag = jnp.where(raw <= LOG_ZERO, 0, mag.astype(jnp.int32))
        bits = jnp.left_shift(sign, 15) | mag
        return bits.astype(jnp.uint16), None

    def decode(self, data, scales):
        return jax.lax.bitcast_convert_type(
            data, jnp.bfloat16).astype(jnp.float32)

    def bytes_per_row(self, d, ref_dtype):
        return d * 2


_REGISTRY: dict[str, PageCodec] = {
    c.name: c for c in (FpCodec(), Int8Codec(), Log16Codec())
}


def get_codec(name: str | PageCodec | None) -> PageCodec:
    """Resolve a codec by name (None -> fp); PageCodec passes through."""
    if name is None:
        return _REGISTRY["fp"]
    if isinstance(name, PageCodec):
        return name
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown page codec {name!r}; have {sorted(_REGISTRY)}")


def bytes_per_token(codec, hkv: int, d: int, ref_dtype) -> int:
    """Stored KV bytes per token position per layer: K + V rows across
    all KV heads, scale sidecar included."""
    return 2 * hkv * get_codec(codec).bytes_per_row(d, ref_dtype)


def decode_pages(codec, pages: jax.Array,
                 scales: jax.Array | None) -> jax.Array:
    """Decode a whole (or gathered) pool view to f32 (jnp fallback /
    oracle path - the Pallas kernels call ``codec.decode`` per tile)."""
    return get_codec(codec).decode(pages, scales)


def encode_write(writer, codec, pools: dict, k_new: jax.Array,
                 v_new: jax.Array, *args) -> dict:
    """Encode-on-write: run ``codec.encode`` on this step's K/V and push
    data (and scale sidecars) through ``writer(kp, vp, k, v, *args)``.

    ``writer`` is any of the page scatter ops (``append_kv``,
    ``write_chunk_kv``, ``write_prefill_kv``) - they are dtype- and
    trailing-dim-agnostic, so the (B, L, Hkv, 1) scale rows ride through
    the *same* page-table-resolved scatter (same drop semantics) as the
    (B, L, Hkv, d) data rows.  ``pools`` holds "k_pages"/"v_pages" and,
    for codecs with scales, "k_scale"/"v_scale"; the returned dict has
    the same keys.  The fp codec's encode is the identity, so its write
    is bit-exact to the pre-codec path.
    """
    c = get_codec(codec)
    kd, ks = c.encode(k_new)
    vd, vs = c.encode(v_new)
    kp, vp = writer(pools["k_pages"], pools["v_pages"], kd, vd, *args)
    out = {"k_pages": kp, "v_pages": vp}
    if c.has_scales:
        ksp, vsp = writer(pools["k_scale"], pools["v_scale"], ks, vs, *args)
        out["k_scale"] = ksp
        out["v_scale"] = vsp
    return out
