"""Bit-trick float math shared by the TPU H-FA kernels.

These are the TPU-native adaptations of the paper's hardware blocks: on an
ASIC they are wire reinterpretations + small adders; on a TPU VPU they are
an integer bitcast + add/shift - still far cheaper than transcendental
``exp``/``log`` or a vector divide.

All functions are pure jnp and trace inside Pallas kernel bodies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import lns
from repro.core.numerics import FRAC_BITS, FRAC_ONE

F32_BIAS = 127
F32_MANT = 23


def exp2_int(p: jax.Array) -> jax.Array:
    """Exact 2^p for integer-valued float/int p via exponent-field packing."""
    pi = jnp.clip(p.astype(jnp.int32), -126, 127)
    bits = jnp.left_shift(pi + F32_BIAS, F32_MANT)
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def log2_mitchell_f32(x: jax.Array) -> jax.Array:
    """Blinn/Mitchell log2 of |x| for positive float32 x (Eq. 18 on f32 bits).

    log2(x) ~= E + M (pseudo-log): one bitcast, one int subtract, one scale.
    """
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    mag = jnp.bitwise_and(bits, 0x7FFFFFFF)
    return (mag - (F32_BIAS << F32_MANT)).astype(jnp.float32) * (2.0 ** -F32_MANT)


def exp2_mitchell_f32(y: jax.Array) -> jax.Array:
    """Inverse Mitchell 2^y ~= bit-pack of (I+bias, F) for float32 y."""
    yi = jnp.floor(y)
    f = y - yi
    pi = jnp.clip(yi.astype(jnp.int32), -126, 127)
    bits = jnp.left_shift(pi + F32_BIAS, F32_MANT) + jnp.round(
        f * (1 << F32_MANT)).astype(jnp.int32)
    out = jax.lax.bitcast_convert_type(bits, jnp.float32)
    return jnp.where(y < -126.0, 0.0, out)


def pwl_exp2_frac_f32(f: jax.Array) -> jax.Array:
    """The paper's 8-segment PWL 2^{-f}, f in [0,1), on float values.

    Uses the same Q1.15 LUT coefficients as the FIX16 datapath
    (:mod:`repro.core.lns`); the select chain uses literal constants only
    (cheap on the VPU - no gather needed).
    """
    seg = jnp.clip(jnp.floor(f * 8.0), 0, 7)
    av = lns._lut8(seg, lns.PWL_SLOPES_Q15)
    bv = lns._lut8(seg, lns.PWL_INTERCEPTS_Q15)
    return (av * f + bv) * (2.0 ** -15)


def _ste(hw: jax.Array, smooth: jax.Array) -> jax.Array:
    """Straight-through estimator: forward = hw (bit-exact), grad = smooth.

    The quantize/PWL/floor chain has zero derivative almost everywhere;
    training through the H-FA numerics uses the standard QAT surrogate.
    Inside a Pallas kernel body stop_gradient is a no-op, so the kernels
    keep their exact forward semantics.
    """
    return smooth + jax.lax.stop_gradient(hw - smooth)


def exp2_hfa_rail(rail: jax.Array) -> jax.Array:
    """H-FA hardware 2^{rail/128} for a non-positive FIX16 rail value.

    Splits into integer/fraction, PWL for the fractional 2^{-f}, exponent
    packing for the 2^{-p} shift.  Quantizes the PWL output to the 7-bit
    rail exactly like the FIX16 datapath, so this matches
    ``lns.exp2_neg`` bit-for-bit on integer rails.  STE backward.
    """
    d = -rail  # non-negative
    p = jnp.floor(d / FRAC_ONE)
    f7 = d - p * FRAC_ONE
    g7 = lns.pwl_exp2_frac(f7)          # fraction rail in [64, 128]
    hw = (g7 * (1.0 / FRAC_ONE)) * exp2_int(-p)
    return _ste(hw, jnp.exp2(rail * (1.0 / FRAC_ONE)))


def quant_rail(diff_nat: jax.Array) -> jax.Array:
    """quant[(.)*log2 e] to the FIX16 rail (Eq. 14b/c). STE backward."""
    diff = jnp.clip(diff_nat, lns.DIFF_CLAMP_NAT, 0.0)
    return _ste(jnp.round(diff * lns.LOG2E * FRAC_ONE),
                diff * lns.LOG2E * FRAC_ONE)


def recip_logdiv(ell: jax.Array) -> jax.Array:
    """1/ell without a divider: Blinn log2, rail negate, inverse bit-pack.

    This is the LogDiv unit's division-free normalization adapted to a
    float accumulator: |1/ell| = 2^{-log2 ell}.  Uses the FIX16 rail
    quantization so the error sources match the paper's LogDiv.
    """
    # Blinn forward on f32 bits, quantized to the 7-bit fraction rail.
    rail = jnp.round(log2_mitchell_f32(ell) * FRAC_ONE)
    neg = -rail
    i_part = jnp.floor(neg / FRAC_ONE)
    f_part = neg / FRAC_ONE - i_part
    hw = exp2_int(i_part) * (1.0 + f_part)
    return _ste(hw, 1.0 / ell)
