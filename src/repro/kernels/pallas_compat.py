"""Version compatibility shims for the Pallas TPU API surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
around 0.5; the kernels in this package run against both, so resolve
the name once here instead of pinning a jax version.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
