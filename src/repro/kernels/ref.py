"""Pure-jnp oracles for every Pallas kernel (tested with assert_allclose).

  * ``ref_fa2``          - float FlashAttention-2 == exact attention.
  * ``ref_hfa_mxu``      - tile-level H-FA with identical op order /
                           quantization to kernels/hfa.py (bit-matched).
  * ``ref_decode_partial`` - partial (o~, m, l) triplet for one KV span.
  * ``ref_hfa_datapath`` - the core.hfa bit-accurate emulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import hfa as core_hfa
from repro.core import reference
from repro.kernels import bitmath

NEG_INF = -1e30


def ref_fa2(q, k, v, *, causal=False, scale=None):
    """Oracle for fa2.py: exact attention in f32."""
    return reference.exact_attention(q, k, v, causal=causal, scale=scale)


def ref_hfa_mxu(q, k, v, *, causal=False, scale=None, block_kv=128,
                q_offset=None):
    """Oracle for hfa.py: same tile walk, same quant/PWL/bit-pack ops.

    Processes KV in blocks of ``block_kv`` sequentially (the kernel's
    'arbitrary' grid axis), queries all at once (grid-parallel axes
    commute).  KV length may be a non-multiple of ``block_kv`` (padded and
    masked internally); ``q_offset`` overrides the causal row of query 0.
    """
    d = q.shape[-1]
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    lq, lkv = q.shape[-2], k.shape[-2]
    nblk = (lkv + block_kv - 1) // block_kv
    pad = nblk * block_kv - lkv
    if pad:
        widths = [(0, 0)] * (k.ndim - 2) + [(0, pad), (0, 0)]
        k = jnp.pad(k, widths)
        v = jnp.pad(v, widths)
    if q_offset is None:
        q_offset = lkv - lq

    qf = q.astype(jnp.float32)
    batch = q.shape[:-2]
    m = jnp.full(batch + (lq,), NEG_INF, jnp.float32)
    l = jnp.zeros(batch + (lq,), jnp.float32)
    acc = jnp.zeros(batch + (lq, d), jnp.float32)

    for ik in range(nblk):
        sl = slice(ik * block_kv, (ik + 1) * block_kv)
        kb = k[..., sl, :].astype(jnp.float32)
        vb = v[..., sl, :].astype(jnp.float32)
        s = jnp.einsum("...qd,...kd->...qk", qf, kb) * scale_v
        s = s.astype(jnp.bfloat16).astype(jnp.float32)
        kv_ids = ik * block_kv + jnp.arange(block_kv)[None, :]
        mask = jnp.broadcast_to(kv_ids < lkv, s.shape)
        if causal:
            q_ids = q_offset + jnp.arange(lq)[:, None]
            mask = mask & jnp.broadcast_to(kv_ids <= q_ids, s.shape)
            if (ik * block_kv) > q_offset + lq - 1:
                continue  # kernel skips blocks above the diagonal
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = bitmath.exp2_hfa_rail(
            bitmath.quant_rail(jnp.minimum(m - m_new, 0.0)))
        p = bitmath.exp2_hfa_rail(bitmath.quant_rail(s - m_new[..., None]))
        p = jnp.where(mask & (m_new != NEG_INF)[..., None], p, 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("...qk,...kd->...qd", p, vb)
        m = m_new

    safe = jnp.where(l <= 0.0, 1.0, l)
    recip = bitmath.recip_logdiv(safe)
    recip = jnp.where(l <= 0.0, 0.0, recip)
    return acc * recip[..., None]


# alias used by the custom_vjp backward in ops.py
ref_hfa_mxu_padded = ref_hfa_mxu


def ref_decode_partial(q, k, v, *, scale=None, use_hfa=False, block_kv=128):
    """Oracle for decode.py: streamed partial triplet over one KV span."""
    d = q.shape[-1]
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    lkv = k.shape[-2]
    nblk = lkv // block_kv
    qf = q.astype(jnp.float32)
    batch = q.shape[:-1]  # (..., G)
    m = jnp.full(batch[:-1] + (q.shape[-2],), NEG_INF, jnp.float32)
    l = jnp.zeros_like(m)
    acc = jnp.zeros(m.shape + (d,), jnp.float32)
    for ik in range(nblk):
        sl = slice(ik * block_kv, (ik + 1) * block_kv)
        kb = k[..., sl, :].astype(jnp.float32)
        vb = v[..., sl, :].astype(jnp.float32)
        s = jnp.einsum("...gd,...kd->...gk", qf, kb) * scale_v
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        if use_hfa:
            alpha = bitmath.exp2_hfa_rail(
                bitmath.quant_rail(jnp.minimum(m - m_new, 0.0)))
            p = bitmath.exp2_hfa_rail(bitmath.quant_rail(s - m_new[..., None]))
        else:
            alpha = jnp.exp(jnp.minimum(m - m_new, 0.0))
            p = jnp.exp(s - m_new[..., None])
        p = jnp.where((m_new != NEG_INF)[..., None], p, 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("...gk,...kd->...gd", p, vb)
        m = m_new
    return acc, m, l


def ref_hfa_datapath(q, k, v, *, causal=False, scale=None):
    """Oracle for hfa_datapath.py: the bit-accurate core emulation."""
    return core_hfa.hfa_attention(q, k, v, causal=causal, scale=scale)
