"""Paged chunked-prefill Pallas kernel + chunk-write / page-copy ops.

Chunked prefill is the serving-side dual of :mod:`paged_decode`: instead
of one query token per sequence, a bounded *chunk* of L prompt tokens
(starting at an arbitrary per-slot offset ``start_pos``) attends
causally against everything already materialized in the paged KV pools
- the shared-prefix pages claimed at admission, earlier chunks, and the
chunk itself, which is scattered into the pools before attention runs.

The kernel walks the sequence's page table with scalar prefetch (page id
feeds the BlockSpec index map, so non-contiguous pages DMA straight from
HBM) and streams each page through the Alg. 2 online update, exactly
like ``paged_decode.py`` but with G*L query rows per (sequence, kv head)
instead of G.  It emits the same partial triplet (m, l, o~), so the
log-domain ACC merge and LogDiv finalize are reused unchanged, and
``use_hfa`` swaps the exponentials for the FIX16 PWL/bit-pack datapath.

Also here: ``write_chunk_kv`` (position-exact scatter of a chunk's K/V
through the page table - padded tail rows are dropped, never written, so
shared copy-on-write pages stay intact) and ``copy_pages`` (the device
side of copy-on-write: duplicate page contents inside a pool).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat
from repro.kernels import bitmath
from repro.kernels.decode import LANES, NEG_INF
from repro.kernels.paged_decode import _flat_write_pos, _load_tile


def _paged_prefill_kernel(pt_ref, sp_ref, kl_ref, q_ref, k_ref, v_ref,
                          *rest, page_size: int, chunk: int, scale: float,
                          use_hfa: bool, codec=None):
    if codec is not None and codec.has_scales:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)           # (G * chunk, d)
    k = _load_tile(codec, k_ref, ks_ref)          # (page, d)
    v = _load_tile(codec, v_ref, vs_ref)          # (page, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kv_ids = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # Row r of the flattened (G, chunk) query block is local chunk
    # position r % chunk, i.e. absolute position start + r % chunk.
    q_pos = sp_ref[b] + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 0) % chunk
    mask = (kv_ids <= q_pos) & (kv_ids < kl_ref[b])
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    if use_hfa:
        alpha = bitmath.exp2_hfa_rail(
            bitmath.quant_rail(jnp.minimum(m_prev - m_new, 0.0)))
        p = bitmath.exp2_hfa_rail(bitmath.quant_rail(s - m_new[:, None]))
    else:
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask & (m_new != NEG_INF)[:, None], p, 0.0)

    l_scr[:, 0] = l_scr[:, 0] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[:, 0] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        o_ref[0, 0] = acc_scr[...].astype(o_ref.dtype)
        m_ref[0, 0, :, 0] = m_scr[:, 0]
        l_ref[0, 0, :, 0] = l_scr[:, 0]


def paged_prefill_partial_pallas(
    q: jax.Array,           # (B, Hkv, G, L, d) grouped chunk queries
    k_pages: jax.Array,     # (P, page, Hkv, d) shared block pool
    v_pages: jax.Array,     # (P, page, Hkv, d)
    page_table: jax.Array,  # (B, pages_per_seq) int32 page ids
    start_pos: jax.Array,   # (B,) int32 chunk start position per sequence
    kv_lens: jax.Array,     # (B,) int32 valid KV length (start + chunk len)
    *,
    scale: float | None = None,
    use_hfa: bool = False,
    interpret: bool = True,
    codec=None,
    k_scales: jax.Array | None = None,  # (P, page, Hkv, 1) f32 sidecar
    v_scales: jax.Array | None = None,
):
    """Partial paged chunked-prefill attention.

    Query row (g, l) of sequence b sits at absolute position
    ``start_pos[b] + l`` and attends causally to KV positions
    ``<= start_pos[b] + l`` (and ``< kv_lens[b]``).  Rows at ``l >=``
    the real chunk length read valid KV but produce garbage the caller
    ignores.  Page-table entries past ``ceil(kv_lens[b] / page)`` may be
    any valid page id (masked out).

    Returns:
      (o~, m, l): o~ (B, Hkv, G, L, d) unnormalized f32 accumulator,
      m/l (B, Hkv, G, L) running max / sum-of-exps - the same block-FAU
      triplet contract as :func:`repro.kernels.paged_decode.
      paged_decode_partial_pallas`, mergeable/finalizable with
      :mod:`repro.kernels.decode`.
    """
    b, hkv, g, chunk, d = q.shape
    _, page_size, hkv_p, _ = k_pages.shape
    assert hkv_p == hkv, (hkv_p, hkv)
    pages_per_seq = page_table.shape[1]
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    rows = g * chunk
    q3 = q.reshape(b, hkv, rows, d)
    has_scales = codec is not None and codec.has_scales

    kernel = functools.partial(_paged_prefill_kernel, page_size=page_size,
                               chunk=chunk, scale=scale_v, use_hfa=use_hfa,
                               codec=codec)
    in_specs = [
        pl.BlockSpec((1, 1, rows, d),
                     lambda b, h, j, pt, sp, kl: (b, h, 0, 0)),
        pl.BlockSpec((1, page_size, 1, d),
                     lambda b, h, j, pt, sp, kl: (pt[b, j], 0, h, 0)),
        pl.BlockSpec((1, page_size, 1, d),
                     lambda b, h, j, pt, sp, kl: (pt[b, j], 0, h, 0)),
    ]
    operands = [q3, k_pages, v_pages]
    if has_scales:
        in_specs += [
            pl.BlockSpec((1, page_size, 1, 1),
                         lambda b, h, j, pt, sp, kl: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, 1),
                         lambda b, h, j, pt, sp, kl: (pt[b, j], 0, h, 0)),
        ]
        operands += [k_scales, v_scales]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, pages_per_seq),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda b, h, j, pt, sp, kl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, rows, 1),
                         lambda b, h, j, pt, sp, kl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, rows, 1),
                         lambda b, h, j, pt, sp, kl: (b, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, d), jnp.float32),
        ],
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, rows, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, rows, 1), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_prefill_partial",
    )(page_table.astype(jnp.int32), start_pos.astype(jnp.int32),
      kv_lens.astype(jnp.int32), *operands)
    return (o.reshape(b, hkv, g, chunk, d),
            m[..., 0].reshape(b, hkv, g, chunk),
            l[..., 0].reshape(b, hkv, g, chunk))


# ------------------------------------------------------- page cache ops
def write_chunk_kv(k_pages, v_pages, k_new, v_new, page_table, start_pos,
                   chunk_lens):
    """Position-exact scatter of a prefill chunk's K/V into the pools.

    k_new/v_new: (B, L, Hkv, d); row b's token i lands at position
    ``start_pos[b] + i``.  Rows with ``i >= chunk_lens[b]`` (padding)
    are DROPPED, not written - unlike the fresh-prefill scatter this
    never touches positions outside the chunk, so shared prefix pages
    below ``start_pos`` and pages beyond the chunk stay intact.
    """
    p, page_size, hkv, d = k_pages.shape
    b, l, _, _ = k_new.shape
    offs = jnp.arange(l, dtype=jnp.int32)[None]                # (1, L)
    pos = start_pos.astype(jnp.int32)[:, None] + offs          # (B, L)
    flat = _flat_write_pos(page_table.astype(jnp.int32), pos, page_size)
    valid = offs < chunk_lens.astype(jnp.int32)[:, None]
    flat = jnp.where(valid, flat, p * page_size)               # OOB => drop
    flat = flat.reshape(-1)
    kf = k_pages.reshape(p * page_size, hkv, d)
    vf = v_pages.reshape(p * page_size, hkv, d)
    kf = kf.at[flat].set(k_new.reshape(b * l, hkv, d).astype(kf.dtype),
                         mode="drop")
    vf = vf.at[flat].set(v_new.reshape(b * l, hkv, d).astype(vf.dtype),
                         mode="drop")
    return kf.reshape(k_pages.shape), vf.reshape(v_pages.shape)


def copy_pages(pages: jax.Array, src: jax.Array, dst: jax.Array,
               axis: int = 0) -> jax.Array:
    """Device side of copy-on-write: ``pages[dst[i]] = pages[src[i]]``
    along ``axis``.  Padding entries use an out-of-range ``dst`` (the
    write is dropped); ``src`` is clipped so the dead gather stays in
    bounds.  ``axis`` selects the page dimension (1 for the stacked
    (groups, P, page, Hkv, d) layer pools)."""
    vals = jnp.take(pages, src.astype(jnp.int32), axis=axis, mode="clip")
    idx = (slice(None),) * axis + (dst.astype(jnp.int32),)
    return pages.at[idx].set(vals, mode="drop")
