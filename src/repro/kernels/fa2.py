"""Baseline FlashAttention-2 Pallas TPU kernel (paper's 'FA-2' datapath).

Tiled per Alg. 2: grid (batch*heads, q_blocks, kv_blocks) with the KV axis
innermost/sequential; the running (m, l, acc) state lives in VMEM scratch
and is rescaled online (lines 4-6).  Block shapes are MXU-aligned
(multiples of 128 on the KV/lane dims; head_dim padded by the wrapper).

This kernel is the float reference datapath that H-FA is compared against,
matching the paper's hardware evaluation setup.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

NEG_INF = -1e30
LANES = 128


def _fa2_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, block_q: int, block_kv: int,
                kv_len: int, q_offset: int):
    """One (q_block, kv_block) step of Alg. 2."""
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q + q_offset          # global row of first query
    k_start = ik * block_kv                    # global col of first key

    def _visit():
        q = q_ref[0].astype(jnp.float32)       # (bq, d)
        k = k_ref[0].astype(jnp.float32)       # (bk, d)
        v = v_ref[0].astype(jnp.float32)       # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)

        # Mask: KV padding + (optionally) the causal triangle.
        kv_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kv_ids < kv_len
        if causal:
            q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (kv_ids <= q_ids)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask & (m_new != NEG_INF)[:, None], p, 0.0)

        l_scr[:, 0] = l_scr[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_new

    if causal:
        # Skip blocks strictly above the diagonal.
        pl.when(k_start <= q_start + block_q - 1)(_visit)
    else:
        _visit()

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)
        # logsumexp residual for the backward kernels
        lse_ref[0, :, 0] = m_scr[:, 0] + jnp.log(safe)


def fa2_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    kv_len: int | None = None,
    q_offset: int | None = None,
    interpret: bool = True,
    out_dtype=jnp.float32,
    return_lse: bool = False,
):
    """Tiled FA-2 over (BH, Lq, d) x (BH, Lkv, d) -> (BH, Lq, d).

    Lq/Lkv must be multiples of the block sizes (the ops.py wrapper pads).
    ``kv_len`` masks KV padding; ``q_offset`` is the global index of query
    row 0 (causal offset, = Lkv - Lq for suffix queries).  With
    ``return_lse`` also returns the per-row logsumexp (backward residual).
    """
    bh, lq, d = q.shape
    _, lkv, _ = k.shape
    assert lq % block_q == 0 and lkv % block_kv == 0, (lq, lkv)
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    kv_len = lkv if kv_len is None else kv_len
    q_offset = (lkv - lq) if q_offset is None else q_offset

    grid = (bh, lq // block_q, lkv // block_kv)
    kernel = functools.partial(
        _fa2_kernel, scale=scale_v, causal=causal, block_q=block_q,
        block_kv=block_kv, kv_len=kv_len, q_offset=q_offset)

    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_q, 1), lambda b, iq, ik: (b, iq, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, lq, d), out_dtype),
            jax.ShapeDtypeStruct((bh, lq, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),   # m
            pltpu.VMEM((block_q, LANES), jnp.float32),   # l
            pltpu.VMEM((block_q, d), jnp.float32),       # acc
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="fa2_fwd",
    )(q, k, v)
    if return_lse:
        return out, lse[..., 0]
    return out
