"""H-FA Pallas TPU kernel: hybrid float/log FlashAttention (paper Sec. IV-V).

TPU-native adaptation of the H-FA datapath (see DESIGN.md):

  * scores ``s = qk^T`` stay in floating point on the MXU, rounded to BF16
    (the paper's dot-product unit is BF16);
  * the exponential terms 2^{quant[(m_prev-m)log2e]} and
    2^{quant[(s-m)log2e]} use the paper's FIX16 (9.7) quantization and the
    8-segment PWL + exponent-bit-packing - no transcendental exp anywhere;
  * the final softmax division is replaced by the LogDiv unit: Blinn
    forward log2 on l, rail negation, inverse Mitchell bit-pack - a
    division-free reciprocal;
  * ``P~ . V`` remains an MXU matmul: on TPU the per-element LNS adder of
    the ASIC cannot beat the systolic array, so the *accumulation* is kept
    in linear float while every exp/div is from the paper's log datapath.
    The per-element LNS datapath itself is validated separately in
    ``hfa_datapath.py``.

Error sources (quantization, Mitchell, PWL) are therefore the same three
as the paper's Table III, at tile granularity.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat
from repro.kernels import bitmath

NEG_INF = -1e30
LANES = 128


def _hfa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, block_q: int, block_kv: int,
                kv_len: int, q_offset: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q + q_offset
    k_start = ik * block_kv

    def _visit():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        s = s.astype(jnp.bfloat16).astype(jnp.float32)  # BF16 score datapath

        kv_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kv_ids < kv_len
        if causal:
            q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (kv_ids <= q_ids)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))

        # --- log-domain exponential terms (Eq. 14b/c): FIX16 quantization,
        # PWL 2^{-f}, exponent packing. No exp(), no exp2() calls.
        dm_rail = bitmath.quant_rail(jnp.minimum(m_prev - m_new, 0.0))
        alpha = bitmath.exp2_hfa_rail(dm_rail)               # (bq,)
        ds_rail = bitmath.quant_rail(s - m_new[:, None])
        p = bitmath.exp2_hfa_rail(ds_rail)                   # (bq, bk)
        p = jnp.where(mask & (m_new != NEG_INF)[:, None], p, 0.0)

        l_scr[:, 0] = l_scr[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[:, 0] = m_new

    if causal:
        pl.when(k_start <= q_start + block_q - 1)(_visit)
    else:
        _visit()

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[:, 0]
        safe = jnp.where(l <= 0.0, 1.0, l)
        # LogDiv: division-free normalization via the log-domain reciprocal.
        recip = bitmath.recip_logdiv(safe)
        recip = jnp.where(l <= 0.0, 0.0, recip)
        o_ref[0] = (acc_scr[...] * recip[:, None]).astype(o_ref.dtype)


def hfa_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    block_q: int = 128,
    block_kv: int = 128,
    kv_len: int | None = None,
    q_offset: int | None = None,
    interpret: bool = True,
    out_dtype=jnp.float32,
) -> jax.Array:
    """Tiled H-FA over (BH, Lq, d) x (BH, Lkv, d) -> (BH, Lq, d)."""
    bh, lq, d = q.shape
    _, lkv, _ = k.shape
    assert lq % block_q == 0 and lkv % block_kv == 0, (lq, lkv)
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    kv_len = lkv if kv_len is None else kv_len
    q_offset = (lkv - lq) if q_offset is None else q_offset

    grid = (bh, lq // block_q, lkv // block_kv)
    kernel = functools.partial(
        _hfa_kernel, scale=scale_v, causal=causal, block_q=block_q,
        block_kv=block_kv, kv_len=kv_len, q_offset=q_offset)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lq, d), out_dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="hfa_fwd",
    )(q, k, v)
