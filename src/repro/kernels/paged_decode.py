"""Paged flash-decode Pallas kernel + device-side page-table cache ops.

The serving engine stores each layer's KV cache as a shared *block pool*
of fixed-size pages, ``(num_pages, page_size, Hkv, d)``, addressed by a
per-sequence page table ``(B, pages_per_seq)`` - vLLM's PagedAttention
layout mapped onto the paper's multi-KV-block FAU architecture (Fig. 2):

  * Every page is one KV block.  The kernel walks a sequence's page
    table with scalar prefetch (the page id feeds the BlockSpec index
    map, so the DMA engine gathers non-contiguous pages directly from
    HBM) and streams them through the Alg. 2 online update.
  * The kernel emits the same *partial triplet* (m, l, o~) as the dense
    ``decode.py`` kernel, so the log-domain ACC merge (Eq. 16) and the
    LogDiv finalize are reused unchanged.
  * ``use_hfa`` switches the exponentials to the FIX16-quantized
    PWL/bit-pack datapath, exactly as in the dense kernel.

Also here (they pair with the kernel, not with host bookkeeping):
``append_kv`` / ``write_prefill_kv`` scatter new K/V into the pools at
page-table-resolved positions, and ``gather_pages`` reconstructs a dense
view for the jnp fallback path and the test oracles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat
from repro.kernels import bitmath
from repro.kernels.decode import LANES, NEG_INF


def _load_tile(codec, ref, s_ref):
    """Decode one (page, d) KV tile to f32 right after its DMA.

    ``codec is None`` is the raw fp pool (astype only - bit-exact to the
    pre-codec kernel); otherwise the codec's decode runs inside the tile
    loop, with the per-page scale tile (page, 1) from the sidecar pool.
    """
    tile = ref[0, :, 0, :]
    if codec is None:
        return tile.astype(jnp.float32)
    s = None if s_ref is None else s_ref[0, :, 0, :].astype(jnp.float32)
    return codec.decode(tile, s).astype(jnp.float32)


def _paged_decode_kernel(pt_ref, sl_ref, q_ref, k_ref, v_ref, *rest,
                         page_size: int, scale: float, use_hfa: bool,
                         codec=None):
    if codec is not None and codec.has_scales:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)           # (G, d)
    k = _load_tile(codec, k_ref, ks_ref)          # (page, d)
    v = _load_tile(codec, v_ref, vs_ref)          # (page, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kv_ids = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kv_ids < sl_ref[b]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    if use_hfa:
        alpha = bitmath.exp2_hfa_rail(
            bitmath.quant_rail(jnp.minimum(m_prev - m_new, 0.0)))
        p = bitmath.exp2_hfa_rail(bitmath.quant_rail(s - m_new[:, None]))
    else:
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask & (m_new != NEG_INF)[:, None], p, 0.0)

    l_scr[:, 0] = l_scr[:, 0] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[:, 0] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        o_ref[0, 0] = acc_scr[...].astype(o_ref.dtype)
        m_ref[0, 0, :, 0] = m_scr[:, 0]
        l_ref[0, 0, :, 0] = l_scr[:, 0]


def paged_decode_partial_pallas(
    q: jax.Array,           # (B, Hkv, G, d) grouped queries
    k_pages: jax.Array,     # (P, page, Hkv, d) shared block pool
    v_pages: jax.Array,     # (P, page, Hkv, d)
    page_table: jax.Array,  # (B, pages_per_seq) int32 page ids
    kv_lens: jax.Array,     # (B,) int32 valid KV length per sequence
    *,
    scale: float | None = None,
    use_hfa: bool = False,
    interpret: bool = True,
    codec=None,
    k_scales: jax.Array | None = None,  # (P, page, Hkv, 1) f32 sidecar
    v_scales: jax.Array | None = None,
):
    """Partial paged decode attention: one block-FAU triplet per (b, hkv).

    Page-table entries past ``ceil(kv_lens[b] / page)`` may be any valid
    page id (their contribution is masked out); ``kv_lens[b] == 0`` marks
    a free slot and yields an all-zero triplet.

    ``codec`` (a :class:`repro.kernels.page_codec.PageCodec`, or None for
    the raw fp pool) decodes each page tile inside the loop; codecs with
    scales stream the matching sidecar page through the same
    scalar-prefetch index map as the KV pages.

    Returns:
      (o~, m, l): o~ (B, Hkv, G, d) unnormalized f32 accumulator, m/l
      (B, Hkv, G) running max / sum-of-exps - mergeable with the dense
      triplets via :func:`repro.kernels.decode.merge_partials`.
    """
    b, hkv, g, d = q.shape
    _, page_size, hkv_p, _ = k_pages.shape
    assert hkv_p == hkv, (hkv_p, hkv)
    pages_per_seq = page_table.shape[1]
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    has_scales = codec is not None and codec.has_scales

    kernel = functools.partial(_paged_decode_kernel, page_size=page_size,
                               scale=scale_v, use_hfa=use_hfa, codec=codec)
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda b, h, j, pt, sl: (b, h, 0, 0)),
        pl.BlockSpec((1, page_size, 1, d),
                     lambda b, h, j, pt, sl: (pt[b, j], 0, h, 0)),
        pl.BlockSpec((1, page_size, 1, d),
                     lambda b, h, j, pt, sl: (pt[b, j], 0, h, 0)),
    ]
    operands = [q, k_pages, v_pages]
    if has_scales:
        in_specs += [
            pl.BlockSpec((1, page_size, 1, 1),
                         lambda b, h, j, pt, sl: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, 1),
                         lambda b, h, j, pt, sl: (pt[b, j], 0, h, 0)),
        ]
        operands += [k_scales, v_scales]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, hkv, pages_per_seq),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda b, h, j, pt, sl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda b, h, j, pt, sl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda b, h, j, pt, sl: (b, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_flash_decode_partial",
    )(page_table.astype(jnp.int32), kv_lens.astype(jnp.int32), *operands)
    return o, m[..., 0], l[..., 0]


# ------------------------------------------------------- page cache ops
def _flat_write_pos(page_table, positions, page_size):
    """Pool-flat write index for (b, position): table[b, pos//page] * page
    + pos % page.  positions: (B,) or (B, L).  Page indices are clamped
    to the table width so padded positions past the allocation resolve
    to a (wrong but in-bounds) page - callers that can produce them
    (write_chunk_kv) drop those writes explicitly."""
    pidx = jnp.minimum(positions // page_size, page_table.shape[1] - 1)
    page = jnp.take_along_axis(page_table, pidx, axis=1)
    return page * page_size + positions % page_size


def append_kv(k_pages, v_pages, k_new, v_new, page_table, seq_lens):
    """Scatter one new token's K/V per *active* sequence into the pools.

    k_new/v_new: (B, 1, Hkv, d); the token for sequence b lands at
    position ``seq_lens[b]``.  Slots with ``seq_lens[b] == 0`` are free
    (nothing has been prefilled) and their write is dropped.
    """
    p, page_size, hkv, d = k_pages.shape
    pos = seq_lens.astype(jnp.int32)
    flat = _flat_write_pos(page_table, pos[:, None], page_size)[:, 0]
    flat = jnp.where(pos > 0, flat, p * page_size)     # OOB => dropped
    kf = k_pages.reshape(p * page_size, hkv, d)
    vf = v_pages.reshape(p * page_size, hkv, d)
    kf = kf.at[flat].set(k_new[:, 0].astype(kf.dtype), mode="drop")
    vf = vf.at[flat].set(v_new[:, 0].astype(vf.dtype), mode="drop")
    return kf.reshape(k_pages.shape), vf.reshape(v_pages.shape)


def write_prefill_kv(k_pages, v_pages, k_new, v_new, page_table):
    """Write a fresh prompt's K/V (positions 0..L-1) through the page table.

    k_new/v_new: (B, L, Hkv, d); row b uses page_table row b.  All rows
    are written in full - the engine prefills per request (or per group
    of equal-length requests), padding to a page multiple; padded tail
    positions are masked later by ``kv_lens``.
    """
    p, page_size, hkv, d = k_pages.shape
    b, l, _, _ = k_new.shape
    pos = jnp.broadcast_to(jnp.arange(l, dtype=jnp.int32)[None], (b, l))
    flat = _flat_write_pos(page_table, pos, page_size).reshape(-1)
    kf = k_pages.reshape(p * page_size, hkv, d)
    vf = v_pages.reshape(p * page_size, hkv, d)
    kf = kf.at[flat].set(k_new.reshape(b * l, hkv, d).astype(kf.dtype))
    vf = vf.at[flat].set(v_new.reshape(b * l, hkv, d).astype(vf.dtype))
    return kf.reshape(k_pages.shape), vf.reshape(v_pages.shape)


def gather_pages(pages: jax.Array, page_table: jax.Array) -> jax.Array:
    """Dense (B, pages_per_seq * page, Hkv, d) view of each sequence's KV."""
    b, j = page_table.shape
    _, page_size, hkv, d = pages.shape
    out = jnp.take(pages, page_table.reshape(-1), axis=0)
    return out.reshape(b, j * page_size, hkv, d)
