"""Paged multi-query verify Pallas kernel for self-speculative decode.

One verify step scores K = 1 + spec_k input tokens per sequence (the
carry token plus up to spec_k drafted continuations) against the paged
KV pools in a *single* page-table walk - FlashAttention-2's
work-partitioning argument applied to speculation: the page gather and
the log-domain ACC merge that H-FA makes cheap are amortized over all K
positions instead of being paid once per generated token.

Contract (the decode-shaped sibling of :mod:`paged_prefill`):

  * The step's K tokens sit at absolute positions
    ``seq_lens[b] + i`` for i in [0, chunk_lens[b]); their K/V must
    already be scattered into the pools (``paged_prefill.write_chunk_kv``
    with ``start_pos = seq_lens``).  Query row i attends causally to KV
    positions ``<= seq_lens[b] + i`` and ``< seq_lens[b] +
    chunk_lens[b]``.
  * ``chunk_lens[b] == 0`` marks a free / mid-prefill slot riding along
    masked: it emits an all-zero triplet.  Rows at ``i >=
    chunk_lens[b]`` read only valid KV but produce garbage the caller
    ignores.
  * The kernel emits the same partial triplet (m, l, o~) as
    ``paged_decode.py`` / ``paged_prefill.py`` - with K = 1 it computes
    exactly the paged decode attention - so the Eq. 16 merge and the
    LogDiv finalize are reused unchanged, and ``use_hfa`` swaps the
    exponentials for the FIX16 PWL/bit-pack datapath.

``paged_verify_partial_ref`` is the op-order-free jnp triplet oracle
(dense gather + full softmax pieces) used by the golden-parity matrix in
``tests/test_kernels.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat
from repro.kernels import bitmath
from repro.kernels.decode import LANES, NEG_INF
from repro.kernels.paged_decode import _load_tile, gather_pages


def _paged_verify_kernel(pt_ref, sl_ref, cl_ref, q_ref, k_ref, v_ref,
                         *rest, page_size: int, spec_width: int,
                         scale: float, use_hfa: bool, codec=None):
    if codec is not None and codec.has_scales:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = rest
    else:
        ks_ref = vs_ref = None
        o_ref, m_ref, l_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    j = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)           # (G * K, d)
    k = _load_tile(codec, k_ref, ks_ref)          # (page, d)
    v = _load_tile(codec, v_ref, vs_ref)          # (page, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kv_ids = j * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    # Row r of the flattened (G, K) query block is verify position
    # r % K, i.e. absolute position seq_lens[b] + r % K.
    q_pos = sl_ref[b] + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 0) % spec_width
    mask = (kv_ids <= q_pos) & (kv_ids < sl_ref[b] + cl_ref[b])
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    if use_hfa:
        alpha = bitmath.exp2_hfa_rail(
            bitmath.quant_rail(jnp.minimum(m_prev - m_new, 0.0)))
        p = bitmath.exp2_hfa_rail(bitmath.quant_rail(s - m_new[:, None]))
    else:
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask & (m_new != NEG_INF)[:, None], p, 0.0)

    l_scr[:, 0] = l_scr[:, 0] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[:, 0] = m_new

    @pl.when(j == nj - 1)
    def _finalize():
        o_ref[0, 0] = acc_scr[...].astype(o_ref.dtype)
        m_ref[0, 0, :, 0] = m_scr[:, 0]
        l_ref[0, 0, :, 0] = l_scr[:, 0]


def paged_verify_partial_pallas(
    q: jax.Array,           # (B, Hkv, G, K, d) grouped verify queries
    k_pages: jax.Array,     # (P, page, Hkv, d) shared block pool
    v_pages: jax.Array,     # (P, page, Hkv, d)
    page_table: jax.Array,  # (B, pages_per_seq) int32 page ids
    seq_lens: jax.Array,    # (B,) int32 pre-step KV length per sequence
    chunk_lens: jax.Array,  # (B,) int32 real input count this step (0=free)
    *,
    scale: float | None = None,
    use_hfa: bool = False,
    interpret: bool = True,
    codec=None,
    k_scales: jax.Array | None = None,  # (P, page, Hkv, 1) f32 sidecar
    v_scales: jax.Array | None = None,
):
    """Partial paged verify attention: one block-FAU triplet per
    (sequence, kv head, verify position).

    Returns:
      (o~, m, l): o~ (B, Hkv, G, K, d) unnormalized f32 accumulator,
      m/l (B, Hkv, G, K) running max / sum-of-exps - the same triplet
      contract as ``paged_decode_partial_pallas`` (K = 1 is exactly the
      paged decode), mergeable/finalizable via
      :mod:`repro.kernels.decode`.
    """
    b, hkv, g, spec_width, d = q.shape
    _, page_size, hkv_p, _ = k_pages.shape
    assert hkv_p == hkv, (hkv_p, hkv)
    pages_per_seq = page_table.shape[1]
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    rows = g * spec_width
    q3 = q.reshape(b, hkv, rows, d)
    has_scales = codec is not None and codec.has_scales

    kernel = functools.partial(_paged_verify_kernel, page_size=page_size,
                               spec_width=spec_width, scale=scale_v,
                               use_hfa=use_hfa, codec=codec)
    in_specs = [
        pl.BlockSpec((1, 1, rows, d),
                     lambda b, h, j, pt, sl, cl: (b, h, 0, 0)),
        pl.BlockSpec((1, page_size, 1, d),
                     lambda b, h, j, pt, sl, cl: (pt[b, j], 0, h, 0)),
        pl.BlockSpec((1, page_size, 1, d),
                     lambda b, h, j, pt, sl, cl: (pt[b, j], 0, h, 0)),
    ]
    operands = [q3, k_pages, v_pages]
    if has_scales:
        in_specs += [
            pl.BlockSpec((1, page_size, 1, 1),
                         lambda b, h, j, pt, sl, cl: (pt[b, j], 0, h, 0)),
            pl.BlockSpec((1, page_size, 1, 1),
                         lambda b, h, j, pt, sl, cl: (pt[b, j], 0, h, 0)),
        ]
        operands += [k_scales, v_scales]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(b, hkv, pages_per_seq),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1, rows, d),
                         lambda b, h, j, pt, sl, cl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, rows, 1),
                         lambda b, h, j, pt, sl, cl: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, rows, 1),
                         lambda b, h, j, pt, sl, cl: (b, h, 0, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, LANES), jnp.float32),
            pltpu.VMEM((rows, d), jnp.float32),
        ],
    )
    o, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, rows, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, rows, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, rows, 1), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
        name="paged_verify_partial",
    )(page_table.astype(jnp.int32), seq_lens.astype(jnp.int32),
      chunk_lens.astype(jnp.int32), *operands)
    return (o.reshape(b, hkv, g, spec_width, d),
            m[..., 0].reshape(b, hkv, g, spec_width),
            l[..., 0].reshape(b, hkv, g, spec_width))


def paged_verify_partial_ref(q, k_pages, v_pages, page_table, seq_lens,
                             chunk_lens, *, scale=None, use_hfa=False,
                             codec=None, k_scales=None, v_scales=None):
    """jnp triplet oracle: dense gather + one-shot softmax pieces.

    Same signature/returns as :func:`paged_verify_partial_pallas`.  The
    running max equals the global max, so ``m`` matches the kernel
    exactly; ``l``/``o~`` differ only by f32 summation order.  With a
    ``codec`` the gathered pages (and sidecar scales) are decoded before
    the dense softmax - the same decode the kernel applies per tile.
    """
    b, hkv, g, spec_width, d = q.shape
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    kc = gather_pages(k_pages, page_table)        # (B, S, Hkv, d)
    vc = gather_pages(v_pages, page_table)
    if codec is not None:
        ks = None if k_scales is None else gather_pages(k_scales, page_table)
        vs = None if v_scales is None else gather_pages(v_scales, page_table)
        kc = codec.decode(kc, ks)
        vc = codec.decode(vc, vs)
    s = jnp.einsum("bhgld,bshd->bhgls", q.astype(jnp.float32),
                   kc.astype(jnp.float32),
                   preferred_element_type=jnp.float32) * scale_v
    kv_ids = jnp.arange(kc.shape[1], dtype=jnp.int32)
    sl = seq_lens.astype(jnp.int32)[:, None, None]
    q_pos = sl + jnp.arange(spec_width, dtype=jnp.int32)[None, :, None]
    mask = (kv_ids[None, None, :] <= q_pos) & \
        (kv_ids[None, None, :] < sl + chunk_lens.astype(jnp.int32)[:, None,
                                                                   None])
    s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    if use_hfa:
        p = bitmath.exp2_hfa_rail(bitmath.quant_rail(s - m[..., None]))
    else:
        p = jnp.exp(s - m[..., None])
    live = (m != NEG_INF)
    p = jnp.where(mask[:, None, None, :, :] & live[..., None], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgls,bshd->bhgld", p, vc.astype(jnp.float32),
                   preferred_element_type=jnp.float32)
    m = jnp.where(live, m, NEG_INF)
    return o, m, l
