"""Pallas TPU kernels for the H-FA hot spots + jnp oracles.

fa2.py           baseline FlashAttention-2 (float datapath, 'FA-2')
hfa.py           hybrid float/log H-FA kernel (MXU-compatible adaptation)
hfa_datapath.py  per-element FIX16 LNS FAU (datapath-faithful validation)
decode.py        grouped flash-decode partials + log-domain ACC merge
paged_decode.py  page-table flash-decode (serving) + page scatter/gather
bitmath.py       bit-trick exp2/log2/PWL shared helpers
ops.py           public jit'd wrappers (impl dispatch, GQA, padding)
ref.py           pure-jnp oracles
pallas_compat.py jax-version shims for the Pallas TPU API
"""
