"""FlashAttention-2 backward Pallas kernels.

Standard two-kernel FA-2 backward (Dao 2023), TPU-tiled:

  * forward saves the per-row logsumexp L = m + ln(l)  (``return_lse``);
  * ``delta = rowsum(do * o)`` is computed outside (one fused elementwise);
  * dq kernel: grid (bh, q_blocks, kv_blocks), accumulates
      ds = p * (do . v^T - delta),   dq += ds . k * scale
  * dkv kernel: grid (bh, kv_blocks, q_blocks), accumulates
      dv += p^T . do,   dk += ds^T . q * scale

Both recompute p = exp(s - L) on the fly (no (Lq x Lkv) residuals), with
the same iota-based causal/padding masks as the forward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat

NEG_INF = -1e30
LANES = 128


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
               acc, *, scale, causal, block_q, block_kv, kv_len, q_offset):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    q_start = iq * block_q + q_offset
    k_start = ik * block_kv

    def _visit():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kv_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kv_ids < kv_len
        if causal:
            q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (kv_ids <= q_ids)
        p = jnp.where(mask, jnp.exp(s - lse_ref[0, :, 0][:, None]), 0.0)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, :, 0][:, None])
        acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if causal:
        pl.when(k_start <= q_start + block_q - 1)(_visit)
    else:
        _visit()

    @pl.when(ik == nk - 1)
    def _done():
        dq_ref[0] = acc[...].astype(dq_ref.dtype)


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, acck, accv, *,
                scale, causal, block_q, block_kv, kv_len, q_offset):
    ik = pl.program_id(1)
    iq = pl.program_id(2)
    nq = pl.num_programs(2)

    @pl.when(iq == 0)
    def _init():
        acck[...] = jnp.zeros_like(acck)
        accv[...] = jnp.zeros_like(accv)

    q_start = iq * block_q + q_offset
    k_start = ik * block_kv

    def _visit():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kv_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kv_ids < kv_len
        if causal:
            q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (kv_ids <= q_ids)
        p = jnp.where(mask, jnp.exp(s - lse_ref[0, :, 0][:, None]), 0.0)
        accv[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        ds = p * (dp - delta_ref[0, :, 0][:, None])
        acck[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if causal:
        # This kv block only sees q rows at or below the diagonal.
        pl.when(q_start + block_q - 1 >= k_start)(_visit)
    else:
        _visit()

    @pl.when(iq == nq - 1)
    def _done():
        dk_ref[0] = acck[...].astype(dk_ref.dtype)
        dv_ref[0] = accv[...].astype(dv_ref.dtype)


def fa2_backward(q, k, v, o, do, lse, *, causal=False, scale=None,
                 block_q=128, block_kv=128, kv_len=None, q_offset=None,
                 interpret=True):
    """Returns (dq, dk, dv) for the padded (bh, lq, d)/(bh, lkv, d) tiles."""
    bh, lq, d = q.shape
    _, lkv, _ = k.shape
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    kv_len = lkv if kv_len is None else kv_len
    q_offset = (lkv - lq) if q_offset is None else q_offset

    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32),
                    axis=-1, keepdims=True)              # (bh, lq, 1)
    lse3 = lse[..., None]                                 # (bh, lq, 1)

    common = dict(scale=scale_v, causal=causal, block_q=block_q,
                  block_kv=block_kv, kv_len=kv_len, q_offset=q_offset)

    dq = pl.pallas_call(
        functools.partial(_dq_kernel, **common),
        grid=(bh, lq // block_q, lkv // block_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_q, 1), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, block_q, 1), lambda b, iq, ik: (b, iq, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret, name="fa2_bwd_dq",
    )(q, k, v, do, lse3, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_dkv_kernel, **common),
        grid=(bh, lkv // block_kv, lq // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, ik, iq: (b, iq, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, ik, iq: (b, ik, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, ik, iq: (b, ik, 0)),
            pl.BlockSpec((1, block_q, d), lambda b, ik, iq: (b, iq, 0)),
            pl.BlockSpec((1, block_q, 1), lambda b, ik, iq: (b, iq, 0)),
            pl.BlockSpec((1, block_q, 1), lambda b, ik, iq: (b, iq, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_kv, d), lambda b, ik, iq: (b, ik, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, ik, iq: (b, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, lkv, d), k.dtype),
            jax.ShapeDtypeStruct((bh, lkv, d), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_kv, d), jnp.float32),
                        pltpu.VMEM((block_kv, d), jnp.float32)],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret, name="fa2_bwd_dkv",
    )(q, k, v, do, lse3, delta)
    return dq, dk, dv
