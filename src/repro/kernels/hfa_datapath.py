"""Datapath-faithful H-FA Pallas kernel: per-element FIX16 LNS accumulation.

This kernel is the direct transcription of the paper's FAU (Fig. 3): it
streams keys one-by-one inside the kernel and keeps the fused accumulator
O = [l, o] as (sign, raw) LNS state in VMEM, using exactly the
:mod:`repro.core.lns` operations (quant -> Blinn -> Mitchell add -> LogDiv).
It exists to prove the hardware spec is implementable as a kernel and to
pin the semantics: tests assert *exact* rail equality against the
``core.hfa`` emulation.

It is validated in interpret mode; on a real TPU it would be VPU-bound and
slower than ``hfa.py`` (the MXU-compatible kernel) - that trade-off is the
central hardware-adaptation point discussed in DESIGN.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat
from repro.core import lns
from repro.core.numerics import LOG_ZERO

NEG_INF = -1e30


def _datapath_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float,
                     causal: bool, kv_len: int, q_offset: int):
    """Whole-row FAU: streams every key for one (batch*head) slice."""
    lq, d = q_ref.shape[1], q_ref.shape[2]
    lkv = k_ref.shape[1]

    q = q_ref[0].astype(jnp.float32)
    # Scores for the full row in BF16 (the FP half of the datapath).
    s_all = jax.lax.dot_general(
        q, k_ref[0].astype(jnp.float32), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    s_all = s_all.astype(jnp.bfloat16).astype(jnp.float32)   # (lq, lkv)

    kv_ids = jax.lax.broadcasted_iota(jnp.int32, (lq, lkv), 1)
    valid = kv_ids < kv_len
    if causal:
        q_ids = q_offset + jax.lax.broadcasted_iota(jnp.int32, (lq, lkv), 0)
        valid = valid & (kv_ids <= q_ids)

    def step(i, carry):
        m_prev, sgn_prev, raw_prev = carry
        s_i = jax.lax.dynamic_slice(s_all, (0, i), (lq, 1))[:, 0]
        valid_i = jax.lax.dynamic_slice(valid, (0, i), (lq, 1))[:, 0]
        v_i = jax.lax.dynamic_slice(v_ref[0], (i, 0), (1, d))[0]
        v_i = v_i.astype(jnp.bfloat16)

        m_new = jnp.maximum(m_prev, s_i)
        live = valid_i & (m_new > NEG_INF / 2)

        q_dm = lns.quant_scorediff(m_prev - m_new)
        q_ds = lns.quant_scorediff(s_i - m_new)

        a_raw = lns.clamp_rail(raw_prev + q_dm[:, None])
        a_raw = jnp.where(raw_prev <= LOG_ZERO, float(LOG_ZERO), a_raw)

        ones = jnp.ones((1,), jnp.bfloat16)
        v_ext = jnp.concatenate([ones, v_i], axis=0)          # (d+1,)
        sgn_v, raw_v = lns.blinn_log2(v_ext)
        b_raw = lns.clamp_rail(raw_v[None, :] + q_ds[:, None])
        b_raw = jnp.where(raw_v[None, :] <= LOG_ZERO, float(LOG_ZERO), b_raw)
        sgn_b = jnp.broadcast_to(sgn_v[None, :], sgn_prev.shape)
        b_raw = jnp.broadcast_to(b_raw, raw_prev.shape)

        sgn_new, raw_new = lns.lns_add(sgn_prev, a_raw, sgn_b, b_raw)

        keep = ~live
        m_out = jnp.where(keep, m_prev, m_new)
        sgn_out = jnp.where(keep[:, None], sgn_prev, sgn_new)
        raw_out = jnp.where(keep[:, None], raw_prev, raw_new)
        return m_out, sgn_out, raw_out

    init = (
        jnp.full((lq,), NEG_INF, jnp.float32),
        jnp.zeros((lq, d + 1), jnp.int32),
        jnp.full((lq, d + 1), float(LOG_ZERO), jnp.float32),
    )
    m, sgn, raw = jax.lax.fori_loop(0, lkv, step, init)

    # LogDiv (Eq. 15) + inverse Blinn (Eq. 22).
    raw_l = raw[:, :1]
    sgn_l = sgn[:, :1]
    raw_attn = lns.clamp_rail(raw[:, 1:] - raw_l)
    sgn_attn = jnp.bitwise_xor(sgn[:, 1:], sgn_l)
    empty = (raw_l <= LOG_ZERO) | (raw[:, 1:] <= LOG_ZERO)
    raw_attn = jnp.where(empty, float(LOG_ZERO), raw_attn)
    o_ref[0] = lns.lns_to_bf16(sgn_attn, raw_attn).astype(o_ref.dtype)


def hfa_datapath_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = False,
    scale: float | None = None,
    kv_len: int | None = None,
    interpret: bool = True,
) -> jax.Array:
    """Per-element LNS H-FA over (BH, Lq, d); returns BF16 attention."""
    bh, lq, d = q.shape
    _, lkv, _ = k.shape
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    kv_len = lkv if kv_len is None else kv_len
    q_offset = lkv - lq

    kernel = functools.partial(_datapath_kernel, scale=scale_v,
                               causal=causal, kv_len=kv_len,
                               q_offset=q_offset)
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, lq, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, lkv, d), lambda b: (b, 0, 0)),
            pl.BlockSpec((1, lkv, d), lambda b: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lq, d), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, lq, d), jnp.bfloat16),
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
        name="hfa_datapath",
    )(q, k, v)
