"""Flash-decode Pallas kernel: one new token vs a long KV cache.

Maps the paper's multi-KV-block FAU architecture (Fig. 2) onto decode:

  * GQA grouping: the G query heads that share one KV head become the MXU
    rows, so the score matmul is (G x d) @ (d x block_kv) instead of a
    degenerate (1 x d) vector op.
  * The kernel streams KV blocks with the Alg. 2 online update and returns
    the *partial triplet* (m, l, o~) - unnormalized - exactly like a block
    FAU.  The caller (a single host, or shard_map across devices holding a
    sequence-sharded cache) merges triplets with the log-domain ACC rule
    (Eq. 16) and applies LogDiv.  The cross-device merge is the paper's
    cascaded ACC pipeline promoted to the cluster interconnect.
  * ``use_hfa`` switches the exponential terms to the FIX16-quantized
    PWL/bit-pack datapath (no transcendental exp).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import pallas_compat
from repro.kernels import bitmath

NEG_INF = -1e30
LANES = 128


def _decode_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
                   m_scr, l_scr, acc_scr, *,
                   scale: float, block_kv: int, kv_len: int, use_hfa: bool):
    ik = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (G, d)
    k = k_ref[0].astype(jnp.float32)            # (bk, d)
    v = v_ref[0].astype(jnp.float32)            # (bk, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kv_ids = ik * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kv_ids < kv_len
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[:, 0]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    if use_hfa:
        alpha = bitmath.exp2_hfa_rail(
            bitmath.quant_rail(jnp.minimum(m_prev - m_new, 0.0)))
        p = bitmath.exp2_hfa_rail(bitmath.quant_rail(s - m_new[:, None]))
    else:
        alpha = jnp.exp(jnp.minimum(m_prev - m_new, 0.0))
        p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask & (m_new != NEG_INF)[:, None], p, 0.0)

    l_scr[:, 0] = l_scr[:, 0] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[:, 0] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)
        m_ref[0, :, 0] = m_scr[:, 0]
        l_ref[0, :, 0] = l_scr[:, 0]


def decode_partial_pallas(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    scale: float | None = None,
    block_kv: int = 128,
    kv_len: int | None = None,
    use_hfa: bool = False,
    interpret: bool = True,
):
    """Partial decode attention.

    Args:
      q: (BHkv, G, d) - grouped queries (G = q_heads per kv_head).
      k, v: (BHkv, S, d) local KV shard.
    Returns:
      (o~, m, l): o~ (BHkv, G, d) unnormalized f32 output accumulator,
      m/l (BHkv, G) running max / sum-of-exps - a block-FAU triplet.
    """
    bh, g, d = q.shape
    _, s_len, _ = k.shape
    assert s_len % block_kv == 0, (s_len, block_kv)
    scale_v = (1.0 / d ** 0.5) if scale is None else scale
    kv_len = s_len if kv_len is None else kv_len

    grid = (bh, s_len // block_kv)
    kernel = functools.partial(_decode_kernel, scale=scale_v,
                               block_kv=block_kv, kv_len=kv_len,
                               use_hfa=use_hfa)
    o, m, l = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, g, d), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, ik: (b, ik, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, ik: (b, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, g, d), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda b, ik: (b, 0, 0)),
            pl.BlockSpec((1, g, 1), lambda b, ik: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, g, d), jnp.float32),
            jax.ShapeDtypeStruct((bh, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((bh, g, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, LANES), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        compiler_params=pallas_compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
        name="flash_decode_partial",
    )(q, k, v)
    return o, m[..., 0], l[..., 0]


def merge_partials(
    o_parts: jax.Array,   # (P, ..., d)
    m_parts: jax.Array,   # (P, ...)
    l_parts: jax.Array,   # (P, ...)
    *,
    use_hfa: bool = False,
):
    """Eq. (1)/(16): merge P block-FAU triplets (ACC cascade, vectorized).

    With ``use_hfa`` the rescale factors go through the FIX16 quantized
    log-domain path (the ACC unit of Fig. 4); the adds stay in float (on
    TPU the cross-block adds ride the VPU; the LNS adder is an ASIC win).
    """
    m_n = jnp.max(m_parts, axis=0)
    dm = jnp.minimum(m_parts - m_n[None], 0.0)
    if use_hfa:
        w = bitmath.exp2_hfa_rail(bitmath.quant_rail(dm))
    else:
        w = jnp.exp(dm)
    l_n = jnp.sum(l_parts * w, axis=0)
    o_n = jnp.sum(o_parts * w[..., None], axis=0)
    return o_n, m_n, l_n


def finalize_decode(o_acc: jax.Array, l: jax.Array, *, use_hfa: bool = False):
    """Final normalization: float divide (FA-2) or LogDiv (H-FA)."""
    safe = jnp.where(l <= 0.0, 1.0, l)
    if use_hfa:
        recip = bitmath.recip_logdiv(safe)
        recip = jnp.where(l <= 0.0, 0.0, recip)
        return o_acc * recip[..., None]
    return jnp.where((l <= 0.0)[..., None], 0.0, o_acc / safe[..., None])
