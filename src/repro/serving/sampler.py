"""Device-side stochastic sampling for the paged serving engine.

Sampling runs *inside* the jitted decode/prefill step (not as host-side
post-processing): the engine hands the batched logits plus per-slot
parameter vectors to :func:`sample_tokens` and only the sampled token
matrix crosses back to the host.

Determinism contract (what the conformance suite pins down):

  * Every request carries its own ``seed``.  The key for the token at
    stream index ``pos`` (prompt + generated, 0-based) is
    ``jax.random.fold_in(PRNGKey(seed), pos)`` - a pure function of
    (request, position).  A request therefore samples the *same* stream
    whether it shares an engine step with 0 or 7 neighbors, whether its
    prefill was chunked, and whether it was preempted and replayed.
  * The same position-keying makes self-speculative decode *lossless*
    under sampling: a draft token is accepted iff it equals the token
    this sampler would have produced at that position, and the sampler's
    output depends only on (seed, position, verified logits).
  * ``temperature == 0`` short-circuits to argmax over the
    repetition-penalized logits (top-k/top-p are skipped), which is
    bit-identical to the engine's historical greedy path.

Filter pipeline (HF convention, mirrored by the numpy oracle in
``tests/test_sampling_spec.py``):

  repetition penalty -> temperature -> top-k -> top-p -> categorical

Repetition-penalty context is a per-row *presence* bitmask over the
vocab (every token that precedes the sampled position).  For a k-token
verify step the engine combines the slot's base presence with the
step's own draft inputs via :func:`step_presence`, so position i sees
exactly the tokens the no-spec loop would have seen.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling configuration (host-side, hashable)."""
    temperature: float = 0.0      # 0 => greedy argmax
    top_k: int = 0                # 0 => disabled
    top_p: float = 1.0            # 1 => disabled
    repetition_penalty: float = 1.0
    seed: int = 0

    def __post_init__(self):
        assert self.temperature >= 0.0, self.temperature
        assert self.top_k >= 0, self.top_k
        assert 0.0 < self.top_p <= 1.0, self.top_p
        assert self.repetition_penalty > 0.0, self.repetition_penalty


GREEDY = SamplingParams()


def branch_seed(seed: int, branch: int) -> int:
    """Derived seed for branch ``branch`` of a parallel-sampling group.

    ``fold_in(PRNGKey(seed), branch)`` squeezed back to an int32 seed,
    so branch b of a group samples *exactly* the stream an independent
    request submitted with ``SamplingParams(seed=branch_seed(seed, b))``
    would - the conformance contract that makes n-parallel sampling
    testable against n single-slot requests.  Branch 0 keeps the base
    seed (an n=1 group degenerates to the plain request).  A pure
    function of (seed, branch): bit-stable under batch composition,
    preemption replay, and speculation, like the position keys.
    """
    if branch == 0:
        return int(seed)
    key = jax.random.fold_in(jax.random.PRNGKey(int(seed) & 0xFFFFFFFF),
                             int(branch))
    return int(jax.random.randint(key, (), 0, jnp.iinfo(jnp.int32).max))


def apply_repetition_penalty(logits, presence, penalty):
    """HF-style repetition penalty: seen tokens' logits shrink toward 0.

    logits (N, V) f32; presence (N, V) bool; penalty (N,).
    """
    pen = penalty[:, None]
    hit = jnp.where(logits > 0, logits / pen, logits * pen)
    return jnp.where(presence, hit, logits)


def apply_top_k(logits, top_k):
    """Mask logits strictly below the k-th largest (ties at the k-th
    value are all kept).  top_k (N,) int32; 0 disables the filter."""
    v = logits.shape[-1]
    srt = jnp.sort(logits, axis=-1)[:, ::-1]
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v).astype(jnp.int32)
    kth = jnp.take_along_axis(srt, (k_eff - 1)[:, None], axis=-1)
    return jnp.where(logits >= kth, logits, NEG_INF)


def apply_top_p(logits, top_p):
    """Nucleus filter: keep the smallest prefix of the sorted
    distribution whose mass reaches ``top_p`` (the token that crosses
    the threshold is kept; the top-1 token always survives)."""
    order = jnp.argsort(-logits, axis=-1)
    probs = jax.nn.softmax(jnp.take_along_axis(logits, order, axis=-1),
                           axis=-1)
    excl = jnp.cumsum(probs, axis=-1) - probs       # mass strictly before
    keep_sorted = excl < top_p[:, None]
    inv = jnp.argsort(order, axis=-1)
    keep = jnp.take_along_axis(keep_sorted, inv, axis=-1)
    return jnp.where(keep, logits, NEG_INF)


def sample_tokens(logits, presence, seeds, positions, temperature, top_k,
                  top_p, repetition_penalty):
    """Sample one token per row.  All args are batched over N rows:

      logits (N, V); presence (N, V) bool context bitmask;
      seeds/positions (N,) int32; temperature/top_p/repetition_penalty
      (N,) f32; top_k (N,) int32.

    Returns (N,) int32.  Rows with ``temperature == 0`` return the
    argmax of the repetition-penalized logits (greedy).

    Both truncation filters run off one shared descending argsort and
    the draw happens in sorted space (the categorical index maps back
    through the permutation), so the hot step pays a single O(V log V)
    sort instead of three.  Top-k is rank-based here: an exact logit
    tie at the k-th rank keeps the stably-first k entries, where the
    standalone :func:`apply_top_k` keeps all tied values.
    """
    logits = logits.astype(jnp.float32)
    logits = apply_repetition_penalty(logits, presence, repetition_penalty)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    v = logits.shape[-1]
    temp = temperature[:, None]
    scaled = logits / jnp.where(temp > 0, temp, 1.0)
    order = jnp.argsort(-scaled, axis=-1)
    slog = jnp.take_along_axis(scaled, order, axis=-1)
    # top-k: rank < k in sorted space
    k_eff = jnp.where(top_k > 0, jnp.clip(top_k, 1, v), v).astype(jnp.int32)
    keep = jnp.arange(v, dtype=jnp.int32)[None, :] < k_eff[:, None]
    slog = jnp.where(keep, slog, NEG_INF)
    # top-p over the top-k survivors: keep while the mass strictly
    # before a token is < p (the top-1 token always survives)
    probs = jax.nn.softmax(slog, axis=-1)
    excl = jnp.cumsum(probs, axis=-1) - probs
    slog = jnp.where(excl < top_p[:, None], slog, NEG_INF)

    def draw(seed, pos, row):
        key = jax.random.fold_in(jax.random.PRNGKey(seed), pos)
        return jax.random.categorical(key, row)

    idx = jax.vmap(draw)(seeds.astype(jnp.uint32),
                         positions.astype(jnp.int32), slog)
    sampled = jnp.take_along_axis(order, idx[:, None], axis=-1)[:, 0] \
        .astype(jnp.int32)
    return jnp.where(temperature > 0, sampled, greedy)


def step_presence(base, tokens):
    """Per-position context bitmask for a k-token verify step.

    base (B, V) bool: every token in the slot's stream up to and
    including the step's first input (the carry token - already
    recorded by the scheduler).  tokens (B, K) int32: the step's input
    tokens; position i's context additionally includes draft inputs
    1..i (the no-spec loop would have recorded them before sampling).
    Returns (B, K, V) bool.
    """
    b, k = tokens.shape
    v = base.shape[-1]
    oh = (tokens[..., None] == jnp.arange(v, dtype=tokens.dtype))  # (B,K,V)
    oh = oh.at[:, 0, :].set(False)          # carry token is in base already
    cum = jax.lax.associative_scan(jnp.logical_or, oh, axis=1)
    return base[:, None, :] | cum
