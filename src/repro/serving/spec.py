"""Self-speculative draft proposal: prompt-lookup / n-gram matching.

The proposer suggests up to k continuation tokens for a request from the
request's *own* token history (prompt + everything generated so far):
find the most recent earlier occurrence of the stream's trailing n-gram
(longest n first) and propose the tokens that followed it.  No draft
model, no extra forward pass - the only cost is the host-side scan.

This is the PLD/lookahead-lite scheme: it wins exactly where serving
workloads repeat themselves (copied spans, templated output, greedy
cycles), and because the verify step scores every draft against the
target model's own logits, a wrong draft costs one discarded column -
acceptance is *exact*, never approximate.

Pure host logic - fully testable without jax.
"""
from __future__ import annotations


def propose_draft(tokens: list[int], k: int, *, max_ngram: int = 3,
                  min_ngram: int = 1) -> list[int]:
    """Propose up to ``k`` tokens continuing ``tokens``.

    Scans for the most recent earlier occurrence of the stream's
    trailing n-gram, preferring longer n-grams (``max_ngram`` down to
    ``min_ngram``), and returns the up-to-k tokens that followed that
    occurrence.  Returns [] when history offers no match (caller falls
    back to plain one-token decode).
    """
    n = len(tokens)
    if k <= 0 or n < min_ngram + 1:
        return []
    for g in range(min(max_ngram, n - 1), min_ngram - 1, -1):
        tail = tokens[n - g:]
        for start in range(n - g - 1, -1, -1):
            if tokens[start:start + g] == tail:
                # The match says the stream repeats with period
                # d = n - g - start; a match flush against the tail
                # (constant run / short cycle - the dominant greedy
                # case) leaves fewer than k history tokens after it, so
                # extend the continuation periodically: the token at
                # stream position n + j is predicted by position
                # n + j - d, which may itself be a draft.
                d = n - g - start
                out: list[int] = []
                for j in range(k):
                    idx = start + g + j
                    out.append(tokens[idx] if idx < n else out[j - d])
                return out
    return []
