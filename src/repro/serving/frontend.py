"""Asyncio streaming front-end over :meth:`ServingEngine.step`.

:class:`AsyncFrontend` turns the engine's synchronous step loop into a
per-request token stream: ``submit(request)`` returns an async generator
that yields generated token ids as the engine produces them.  One
background *drive task* owns the engine; each iteration

  1. applies cancellations (abandoned generators), then
  2. feeds queued submissions to the engine, then
  3. runs exactly one ``engine.step()`` in a thread-pool executor (the
     event loop stays responsive during the jitted device work), then
  4. publishes each running request's newly generated tokens to its
     stream.

Everything that mutates engine state happens inside the drive task,
*between* steps - client coroutines only enqueue intents (submit /
cancel) and read from per-stream queues, so the scheduler and paged
cache never see concurrent mutation and cancellation is always applied
at a step boundary (``engine.cancel`` flushes pending COW copies before
freeing slots, see :mod:`repro.serving.engine`).

Cancellation: abandoning the generator (``break`` / ``aclose()`` / GC)
triggers its ``finally`` block, which files a cancel intent; the next
drive iteration frees the request's slot and pages refcount-clean -
mid-prefill, mid-decode, or fanned-out group alike.  ``drain()`` waits
for every in-flight stream to finish; ``close()`` drains (optionally)
and stops the drive task.

Token publishing is diff-based: a plain request streams each token the
step it is recorded (``_Running.generated`` grows monotonically between
preemption replays, which replay *into the KV*, not into ``generated``);
a sequence group (n > 1 / best_of / beam) bursts its primary
completion's tokens at retirement - branch streams diverge, so there is
no single incremental stream to publish.  The full
:class:`FinishedRequest` (completions, scores, scheduler TTFT) is
available via :meth:`AsyncFrontend.result` once the stream ends.
"""
from __future__ import annotations

import asyncio
import dataclasses

from repro.serving.engine import ServingEngine
from repro.serving.scheduler import (FinishedRequest, InvalidRequestError,
                                     Request)


@dataclasses.dataclass
class _End:
    """Stream terminator carrying the request's FinishedRequest."""
    fr: FinishedRequest


@dataclasses.dataclass
class _Stream:
    req: Request
    queue: asyncio.Queue
    sent: int = 0              # generated tokens published so far
    done: bool = False


class AsyncFrontend:
    """Async streaming facade over one :class:`ServingEngine`.

    Single-event-loop, single-drive-task; not thread-safe.  Typical use::

        fe = AsyncFrontend(engine)
        async for tok in fe.submit(req):
            ...
        fr = fe.result(req.rid)
        await fe.close()
    """

    def __init__(self, engine: ServingEngine):
        self.engine = engine
        self._streams: dict[int, _Stream] = {}
        self._pending: list[Request] = []
        self._cancels: list[int] = []
        self.results: dict[int, FinishedRequest] = {}
        self._wake = asyncio.Event()
        self._idle = asyncio.Event()
        self._idle.set()
        self._task: asyncio.Task | None = None
        self._closed = False

    # ------------------------------------------------------------- client
    def submit(self, req: Request):
        """Enqueue ``req`` and return an async generator of its token
        ids.  The request enters the engine on the next drive iteration;
        abandoning the generator cancels the request and frees its
        slot/pages refcount-clean."""
        if self._closed:
            raise RuntimeError("frontend is closed")
        if req.rid in self._streams:
            raise ValueError(f"rid {req.rid} already in flight")
        st = _Stream(req=req, queue=asyncio.Queue())
        self._streams[req.rid] = st
        self._pending.append(req)
        self._idle.clear()
        self._wake.set()
        self._ensure_task()
        return self._stream(st)

    async def _stream(self, st: _Stream):
        try:
            while True:
                item = await st.queue.get()
                if isinstance(item, _End):
                    return
                if isinstance(item, BaseException):
                    raise item
                yield item
        finally:
            # Runs on normal exhaustion AND on abandonment (break /
            # aclose / GC closing the generator mid-iteration).
            if not st.done:
                self._request_cancel(st.req.rid)

    def result(self, rid: int) -> FinishedRequest | None:
        """The FinishedRequest of a completed stream (None while the
        stream is live)."""
        return self.results.get(rid)

    def _request_cancel(self, rid: int) -> None:
        if rid in self._streams and not self._streams[rid].done:
            self._cancels.append(rid)
            self._wake.set()

    async def drain(self) -> None:
        """Wait until every submitted stream has finished (or been
        cancelled) and the engine is idle."""
        self._ensure_task()
        await self._idle.wait()

    async def close(self, drain: bool = True) -> None:
        """Stop the drive task; ``drain=True`` finishes in-flight work
        first, ``drain=False`` cancels every live stream."""
        if drain:
            await self.drain()
        else:
            for rid, st in self._streams.items():
                if not st.done:
                    self._cancels.append(rid)
            self._wake.set()
            await self.drain()
        self._closed = True
        if self._task is not None:
            self._wake.set()        # unblock the wait, task sees _closed
            await self._task
            self._task = None

    # -------------------------------------------------------- drive task
    def _ensure_task(self) -> None:
        if self._task is None or self._task.done():
            self._task = asyncio.get_running_loop().create_task(
                self._drive())

    async def _drive(self) -> None:
        loop = asyncio.get_running_loop()
        while True:
            self._apply_cancels()
            self._apply_submissions()
            if not self.engine.sched.has_work:
                self._idle.set()
                if self._closed:
                    return
                self._wake.clear()
                # Intents filed between the clear and this wait were
                # filed with _wake.set() afterwards, so no lost wakeup.
                if not (self._pending or self._cancels):
                    await self._wake.wait()
                continue
            finished = await loop.run_in_executor(None, self.engine.step)
            self._publish(finished)

    def _apply_cancels(self) -> None:
        while self._cancels:
            rid = self._cancels.pop()
            st = self._streams.get(rid)
            if st is None or st.done:
                continue
            # Snapshot generated-so-far before the scheduler forgets it.
            toks: list[int] = []
            for run in self.engine.sched.running.values():
                if run.req.rid == rid and run.group is None:
                    toks = list(run.generated)
                    break
            self._pending = [r for r in self._pending if r.rid != rid]
            self.engine.cancel(rid)
            self._finish(st, FinishedRequest(
                rid=rid, prompt=st.req.prompt, tokens=toks,
                reason="cancelled"))

    def _apply_submissions(self) -> None:
        while self._pending:
            req = self._pending.pop(0)
            st = self._streams[req.rid]
            try:
                self.engine.submit(req)
            except InvalidRequestError as e:
                # Client misuse: raise it out of the client's generator.
                st.done = True
                del self._streams[req.rid]
                st.queue.put_nowait(e)
            except ValueError:
                # Resource rejection (prompt/width over capacity) -
                # mirrors ServingEngine.run's per-request rejection.
                self.engine.stats["rejected"] += 1
                self._finish(st, FinishedRequest(
                    rid=req.rid, prompt=req.prompt, tokens=[],
                    reason="rejected"))

    def _publish(self, finished: list[FinishedRequest]) -> None:
        for fr in finished:
            st = self._streams.get(fr.rid)
            if st is None or st.done:
                continue
            for tok in fr.tokens[st.sent:]:
                st.queue.put_nowait(tok)
            st.sent = len(fr.tokens)
            self._finish(st, fr)
        # Incremental: publish each live plain request's new tokens.
        for run in self.engine.sched.running.values():
            st = self._streams.get(run.req.rid)
            if st is None or st.done or run.group is not None:
                continue
            gen = run.generated
            for tok in gen[st.sent:]:
                st.queue.put_nowait(tok)
            st.sent = len(gen)

    def _finish(self, st: _Stream, fr: FinishedRequest) -> None:
        st.done = True
        self.results[fr.rid] = fr
        del self._streams[fr.rid]
        st.queue.put_nowait(_End(fr))
