"""Asyncio streaming front-end over :meth:`ServingEngine.step`.

:class:`AsyncFrontend` turns the engine's synchronous step loop into a
per-request token stream: ``submit(request)`` returns an async generator
that yields generated token ids as the engine produces them.  One
background *drive task* owns the engine; each iteration

  1. applies cancellations (abandoned generators), then
  2. feeds queued submissions to the engine, then
  3. runs exactly one ``engine.step()`` in a thread-pool executor (the
     event loop stays responsive during the jitted device work), then
  4. publishes each running request's newly generated tokens to its
     stream.

Everything that mutates engine state happens inside the drive task,
*between* steps - client coroutines only enqueue intents (submit /
cancel) and read from per-stream queues, so the scheduler and paged
cache never see concurrent mutation and cancellation is always applied
at a step boundary (``engine.cancel`` flushes pending COW copies before
freeing slots, see :mod:`repro.serving.engine`).

Cancellation: abandoning the generator (``break`` / ``aclose()`` / GC)
triggers its ``finally`` block, which files a cancel intent; the next
drive iteration frees the request's slot and pages refcount-clean -
mid-prefill, mid-decode, or fanned-out group alike.  ``drain()`` waits
for every in-flight stream to finish; ``close()`` drains (optionally)
and stops the drive task.

Token publishing is diff-based: a plain request streams each token the
step it is recorded (``_Running.generated`` grows monotonically between
preemption replays, which replay *into the KV*, not into ``generated``);
a sequence group (n > 1 / best_of / beam) bursts its primary
completion's tokens at retirement - branch streams diverge, so there is
no single incremental stream to publish.  The full
:class:`FinishedRequest` (completions, scores, scheduler TTFT) is
available via :meth:`AsyncFrontend.result` once the stream ends.

Long-running-server hygiene (each bound below has a regression test in
``tests/test_frontend.py``):

  * per-stream queues are bounded (``stream_buffer`` items).  A reader
    that stalls for that many tokens is treated as disconnected - the
    request is cancelled (slot/pages freed refcount-clean) rather than
    buffering without limit; ``engine.stats["stream_overflows"]``
    counts it.  The terminal ``_End`` always gets through (oldest
    buffered tokens are dropped to make room - the full token list
    rides the FinishedRequest payload anyway);
  * ``results`` is a bounded LRU: :meth:`result` *claims* (removes) an
    entry, and unclaimed entries beyond ``max_results`` age out
    oldest-first (``engine.stats["results_evicted"]``);
  * a crashed drive task fails the frontend loudly instead of being
    silently restarted: the exception is pushed into every live
    stream's queue (streams raise ``BaseException`` items) and every
    later ``submit`` raises with the original failure chained.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses

from repro.serving.engine import ServingEngine
from repro.serving.scheduler import (FinishedRequest, InvalidRequestError,
                                     Request)


@dataclasses.dataclass
class _End:
    """Stream terminator carrying the request's FinishedRequest."""
    fr: FinishedRequest


@dataclasses.dataclass
class _Stream:
    req: Request
    queue: asyncio.Queue
    sent: int = 0              # generated tokens published so far
    done: bool = False


class AsyncFrontend:
    """Async streaming facade over one :class:`ServingEngine`.

    Single-event-loop, single-drive-task; not thread-safe.  Typical use::

        fe = AsyncFrontend(engine)
        async for tok in fe.submit(req):
            ...
        fr = fe.result(req.rid)          # claims (removes) the result
        await fe.close()

    ``stream_buffer`` bounds each stream's token queue (0 = unbounded;
    a full queue cancels the request - the reader is presumed gone).
    ``max_results`` bounds the unclaimed-results LRU.
    """

    def __init__(self, engine: ServingEngine, *,
                 stream_buffer: int = 1024, max_results: int = 1024):
        self.engine = engine
        self.stream_buffer = stream_buffer
        self.max_results = max_results
        self._streams: dict[int, _Stream] = {}
        self._pending: list[Request] = []
        self._cancels: list[int] = []
        # rid -> FinishedRequest, insertion-ordered for LRU eviction.
        self.results: collections.OrderedDict[int, FinishedRequest] = \
            collections.OrderedDict()
        self._wake = asyncio.Event()
        self._idle = asyncio.Event()
        self._idle.set()
        self._task: asyncio.Task | None = None
        self._closed = False
        self._failed: BaseException | None = None

    # ------------------------------------------------------------- client
    @property
    def closed(self) -> bool:
        return self._closed

    @property
    def failed(self) -> bool:
        """True once the drive task crashed; the frontend no longer
        accepts submissions (the engine's state is suspect)."""
        return self._failed is not None

    def submit(self, req: Request):
        """Enqueue ``req`` and return an async generator of its token
        ids.  The request enters the engine on the next drive iteration;
        abandoning the generator cancels the request and frees its
        slot/pages refcount-clean."""
        if self._failed is not None:
            raise RuntimeError(
                "frontend failed (drive task crashed)") from self._failed
        if self._closed:
            raise RuntimeError("frontend is closed")
        if req.rid in self._streams:
            raise ValueError(f"rid {req.rid} already in flight")
        maxsize = self.stream_buffer if self.stream_buffer > 0 else 0
        st = _Stream(req=req, queue=asyncio.Queue(maxsize=maxsize))
        self._streams[req.rid] = st
        self._pending.append(req)
        self._idle.clear()
        self._wake.set()
        self._ensure_task()
        return self._stream(st)

    async def _stream(self, st: _Stream):
        try:
            while True:
                item = await st.queue.get()
                if isinstance(item, _End):
                    return
                if isinstance(item, BaseException):
                    raise item
                yield item
        finally:
            # Runs on normal exhaustion AND on abandonment (break /
            # aclose / GC closing the generator mid-iteration).
            if not st.done:
                self._request_cancel(st.req.rid)

    def result(self, rid: int) -> FinishedRequest | None:
        """Claim the FinishedRequest of a completed stream: returns it
        and removes it from the unclaimed-results LRU (None while the
        stream is live or after the entry was claimed/evicted)."""
        return self.results.pop(rid, None)

    def queue_depth(self, cls_name: str) -> int:
        """Requests of latency class ``cls_name`` accepted but not yet
        running: frontend submissions awaiting the drive loop plus the
        scheduler's waiting queue.  The HTTP transport's per-class
        admission cap gates on this."""
        n = sum(1 for r in self._pending
                if r.latency_class.name == cls_name)
        n += sum(1 for w in self.engine.sched.waiting
                 if w.req.latency_class.name == cls_name)
        return n

    def _request_cancel(self, rid: int) -> None:
        if rid in self._streams and not self._streams[rid].done:
            self._cancels.append(rid)
            self._wake.set()

    async def drain(self) -> None:
        """Wait until every submitted stream has finished (or been
        cancelled) and the engine is idle."""
        self._ensure_task()
        await self._idle.wait()

    async def close(self, drain: bool = True) -> None:
        """Stop the drive task; ``drain=True`` finishes in-flight work
        first, ``drain=False`` cancels every live stream."""
        if drain:
            await self.drain()
        else:
            for rid, st in self._streams.items():
                if not st.done:
                    self._cancels.append(rid)
            self._wake.set()
            await self.drain()
        self._closed = True
        if self._task is not None:
            self._wake.set()        # unblock the wait, task sees _closed
            await self._task
            self._task = None

    # -------------------------------------------------------- drive task
    def _ensure_task(self) -> None:
        if self._task is not None and self._task.done():
            # A done drive task either saw _closed (clean return) or
            # crashed.  _drive routes its own exceptions through
            # _fail(), but keep the belt-and-braces check here: a
            # crash must fail the frontend, never be silently
            # restarted with the exception discarded.
            exc = None if self._task.cancelled() else self._task.exception()
            if exc is not None:
                self._fail(exc)
            self._task = None
        if self._task is None and self._failed is None:
            self._task = asyncio.get_running_loop().create_task(
                self._drive())

    async def _drive(self) -> None:
        loop = asyncio.get_running_loop()
        try:
            while True:
                self._apply_cancels()
                self._apply_submissions()
                if not self.engine.sched.has_work:
                    self._idle.set()
                    if self._closed:
                        return
                    self._wake.clear()
                    # Intents filed between the clear and this wait were
                    # filed with _wake.set() afterwards, so no lost
                    # wakeup.
                    if not (self._pending or self._cancels):
                        await self._wake.wait()
                    continue
                finished = await loop.run_in_executor(None,
                                                      self.engine.step)
                self._publish(finished)
        except asyncio.CancelledError:
            self._fail(RuntimeError("drive task cancelled"))
            raise
        except BaseException as e:   # noqa: BLE001 - delivered to clients
            # Engine/step failure: every live stream raises it, later
            # submits reject.  Swallowed here so close() can await the
            # task without re-raising what clients already saw.
            self._fail(e)

    def _fail(self, exc: BaseException) -> None:
        """Mark the frontend failed and propagate ``exc`` into every
        live stream (their queues raise BaseException items)."""
        if self._failed is not None:
            return
        self._failed = exc
        for st in list(self._streams.values()):
            if not st.done:
                st.done = True
                self._force_put(st, exc)
        self._streams.clear()
        self._pending.clear()
        self._cancels.clear()
        self._idle.set()

    def _apply_cancels(self) -> None:
        while self._cancels:
            rid = self._cancels.pop()
            st = self._streams.get(rid)
            if st is None or st.done:
                continue
            # Snapshot generated-so-far before the scheduler forgets it.
            # For a fanned-out group there is no single stream; the
            # primary live branch (lowest branch id - completions[0]'s
            # lineage) stands in, mirroring what the client would have
            # been streamed at retirement.
            plain = primary = None
            for run in self.engine.sched.running.values():
                if run.req.rid != rid:
                    continue
                if run.group is None:
                    plain = run
                    break
                if primary is None or run.branch < primary.branch:
                    primary = run
            src = plain if plain is not None else primary
            toks = list(src.generated) if src is not None else []
            self._pending = [r for r in self._pending if r.rid != rid]
            self.engine.cancel(rid)
            self._finish(st, FinishedRequest(
                rid=rid, prompt=st.req.prompt, tokens=toks,
                reason="cancelled"))

    def _apply_submissions(self) -> None:
        while self._pending:
            req = self._pending.pop(0)
            st = self._streams[req.rid]
            try:
                self.engine.submit(req)
            except InvalidRequestError as e:
                # Client misuse: raise it out of the client's generator.
                st.done = True
                del self._streams[req.rid]
                self._force_put(st, e)
            except ValueError:
                # Resource rejection (prompt/width over capacity) -
                # mirrors ServingEngine.run's per-request rejection.
                self.engine.stats["rejected"] += 1
                self._finish(st, FinishedRequest(
                    rid=req.rid, prompt=req.prompt, tokens=[],
                    reason="rejected"))

    def _publish(self, finished: list[FinishedRequest]) -> None:
        for fr in finished:
            st = self._streams.get(fr.rid)
            if st is None or st.done:
                continue
            for tok in fr.tokens[st.sent:]:
                if not self._offer(st, tok):
                    # Finished burst into a stalled reader: drop the
                    # remainder - the full token list rides the _End
                    # payload; the engine holds nothing for this
                    # request anymore.
                    self.engine.stats["stream_overflows"] += 1
                    break
            st.sent = len(fr.tokens)
            self._finish(st, fr)
        # Incremental: publish each live plain request's new tokens.
        for run in self.engine.sched.running.values():
            st = self._streams.get(run.req.rid)
            if st is None or st.done or run.group is not None:
                continue
            for tok in run.generated[st.sent:]:
                if not self._offer(st, tok):
                    # The reader stalled for a full stream_buffer of
                    # tokens while the request still holds slot+pages:
                    # presume it disconnected and cancel (the cancel
                    # snapshot keeps everything generated so far).
                    self.engine.stats["stream_overflows"] += 1
                    self._request_cancel(run.req.rid)
                    break
                st.sent += 1

    def _offer(self, st: _Stream, item) -> bool:
        """put_nowait that reports overflow instead of raising."""
        try:
            st.queue.put_nowait(item)
            return True
        except asyncio.QueueFull:
            return False

    @staticmethod
    def _force_put(st: _Stream, item) -> None:
        """Deliver a terminal item (an _End or an exception) even to a
        full queue by dropping the oldest buffered tokens."""
        while True:
            try:
                st.queue.put_nowait(item)
                return
            except asyncio.QueueFull:
                try:
                    st.queue.get_nowait()
                except asyncio.QueueEmpty:
                    pass

    def _finish(self, st: _Stream, fr: FinishedRequest) -> None:
        st.done = True
        self.results[fr.rid] = fr
        while len(self.results) > self.max_results > 0:
            self.results.popitem(last=False)
            self.engine.stats["results_evicted"] += 1
        del self._streams[fr.rid]
        self._force_put(st, _End(fr))
