"""Paged KV-cache + continuous-batching serving subsystem.

paged_cache.py   host-side block pool: pages, page tables, slot lifecycle
scheduler.py     request admission / preemption / retirement
engine.py        ServingEngine: jitted paged prefill/decode over the model

Device-side pieces live next to the kernels they pair with
(:mod:`repro.kernels.paged_decode`) and in the model facade
(:meth:`repro.models.model.LM.paged_decode_step`).
"""
from repro.serving.engine import ServingEngine
from repro.serving.paged_cache import PagedKVCache
from repro.serving.scheduler import (FinishedRequest, PrefillChunk, Request,
                                     Scheduler)

__all__ = ["PagedKVCache", "PrefillChunk", "Request", "FinishedRequest",
           "Scheduler", "ServingEngine"]
