"""Paged KV-cache + continuous-batching serving subsystem.

paged_cache.py   host-side block pool: pages, page tables, slot lifecycle
scheduler.py     request admission / preemption / retirement + decode plans
sampler.py       device-side temperature/top-k/top-p/penalty sampling
spec.py          prompt-lookup draft proposer (self-speculation)
engine.py        ServingEngine: jitted paged prefill/verify over the model
frontend.py      AsyncFrontend: asyncio token streaming + cancellation
http.py          HttpServer: dependency-free HTTP/1.1 + SSE transport
disagg.py        DisaggPair: prefill/decode workers + KV page handoff
router.py        Router: prefix-cache-aware multi-replica placement

Device-side pieces live next to the kernels they pair with
(:mod:`repro.kernels.paged_decode`, :mod:`repro.kernels.paged_verify`)
and in the model facade (:meth:`repro.models.model.LM.paged_verify_step`).
"""
from repro.serving.disagg import DisaggPair, Handoff
from repro.serving.engine import ServingEngine
from repro.serving.frontend import AsyncFrontend
from repro.serving.http import (HttpError, HttpServer, http_json,
                                stream_generate)
from repro.serving.paged_cache import PagedKVCache
from repro.serving.sampler import SamplingParams, branch_seed
from repro.serving.scheduler import (BATCH, INTERACTIVE, LATENCY_CLASSES,
                                     STANDARD, Completion, DecodeStep,
                                     FinishedRequest, InvalidRequestError,
                                     LatencyClass, PrefillChunk, Request,
                                     Scheduler, SequenceGroup)
from repro.serving.router import Router, RouterCore
from repro.serving.spec import propose_draft

__all__ = ["AsyncFrontend", "BATCH", "Completion", "DecodeStep",
           "DisaggPair", "Handoff", "HttpError", "HttpServer",
           "INTERACTIVE", "InvalidRequestError", "LATENCY_CLASSES",
           "LatencyClass", "PagedKVCache", "PrefillChunk", "Request",
           "FinishedRequest", "Router", "RouterCore", "STANDARD",
           "SamplingParams", "Scheduler",
           "SequenceGroup", "ServingEngine", "branch_seed", "http_json",
           "propose_draft", "stream_generate"]
