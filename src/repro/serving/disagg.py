"""Prefill/decode disaggregated serving: two engines, one request.

A :class:`DisaggPair` runs a request's *prompt* through one engine (the
prefill worker) and its *generation* through another (the decode
worker), shipping the prompt's KV pages between the two pools instead
of recomputing them.  The page table is the transfer manifest:

  1. the prefill worker runs the prompt with a 1-token budget; prefix
     caching publishes every full prompt page into its chain-hash
     table;
  2. :meth:`PagedKVCache.export_prefix` walks those chain hashes,
     returning the page ids + hashes and *export-pinning* each page
     (no eviction, no in-place COW while the copy is in flight);
  3. the decode worker *stages* that many pages out of its own pool
     (:meth:`PagedKVCache.stage_pages` - neither free nor owned until
     the handoff resolves) and one jitted gather/scatter copies the
     page contents across pools, every layer and codec sidecar at once;
  4. :meth:`commit` publishes the staged pages into the decode worker's
     chain-hash table (parked in the cached LRU, exactly like a
     locally-retired prefix) and releases the exporter's pins; the
     original request then submits to the decode worker, whose
     *ordinary admission path* claims the imported prefix - only the
     partial tail page is ever prefilled twice.

Token parity (the conformance claim in tests/test_disagg.py): sampling
is seeded per request and keyed by stream position, kernels are
deterministic, and the imported pages are bit-identical to what the
decode worker would have computed - so the disaggregated stream equals
the single-engine stream token for token, on both the fp and hfa rails
and under every page codec.

Mid-handoff cancellation: :meth:`abort` returns the staged pages to
the free list (their contents are garbage) and unpins the exporter's -
both pools satisfy ``check_invariants`` before and after, which the
conformance suite asserts.

Both engines stay fully functional serving engines - disaggregation is
a protocol between pools, not a third engine class.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp

from repro.serving.engine import ServingEngine
from repro.serving.scheduler import FinishedRequest, Request


def _copy_across(src_layers, dst_layers, src, dst):
    # Layer pools are stacked (groups, P, page, Hkv, d): page-id axis 1.
    # ``dst`` rows padded past the pool are dropped (jit scatter mode).
    return jax.tree.map(
        lambda s, d: d.at[:, dst].set(jnp.take(s, src, axis=1)),
        src_layers, dst_layers)


_COPY_JIT = jax.jit(_copy_across)


@dataclasses.dataclass
class Handoff:
    """One in-flight prefill->decode transfer.  ``src_pages`` are
    export-pinned on the prefill worker, ``dst_pages`` staged on the
    decode worker, until :meth:`DisaggPair.commit` or
    :meth:`DisaggPair.abort` resolves it."""
    req: Request
    src_pages: list[int]
    hashes: list[int]
    dst_pages: list[int]
    state: str = "staged"          # staged -> committed | aborted


class DisaggPair:
    """One prefill worker + one decode worker over separate engines.

    Both engines must agree on page size and codec (the page bytes are
    copied raw) and have prefix caching on (the chain-hash table is the
    manifest on both sides)."""

    def __init__(self, prefill_engine: ServingEngine,
                 decode_engine: ServingEngine):
        for name, a, b in (
                ("page_size", prefill_engine.page_size,
                 decode_engine.page_size),
                ("kv_codec", prefill_engine.kv_codec,
                 decode_engine.kv_codec)):
            if a != b:
                raise ValueError(
                    f"disagg workers must agree on {name}: {a!r} != {b!r}")
        if not (prefill_engine.prefix_caching
                and decode_engine.prefix_caching):
            raise ValueError(
                "disagg needs prefix_caching=True on both workers "
                "(the chain-hash table is the transfer manifest)")
        self.prefill = prefill_engine
        self.decode = decode_engine
        self.stats = {"handoffs": 0, "handoff_pages": 0,
                      "handoff_dupes": 0, "handoff_aborts": 0,
                      "handoff_fallbacks": 0}

    # ---------------------------------------------------------- handoff
    def start_handoff(self, req: Request) -> Handoff | None:
        """Prefill ``req``'s prompt on the prefill worker and stage the
        page transfer onto the decode worker.  Returns None when the
        decode pool cannot stage the pages (caller submits plainly -
        the decode worker recomputes the prompt; correct, just slower).
        """
        pre = Request(rid=req.rid, prompt=list(req.prompt),
                      max_new_tokens=1)
        self.prefill.run([(0, pre)])
        pages, hashes = self.prefill.cache.export_prefix(list(req.prompt))
        if not pages:
            return Handoff(req=req, src_pages=[], hashes=[], dst_pages=[])
        try:
            staged = self.decode.cache.stage_pages(len(pages))
        except RuntimeError:
            self.prefill.cache.release_export(pages)
            self.stats["handoff_fallbacks"] += 1
            return None
        self._copy_pages(pages, staged)
        return Handoff(req=req, src_pages=pages, hashes=hashes,
                       dst_pages=staged)

    def _copy_pages(self, src: list[int], dst: list[int]) -> None:
        """Device-copy page contents across pools, padded to a
        power-of-two count (padding rows write past the destination
        pool and are dropped) so jit sees a handful of shapes."""
        # The exporter's COW queue may still hold copies targeting the
        # exact source pages; land them before reading.
        self.prefill._apply_pending_copies()
        n = 1
        while n < len(src):
            n *= 2
        s = np.zeros((n,), np.int32)
        d = np.full((n,), self.decode.cache.num_pages, np.int32)
        s[:len(src)] = src
        d[:len(dst)] = dst
        self.decode.layers = _COPY_JIT(
            self.prefill.layers, self.decode.layers,
            jnp.asarray(s), jnp.asarray(d))

    def commit(self, h: Handoff) -> None:
        """Publish the staged pages on the decode worker and release
        the exporter's pins - the imported prefix is now claimable by
        the very next admission."""
        assert h.state == "staged", h.state
        published = self.decode.cache.publish_staged(h.dst_pages, h.hashes)
        if h.src_pages:
            self.prefill.cache.release_export(h.src_pages)
        h.state = "committed"
        self.stats["handoffs"] += 1
        self.stats["handoff_pages"] += len(published)
        self.stats["handoff_dupes"] += len(h.dst_pages) - len(published)

    def abort(self, h: Handoff) -> None:
        """Mid-handoff cancellation: staged pages return to the decode
        worker's free list, export pins release - both pools
        refcount-clean."""
        assert h.state == "staged", h.state
        self.decode.cache.abort_staged(h.dst_pages)
        if h.src_pages:
            self.prefill.cache.release_export(h.src_pages)
        h.state = "aborted"
        self.stats["handoff_aborts"] += 1

    # ----------------------------------------------------------- serving
    def submit(self, req: Request) -> None:
        """Full disaggregated intake: hand the prompt KV off, then
        submit the original request to the decode worker (admission
        claims the imported prefix)."""
        h = self.start_handoff(req)
        if h is not None:
            self.commit(h)
        self.decode.submit(req)

    def run(self, arrivals: list[tuple[int, Request]],
            max_steps: int | None = None) -> list[FinishedRequest]:
        """Drive a batch to completion: every request's prompt goes
        through the prefill worker first (in arrival order), generation
        runs on the decode worker.  Mirrors
        :meth:`ServingEngine.run`'s signature for the benchmark."""
        for _, req in sorted(arrivals, key=lambda a: a[0]):
            h = self.start_handoff(req)
            if h is not None:
                self.commit(h)
        return self.decode.run(arrivals, max_steps=max_steps)

    def check_invariants(self) -> None:
        self.prefill.cache.check_invariants()
        self.decode.cache.check_invariants()
