"""ServingEngine: continuous-batching greedy decode over paged KV.

One engine step = one token-budget step that *mixes* prefill chunks with
the batched decode (Sarathi-style):

  * prefill work is bounded by ``prefill_budget`` tokens per step and
    handed out as chunks, so a long prompt streams in across steps
    while every running decode keeps producing one token per step (no
    prefill stall);
  * admission claims the longest cached prompt prefix (full pages, via
    the cache's chain-hash table) instead of recomputing it -
    shared-system-prompt workloads prefill only their unique tail;
  * decode is one jitted call over all ``max_batch`` slots - free and
    mid-prefill slots ride along masked (length 0), so the trace is
    unique and requests join/leave without recompilation;
  * under page pressure, mid-prefill sequences pause in place (keep
    pages, resume at pos > 0) and decode-append pressure preempts the
    *least-advanced* sequence (cheapest replay) - whose published
    prefix pages stay claimable, so the replay usually skips straight
    to the last full page;
  * copy-on-write page copies (fork / shared-page divergence) are
    drained from the cache and applied to the device pools before any
    write.

Greedy argmax happens on-device inside the jitted step; only the
(max_batch,) token vector crosses to the host per step.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import paged_prefill as paged_pf_k
from repro.serving.paged_cache import PagedKVCache
from repro.serving.scheduler import (FinishedRequest, PrefillChunk, Request,
                                     Scheduler)


def _serving_jits(model):
    """Jitted greedy prefill/decode/copy, cached on the model so every
    engine over the same model shares one compile cache (benchmarks and
    tests spin up several engines).  Cache donation is skipped on CPU,
    where it is unsupported and only adds dispatch overhead."""
    jits = getattr(model, "_serving_jits", None)
    if jits is not None:
        return jits

    def prefill_fn(params, layers, tokens, page_table, start_pos, last_pos):
        logits, layers = model.paged_prefill(params, layers, tokens,
                                             page_table, last_pos=last_pos,
                                             start_pos=start_pos)
        return (jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32),
                layers)

    def decode_fn(params, layers, tokens, page_table, seq_lens):
        logits, layers = model.paged_decode_step(
            params, layers, tokens, page_table, seq_lens)
        return (jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32),
                layers)

    def copy_fn(layers, src, dst):
        # Layer pools are stacked (groups, P, page, Hkv, d): page axis 1.
        return jax.tree.map(
            lambda pool: paged_pf_k.copy_pages(pool, src, dst, axis=1),
            layers)

    cpu = jax.default_backend() == "cpu"
    jits = (jax.jit(prefill_fn, donate_argnums=() if cpu else (1,)),
            jax.jit(decode_fn, donate_argnums=() if cpu else (1,)),
            jax.jit(copy_fn, donate_argnums=() if cpu else (0,)))
    model._serving_jits = jits
    return jits


class ServingEngine:
    def __init__(self, model, params, *, max_batch: int = 8,
                 page_size: int = 16, num_pages: int | None = None,
                 max_seq: int | None = None,
                 prefill_budget: int | None = None,
                 prefix_caching: bool = True):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        if prefill_budget is not None and prefill_budget < 1:
            raise ValueError(
                f"prefill_budget must be >= 1, got {prefill_budget}")
        self.model = model
        self.params = params
        self.page_size = page_size
        self.max_batch = max_batch
        self.prefill_budget = prefill_budget
        self.prefix_caching = prefix_caching
        max_seq = max_seq if max_seq is not None else model.cfg.max_seq
        self.pages_per_seq = -(-max_seq // page_size)
        if num_pages is None:
            num_pages = max_batch * self.pages_per_seq
        self.cache = PagedKVCache(num_pages, page_size, max_batch,
                                  self.pages_per_seq)
        self.sched = Scheduler(self.cache)
        self.layers = model.init_paged_cache(num_pages, page_size)
        self._next_tok = np.zeros((max_batch,), np.int32)
        self.stats = {"steps": 0, "prefills": 0, "prefill_chunks": 0,
                      "prefill_tokens": 0, "cached_prefill_tokens": 0,
                      "generated_tokens": 0, "preemptions": 0,
                      "cow_copies": 0, "rejected": 0}
        self._prefill, self._decode, self._copy = _serving_jits(model)

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        limit = self.pages_per_seq * self.page_size
        need = len(req.prompt) + req.max_new_tokens
        if need > limit:
            raise ValueError(
                f"request {req.rid}: prompt+budget {need} exceeds the "
                f"per-sequence ceiling {limit} (pages_per_seq * page_size)")
        self.sched.submit(req)

    # -------------------------------------------------------------- step
    def step(self) -> list[FinishedRequest]:
        """One token-budget step: continue/admit prefill chunks, run one
        batched decode over every decoding slot; returns the requests
        that finished during this step."""
        finished: list[FinishedRequest] = []
        # Decoding slots claim their next page BEFORE prefill work is
        # scheduled - otherwise a prompt chunk can grab the last free
        # pages and evict an in-flight decode into a costly replay.
        self._capacity_pass()

        chunks, reused = self.sched.schedule_prefill(self.prefill_budget)
        if not chunks and not self.sched.decoding_slots() \
                and self.sched.running:
            # Gridlock: every running slot is a paused prefill and the
            # pool is dry.  Free the least-advanced one (cheapest
            # replay; its published pages stay claimable) so the rest
            # can finish, then re-plan.
            victim = self.sched.choose_victim()
            if victim is not None:
                self.sched.preempt(victim)
                self.stats["preemptions"] += 1
                chunks, r2 = self.sched.schedule_prefill(
                    self.prefill_budget)
                reused += r2
        self.stats["cached_prefill_tokens"] += reused

        self._apply_pending_copies()
        self._run_chunks(chunks, finished)
        # Second (idempotent) capacity pass: slots that finished their
        # prefill this step also append a token below, and a prompt
        # ending exactly on a page boundary needs its next page before
        # the decode scatter.
        self._capacity_pass()
        self._apply_pending_copies()
        self._run_decode(finished)
        self.stats["steps"] += 1
        return finished

    # ---------------------------------------------------------- capacity
    def _capacity_pass(self) -> None:
        """Guarantee every decoding slot can append one token, preempting
        the least-advanced running sequence under pool pressure."""
        for slot in self.sched.decoding_slots():
            if slot not in self.sched.running:
                continue                    # already evicted as a victim
            while not self.cache.ensure_append_capacity(slot):
                at_ceiling = self.cache.pages_for(
                    int(self.cache.seq_lens[slot]) + 1) \
                    > self.cache.pages_per_seq
                victim = slot if at_ceiling else self.sched.choose_victim()
                self.sched.preempt(victim)
                self.stats["preemptions"] += 1
                if victim == slot:
                    break

    def _apply_pending_copies(self) -> None:
        """Apply queued copy-on-write page copies to the device pools.

        Padded to a power-of-two batch (dropped out-of-range writes) so
        jit sees a handful of shapes.
        """
        copies = self.cache.take_pending_copies()
        if not copies:
            return
        self.stats["cow_copies"] += len(copies)
        n = 1
        while n < len(copies):
            n *= 2
        src = np.zeros((n,), np.int32)
        dst = np.full((n,), self.cache.num_pages, np.int32)   # dropped
        for i, (s, d) in enumerate(copies):
            src[i], dst[i] = s, d
        self.layers = self._copy(self.layers, jnp.asarray(src),
                                 jnp.asarray(dst))

    # ----------------------------------------------------------- prefill
    def _run_chunks(self, chunks: list[PrefillChunk], finished: list):
        """Run this step's prefill chunks, batched by padded length (one
        jit trace per (group size, padded length) pair).  Final chunks
        yield the sequence's first new token and flip it into decode."""
        groups: dict[int, list[PrefillChunk]] = {}
        for ck in chunks:
            lpad = -(-len(ck.tokens) // self.page_size) * self.page_size
            groups.setdefault(lpad, []).append(ck)
        for lpad, grp in sorted(groups.items()):
            bsz = len(grp)
            width = self._pow2_width(max(
                self.cache.pages_for(ck.start + len(ck.tokens))
                for ck in grp))
            toks = np.zeros((bsz, lpad), np.int32)
            rows = np.zeros((bsz, width), np.int32)
            start = np.zeros((bsz,), np.int32)
            last = np.zeros((bsz,), np.int32)
            for i, ck in enumerate(grp):
                toks[i, :len(ck.tokens)] = ck.tokens
                rows[i] = self.cache.page_table[ck.slot, :width]
                start[i] = ck.start
                last[i] = len(ck.tokens) - 1
            greedy, self.layers = self._prefill(
                self.params, self.layers, jnp.asarray(toks),
                jnp.asarray(rows), jnp.asarray(start), jnp.asarray(last))
            greedy = np.asarray(greedy)
            self.stats["prefills"] += 1
            for i, ck in enumerate(grp):
                self.stats["prefill_chunks"] += 1
                self.stats["prefill_tokens"] += len(ck.tokens)
                self.sched.complete_chunk(ck)
                if self.prefix_caching:
                    self.cache.register_pages(
                        ck.slot, self.sched.running[ck.slot].tokens())
                if not ck.is_final:
                    continue
                tok = int(greedy[i])
                self.stats["generated_tokens"] += 1
                status = self.sched.record_token(ck.slot, tok)
                if status == "running":
                    self._next_tok[ck.slot] = tok
                else:
                    finished.append(self.sched.retire(ck.slot, status))

    # ------------------------------------------------------------ decode
    def _run_decode(self, finished: list) -> None:
        dslots = self.sched.decoding_slots()
        if not dslots:
            return
        # Mid-prefill and free slots ride along masked (length 0): their
        # KV write is dropped and their logits ignored.
        dl = np.zeros((self.max_batch,), np.int32)
        for slot in dslots:
            dl[slot] = self.cache.seq_lens[slot]
        width = self._pow2_width(max(
            self.cache.pages_for(int(self.cache.seq_lens[s]) + 1)
            for s in dslots))
        toks = jnp.asarray(self._next_tok[:, None])
        nxt, self.layers = self._decode(
            self.params, self.layers, toks,
            jnp.asarray(self.cache.page_table[:, :width]),
            jnp.asarray(dl))
        nxt = np.asarray(nxt)
        for slot in dslots:
            self.cache.advance(slot)
            tok = int(nxt[slot])
            self.stats["generated_tokens"] += 1
            status = self.sched.record_token(slot, tok)
            if self.prefix_caching and \
                    int(self.cache.seq_lens[slot]) % self.page_size == 0:
                # A page just filled: publish it so an identical prefix
                # (or this sequence's own replay after a preemption) can
                # claim it instead of recomputing.
                self.cache.register_pages(
                    slot, self.sched.running[slot].tokens())
            if status == "running":
                self._next_tok[slot] = tok
            else:
                finished.append(self.sched.retire(slot, status))

    def _pow2_width(self, need: int) -> int:
        """Page-table width covering ``need`` pages, rounded up to a
        power of two so jit sees a handful of shapes.

        This is where paging pays on the compute side too: decode and
        prefill-chunk attention cover only the KV that exists, not the
        max_seq reservation the dense cache burns every step.
        """
        width = 1
        while width < need:
            width *= 2
        return min(width, self.pages_per_seq)

    # --------------------------------------------------------------- run
    def run(self, arrivals: list[tuple[int, Request]],
            max_steps: int | None = None) -> list[FinishedRequest]:
        """Drive to completion. arrivals: [(arrival_step, request)].

        A request whose prompt + budget cannot ever fit a sequence's
        page allowance is rejected (``reason="rejected"``) instead of
        killing the serving loop.
        """
        pending = sorted(arrivals, key=lambda a: a[0])
        finished: list[FinishedRequest] = []
        step = 0
        while pending or self.sched.has_work:
            while pending and pending[0][0] <= step:
                _, req = pending.pop(0)
                try:
                    self.submit(req)
                except ValueError:
                    self.stats["rejected"] += 1
                    finished.append(FinishedRequest(
                        rid=req.rid, prompt=req.prompt, tokens=[],
                        reason="rejected"))
            before = self.stats["generated_tokens"]
            finished.extend(self.step())
            step += 1
            if max_steps is not None and step >= max_steps:
                break
            if (self.stats["generated_tokens"] == before
                    and not self.sched.running and not pending
                    and self.sched.waiting):
                raise RuntimeError(
                    "serving stalled: page pool too small for the "
                    "smallest waiting request")
        return finished
