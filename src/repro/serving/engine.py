"""ServingEngine: continuous-batching sampled + speculative decode over
paged KV.

One engine step = one token-budget step that *mixes* prefill chunks with
the batched decode (Sarathi-style):

  * prefill work is bounded by ``prefill_budget`` tokens per step and
    handed out as chunks, so a long prompt streams in across steps
    while every running decode keeps producing tokens per step (no
    prefill stall);
  * admission claims the longest cached prompt prefix (full pages, via
    the cache's chain-hash table) instead of recomputing it -
    shared-system-prompt workloads prefill only their unique tail;
  * decode is one jitted *verify* call over all ``max_batch`` slots and
    ``spec_k + 1`` token columns: the carry token plus up to ``spec_k``
    prompt-lookup drafts per slot are scored in a single page-table
    walk (free and mid-prefill slots ride along masked), so the trace
    is unique and requests join/leave without recompilation;
  * sampling (temperature / top-k / top-p / repetition penalty) runs
    *inside* the jitted step, seeded per request and keyed by stream
    position (``jax.random.fold_in``), so a request's tokens are
    identical whether it shares the step with 0 or 7 neighbors - and a
    draft is accepted iff it equals the token the sampler would have
    produced, which makes speculative decode lossless under both greedy
    and stochastic sampling;
  * rejected draft columns are rolled back on the host: ``seq_lens``
    drops to the accepted prefix and now-empty tail pages return to the
    pool (COW refcounts respected);
  * sequence groups (``n > 1`` / ``best_of`` / ``beam_width``): one
    prefill fans out into width branch slots over ``PagedKVCache.fork``
    (prompt pages shared by refcount, zero KV copied).  Parallel
    branches sample under ``branch_seed(seed, branch)`` and decode
    exactly like independent requests - token-identical, asserted by
    tests/test_parallel_sampling.py; beam branches take their tokens
    from a per-group top-2k reorder (fork the parents keeping several
    children, free the childless), with speculation auto-disabled.
    Preemption evicts whole groups; deterministic keys re-derive the
    same completions on re-admission;

  * under page pressure, mid-prefill sequences pause in place (keep
    pages, resume at pos > 0) and decode-append pressure preempts the
    *least-advanced* sequence (cheapest replay);
  * copy-on-write page copies (fork / shared-page divergence) are
    drained from the cache and applied to the device pools before any
    write.

  * tensor parallelism (``mesh`` with a "model" axis of size tp > 1):
    the layer KV pools are KV-head-sharded across the mesh and every
    attention call routes through the cascaded-ACC-merge shard_map
    path - per-shard pool HBM drops by tp, only (m, l, o~) triplets
    cross the interconnect, and the token stream is bit-identical to
    single-shard serving.  All host-side state in this file (page
    tables, scheduler, sampling vectors) stays replicated.

Only the (max_batch, spec_k + 1) sampled-token matrix crosses to the
host per step.

Invariant (rollback x refcounts): the verify step in :meth:`_run_decode`
commits KV for all K+1 columns *before* acceptance is known and then
rolls back - the constraints that make that safe (rollback drops only
this slot's refs, re-trims the hash chain, keeps rejected-column COW
copies, junk KV above seq_lens is never attended) are documented at
length in :mod:`repro.serving.paged_cache` and must hold for every
ordering of mark_prefilled / rollback / register_pages below.
"""
from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels import page_codec
from repro.kernels import paged_prefill as paged_pf_k
from repro.serving import sampler
from repro.serving.paged_cache import PagedKVCache
from repro.serving.scheduler import (FinishedRequest, InvalidRequestError,
                                     PrefillChunk, Request, Scheduler)

# Placeholder for the presence bitmask on greedy (static-flag) traces:
# the argmax branch never reads it, and shipping the real
# (max_batch, padded_vocab) bool matrix to the device every step would
# make the fast path pay for sampling it is not doing.
_NO_PRESENCE = np.zeros((1, 1), bool)


def _serving_jits(model, mesh=None, codec="fp"):
    """Jitted prefill/verify/sample/copy steps, cached on the model so
    every engine over the same model shares one compile cache
    (benchmarks and tests spin up several engines).  The cache is keyed
    by the tensor-parallel mesh (None = single shard) and the page
    codec - a TP engine and a single-shard engine over the same model
    trace different attention paths, and each codec bakes a different
    encode/decode into the trace.  Cache donation is skipped on CPU,
    where it is unsupported and only adds dispatch overhead."""
    cache = getattr(model, "_serving_jits_v5", None)
    if cache is None:
        cache = model._serving_jits_v5 = {}
    jits = cache.get((mesh, codec))
    if jits is not None:
        return jits

    # Prefill returns the last-position logits instead of a sampled
    # token: first tokens are drawn by the shared ``sample_fn`` below,
    # so a sequence group can fan one prefill out into n first tokens
    # (n rows replicating the same logits under per-branch seeds) while
    # a plain request samples through the *identical* code path - the
    # bit-identity the parallel-sampling conformance suite pins down.
    def prefill_fn(params, layers, tokens, page_table, start_pos, last_pos):
        logits, layers = model.paged_prefill(params, layers, tokens,
                                             page_table, last_pos=last_pos,
                                             start_pos=start_pos, mesh=mesh,
                                             codec=codec)
        return logits[:, 0], layers

    # Prompt-logprobs prefill: same KV writes, but the LM head runs at
    # every chunk position (the cost ``Request.logprobs`` opts into)
    # and each position's log p(next prompt token) comes back alongside
    # the last-position logits.  ``targets[b, j]`` is the stream token
    # at position start + j + 1 (0 where out of range; the host slices
    # the valid prefix).
    def prefill_lp_fn(params, layers, tokens, page_table, start_pos,
                      last_pos, targets):
        logits, layers = model.paged_prefill(params, layers, tokens,
                                             page_table, last_pos=last_pos,
                                             start_pos=start_pos, mesh=mesh,
                                             codec=codec,
                                             return_all_logits=True)
        lsm = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        plp = jnp.take_along_axis(
            lsm, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
        last = jnp.take_along_axis(
            logits, last_pos[:, None, None].astype(jnp.int32), axis=1)
        return last[:, 0], plp, layers

    # ``greedy`` is a static (trace-time) flag: when every row this call
    # serves is argmax (temperature 0, no penalty), the whole sampling
    # pipeline (sorts, nucleus scan, categorical) compiles away.
    # ``want_lp`` (static) additionally returns the chosen token's
    # logprob - the best_of ranking signal - and stays off the greedy
    # hot path when no ranking group is live.
    def sample_fn(logits, presence, seeds, positions, temp, top_k, top_p,
                  rep_pen, greedy, want_lp):
        if greedy:
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            toks = sampler.sample_tokens(logits, presence, seeds,
                                         positions, temp, top_k, top_p,
                                         rep_pen)
        return toks, _chosen_lp(logits, toks, want_lp)

    def topk_fn(logits, k):
        """Top-k (logprob, token) per row - the beam expansion feed."""
        lsm = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        vals, idx = jax.lax.top_k(lsm, k)
        return vals, idx.astype(jnp.int32)

    def _chosen_lp(logits, toks, want_lp):
        if not want_lp:
            return jnp.zeros(toks.shape, jnp.float32)
        lsm = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        return jnp.take_along_axis(
            lsm, toks[..., None].astype(jnp.int32), axis=-1)[..., 0]

    def _extras(logits, toks, beam_k, want_lp):
        """Side outputs of a decode/verify call: top-``beam_k``
        (logprob, token) rows for live beam groups and the chosen
        token's logprob for best_of ranking.  Both statically gated -
        zeros (and no log_softmax) when off."""
        if beam_k:
            lsm = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            tkv, tki = jax.lax.top_k(lsm, beam_k)
            tki = tki.astype(jnp.int32)
        else:
            b, kw = toks.shape
            tkv = jnp.zeros((b, kw, 1), jnp.float32)
            tki = jnp.zeros((b, kw, 1), jnp.int32)
        return tkv, tki, _chosen_lp(logits, toks, want_lp)

    def decode_fn(params, layers, tokens, page_table, seq_lens, chunk_lens,
                  seeds, temp, top_k, top_p, rep_pen, presence, greedy,
                  beam_k, want_lp):
        # spec_k == 0 fast path: the single-token decode attention
        # (append + grouped decode) instead of the chunk-write verify.
        logits, layers = model.paged_decode_step(
            params, layers, tokens, page_table, seq_lens, mesh=mesh,
            codec=codec)
        if greedy:
            toks = jnp.argmax(logits[:, :1], axis=-1).astype(jnp.int32)
        else:
            pos = seq_lens.astype(jnp.int32) + 1
            toks = sampler.sample_tokens(
                logits[:, 0], presence, seeds, pos, temp, top_k, top_p,
                rep_pen)[:, None]
        tkv, tki, lp = _extras(logits, toks, beam_k, want_lp)
        return toks, tkv, tki, lp, layers

    def verify_fn(params, layers, tokens, page_table, seq_lens, chunk_lens,
                  seeds, temp, top_k, top_p, rep_pen, presence, greedy,
                  beam_k, want_lp):
        logits, layers = model.paged_verify_step(
            params, layers, tokens, page_table, seq_lens, chunk_lens,
            mesh=mesh, codec=codec)
        b, kw, v = logits.shape
        if greedy:
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        else:
            pres = sampler.step_presence(presence, tokens)
            # Sampled token i lands at stream index seq_lens + 1 + i.
            pos = seq_lens.astype(jnp.int32)[:, None] + 1 + \
                jnp.arange(kw, dtype=jnp.int32)[None]
            rep = lambda x: jnp.repeat(x, kw, axis=0)  # noqa: E731
            toks = sampler.sample_tokens(
                logits.reshape(b * kw, v), pres.reshape(b * kw, -1),
                rep(seeds), pos.reshape(-1), rep(temp), rep(top_k),
                rep(top_p), rep(rep_pen)).reshape(b, kw)
        tkv, tki, lp = _extras(logits, toks, beam_k, want_lp)
        return toks, tkv, tki, lp, layers

    def copy_fn(layers, src, dst):
        # Layer pools are stacked (groups, P, page, Hkv, d): page axis 1.
        return jax.tree.map(
            lambda pool: paged_pf_k.copy_pages(pool, src, dst, axis=1),
            layers)

    cpu = jax.default_backend() == "cpu"
    donate = () if cpu else (1,)
    jits = (jax.jit(prefill_fn, donate_argnums=donate),
            jax.jit(decode_fn, donate_argnums=donate,
                    static_argnums=(12, 13, 14)),
            jax.jit(verify_fn, donate_argnums=donate,
                    static_argnums=(12, 13, 14)),
            jax.jit(copy_fn, donate_argnums=() if cpu else (0,)),
            jax.jit(sample_fn, static_argnums=(8, 9)),
            jax.jit(topk_fn, static_argnums=(1,)),
            jax.jit(prefill_lp_fn, donate_argnums=donate))
    cache[(mesh, codec)] = jits
    return jits


class ServingEngine:
    # spec_k="auto" draft ceiling: bounds both the per-step draft count
    # and the number of distinct (kw) trace shapes jit ever sees.
    AUTO_SPEC_KMAX = 4

    def __init__(self, model, params, *, max_batch: int = 8,
                 page_size: int = 16, num_pages: int | None = None,
                 max_seq: int | None = None,
                 prefill_budget: int | str | None = None,
                 prefix_caching: bool = True,
                 spec_k: int | str = 0,
                 cached_frac: float = 0.5,
                 adaptive_floor: int | None = None,
                 adaptive_ceiling: int | None = None,
                 mesh=None, kv_codec: str = "fp"):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        # Page codec: how KV rows are stored in the device pools ("fp"
        # = raw compute dtype, "int8" = per-row absmax quant + f32 scale
        # sidecar, "log16" = 16-bit log-domain).  Validated here so a
        # typo'd codec fails at engine construction, not first step.
        self.kv_codec = page_codec.get_codec(kv_codec).name
        # prefill_budget: None = unbounded, int = fixed token budget per
        # step, "adaptive" = derived each step from the decode batch's
        # SLA headroom (see Scheduler.adaptive_prefill_budget), clamped
        # to [adaptive_floor, adaptive_ceiling].
        self.adaptive_prefill = prefill_budget == "adaptive"
        if isinstance(prefill_budget, str) and not self.adaptive_prefill:
            raise ValueError(
                f"prefill_budget must be an int, None or 'adaptive', "
                f"got {prefill_budget!r}")
        if not self.adaptive_prefill and prefill_budget is not None \
                and prefill_budget < 1:
            raise ValueError(
                f"prefill_budget must be >= 1, got {prefill_budget}")
        # spec_k = "auto": speculate up to AUTO_SPEC_KMAX drafts and let
        # the measured accept-rate EMA choose each step's draft count
        # (exact acceptance is lossless at any k, so the token stream is
        # identical to every fixed spec_k - only the step count moves).
        self.auto_spec = spec_k == "auto"
        if self.auto_spec:
            spec_k = self.AUTO_SPEC_KMAX
        elif isinstance(spec_k, str):
            raise ValueError(
                f"spec_k must be an int or 'auto', got {spec_k!r}")
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if not 0.0 <= cached_frac <= 1.0:
            raise ValueError(
                f"cached_frac must be in [0, 1], got {cached_frac}")
        # Tensor parallelism: a mesh with a "model" axis of size tp > 1
        # shards the KV pools by head; everything host-side (page
        # tables, refcounts, scheduler) is oblivious to it.  A "data"
        # axis of size dp > 1 additionally batch-shards every paged
        # attention call on the slot dim (pools and host state stay
        # replicated across data shards - see
        # repro.parallel.collectives).
        self.mesh = mesh
        self.tp = 1 if mesh is None else int(mesh.shape.get("model", 1))
        self.dp = 1 if mesh is None else int(mesh.shape.get("data", 1))
        if self.tp > 1 or self.dp > 1:
            if len(mesh.devices.flat) > len(jax.devices()):
                raise ValueError(
                    f"mesh needs {len(mesh.devices.flat)} devices, have "
                    f"{len(jax.devices())}")
        if self.tp > 1:
            if model.cfg.n_kv_heads % self.tp or \
                    model.cfg.n_heads % self.tp:
                raise ValueError(
                    f"tp={self.tp} must divide n_kv_heads="
                    f"{model.cfg.n_kv_heads} and n_heads="
                    f"{model.cfg.n_heads}")
        if self.dp > 1 and max_batch % self.dp:
            # Decode/verify steps are always max_batch-shaped, so the
            # slot dim must divide evenly for the data axis to shard it
            # (odd prefill groups fall back to replicated compute).
            raise ValueError(
                f"data-parallel degree dp={self.dp} must divide "
                f"max_batch={max_batch}")
        self.model = model
        self.params = params
        self.page_size = page_size
        self.max_batch = max_batch
        self.prefill_budget = prefill_budget
        self.adaptive_floor = adaptive_floor if adaptive_floor is not None \
            else page_size
        self.adaptive_ceiling = adaptive_ceiling \
            if adaptive_ceiling is not None \
            else max(8 * page_size, self.adaptive_floor)
        if not 1 <= self.adaptive_floor <= self.adaptive_ceiling:
            raise ValueError(
                f"need 1 <= adaptive_floor <= adaptive_ceiling, got "
                f"{self.adaptive_floor}..{self.adaptive_ceiling}")
        # EMA of measured prefill throughput (tokens/sec of wall time in
        # _run_chunks) - the rate adaptive_prefill_budget converts SLA
        # headroom seconds into a token budget with.
        self._prefill_rate = 0.0
        self.prefix_caching = prefix_caching
        self.spec_k = spec_k
        max_seq = max_seq if max_seq is not None else model.cfg.max_seq
        self.pages_per_seq = -(-max_seq // page_size)
        if num_pages is None:
            num_pages = max_batch * self.pages_per_seq
        # Bound the dead-prefix LRU to a fraction of the pool so
        # long-running multi-tenant churn cannot turn the whole free
        # pool into single-use cached prefixes (1.0 = uncapped).
        max_cached = None if cached_frac >= 1.0 \
            else int(cached_frac * num_pages)
        self.cache = PagedKVCache(num_pages, page_size, max_batch,
                                  self.pages_per_seq,
                                  max_cached_pages=max_cached)
        self.sched = Scheduler(self.cache)
        self.layers = model.init_paged_cache(num_pages, page_size,
                                             mesh=mesh,
                                             codec=self.kv_codec)
        # Per-slot sampling state (greedy defaults), mirrored to device
        # every step; presence is the repetition-penalty context bitmask.
        self._temp = np.zeros((max_batch,), np.float32)
        self._top_k = np.zeros((max_batch,), np.int32)
        self._top_p = np.ones((max_batch,), np.float32)
        self._rep_pen = np.ones((max_batch,), np.float32)
        self._seed = np.zeros((max_batch,), np.int32)
        self._presence = np.zeros((max_batch, model.cfg.padded_vocab), bool)
        self.stats = {"steps": 0, "prefills": 0, "prefill_chunks": 0,
                      "prefill_tokens": 0, "cached_prefill_tokens": 0,
                      "generated_tokens": 0, "preemptions": 0,
                      "cow_copies": 0, "rejected": 0, "decode_steps": 0,
                      "decode_slot_steps": 0, "decode_tokens": 0,
                      "draft_tokens": 0, "draft_accepted": 0,
                      # Draft-quality EMA (alpha 0.2 over verify steps
                      # that proposed >= 1 draft) and the per-step draft
                      # count it chose when spec_k="auto":
                      "accept_rate_ema": 0.0, "spec_k_last": 0,
                      "rollbacks": 0, "triplet_bytes": 0,
                      "groups": 0, "forks": 0, "beam_steps": 0,
                      "beam_early_stops": 0,
                      "cancelled": 0, "adaptive_budget_last": 0,
                      # AsyncFrontend bookkeeping (kept here so every
                      # serving counter surfaces through one dict, e.g.
                      # the HTTP transport's GET /stats):
                      "results_evicted": 0,    # unclaimed finished
                      #                          results aged out of the
                      #                          bounded LRU
                      "stream_overflows": 0}   # bounded per-stream
        #                                        queues hitting capacity
        #                                        (stalled readers)
        (self._prefill, self._decode, self._verify, self._copy,
         self._sample, self._topk,
         self._prefill_lp) = _serving_jits(model, mesh, self.kv_codec)

    # ------------------------------------------------------------- TP info
    def pool_bytes(self) -> int:
        """Total logical KV pool bytes (across all shards)."""
        return sum(x.nbytes for x in jax.tree.leaves(self.layers))

    def bytes_per_token(self) -> int:
        """Pool bytes consumed per stored KV token-row (all layers, data
        + scale sidecars).  Derived from the actual pool leaves, so it
        is the number the equal-pool-bytes slot math in the benchmark
        uses: at a fixed byte budget a codec admits
        ``fp_bytes_per_token / codec_bytes_per_token`` times the
        sequences."""
        num_pages = self.cache.num_pages
        return self.pool_bytes() // (num_pages * self.page_size)

    def pool_bytes_per_shard(self) -> int:
        """KV pool bytes actually resident on the fullest device,
        *measured* from the arrays' addressable shards - not derived
        from ``tp`` - so a silently dropped pool sharding (replicated
        pools) shows up as full-size here and fails the ``--tp``
        benchmark gate instead of hiding behind arithmetic."""
        per_dev: dict = {}
        for leaf in jax.tree.leaves(self.layers):
            for s in leaf.addressable_shards:
                per_dev[s.device] = per_dev.get(s.device, 0) + \
                    s.data.nbytes
        return max(per_dev.values())

    def _count_triplets(self, batch: int, rows: int) -> None:
        """Account the ACC-merge collective volume of one jitted call:
        each of the tp shards gathers tp padded (o~, m, l) triplets -
        (d_head + 2) f32 per (slot, query row, head, layer)."""
        if self.tp <= 1:
            return
        cfg = self.model.cfg
        per_shard = self.tp * batch * rows * cfg.n_heads * \
            (cfg.d_head + 2) * 4 * cfg.n_layers
        self.stats["triplet_bytes"] += self.tp * per_shard

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        limit = self.pages_per_seq * self.page_size
        need = len(req.prompt) + req.max_new_tokens
        if need > limit:
            raise ValueError(
                f"request {req.rid}: prompt+budget {need} exceeds the "
                f"per-sequence ceiling {limit} (pages_per_seq * page_size)")
        width = req.beam_width if req.beam_width > 0 \
            else (req.best_of if req.best_of is not None else req.n)
        if width > self.max_batch:
            raise ValueError(
                f"request {req.rid}: group width {width} exceeds "
                f"max_batch {self.max_batch}")
        self.sched.submit(req)

    def cancel(self, rid: int) -> bool:
        """Drop request ``rid`` wherever it is (waiting / mid-prefill /
        mid-decode / fanned-out group), freeing its slots and pages
        refcount-clean.  Pending COW copies are flushed *first*: a
        queued device copy whose destination page gets freed here and
        reallocated next step would clobber the new owner's KV.
        Returns True if the request was found.  Must be called between
        engine steps (the async frontend serializes this)."""
        self._apply_pending_copies()
        hit = self.sched.cancel(rid)
        if hit:
            self.stats["cancelled"] += 1
        return hit

    # -------------------------------------------------------------- step
    def _step_budget(self) -> int | None:
        """This step's prefill token budget (None = unbounded)."""
        if not self.adaptive_prefill:
            return self.prefill_budget
        budget = self.sched.adaptive_prefill_budget(
            self._prefill_rate, self.adaptive_floor, self.adaptive_ceiling)
        self.stats["adaptive_budget_last"] = budget
        return budget

    def step(self) -> list[FinishedRequest]:
        """One token-budget step: continue/admit prefill chunks, run one
        batched (speculative) decode over every decoding slot; returns
        the requests that finished during this step."""
        finished: list[FinishedRequest] = []
        # Decoding slots claim their next page BEFORE prefill work is
        # scheduled - otherwise a prompt chunk can grab the last free
        # pages and evict an in-flight decode into a costly replay.
        self._capacity_pass()

        budget = self._step_budget()
        chunks, reused = self.sched.schedule_prefill(budget)
        if not chunks and not self.sched.decoding_slots() \
                and self.sched.running:
            # Gridlock: every running slot is a paused prefill and the
            # pool is dry.  Free the least-advanced one (cheapest
            # replay; its published pages stay claimable) so the rest
            # can finish, then re-plan.
            victim = self.sched.choose_victim()
            if victim is not None:
                self.sched.preempt(victim)
                self.stats["preemptions"] += 1
                chunks, r2 = self.sched.schedule_prefill(budget)
                reused += r2
        self.stats["cached_prefill_tokens"] += reused

        self._apply_pending_copies()
        t0 = time.perf_counter()
        self._run_chunks(chunks, finished)
        if chunks:
            dt = time.perf_counter() - t0
            n_tok = sum(len(ck.tokens) for ck in chunks)
            if dt > 0.0 and n_tok:
                rate = n_tok / dt
                self._prefill_rate = rate if self._prefill_rate == 0.0 \
                    else 0.8 * self._prefill_rate + 0.2 * rate
        # Second (idempotent) capacity pass: slots that finished their
        # prefill this step also append a token below, and a prompt
        # ending exactly on a page boundary needs its next page before
        # the decode scatter.
        self._capacity_pass()
        self._run_decode(finished)
        self.stats["steps"] += 1
        return finished

    # ---------------------------------------------------------- capacity
    def _capacity_pass(self) -> None:
        """Guarantee every decoding slot can append one token, preempting
        the least-advanced running sequence under pool pressure."""
        for slot in self.sched.decoding_slots():
            if slot not in self.sched.running:
                continue                    # already evicted as a victim
            while slot in self.sched.running and \
                    not self.cache.ensure_append_capacity(slot):
                at_ceiling = self.cache.pages_for(
                    int(self.cache.seq_lens[slot]) + 1) \
                    > self.cache.pages_per_seq
                victim = slot if at_ceiling else self.sched.choose_victim()
                self.sched.preempt(victim)
                self.stats["preemptions"] += 1
                # A group victim evicts every branch of its group - the
                # probed slot itself may be gone (membership re-checked
                # by the loop condition).

    def _apply_pending_copies(self) -> None:
        """Apply queued copy-on-write page copies to the device pools.

        Padded to a power-of-two batch (dropped out-of-range writes) so
        jit sees a handful of shapes.
        """
        copies = self.cache.take_pending_copies()
        if not copies:
            return
        self.stats["cow_copies"] += len(copies)
        n = 1
        while n < len(copies):
            n *= 2
        src = np.zeros((n,), np.int32)
        dst = np.full((n,), self.cache.num_pages, np.int32)   # dropped
        for i, (s, d) in enumerate(copies):
            src[i], dst[i] = s, d
        self.layers = self._copy(self.layers, jnp.asarray(src),
                                 jnp.asarray(dst))

    # ----------------------------------------------------------- sampling
    def _all_greedy(self, slots) -> bool:
        """True when every listed slot is pure argmax (temperature 0, no
        repetition penalty) - the static fast-path flag for the jits."""
        idx = np.asarray(list(slots), np.int64)
        return bool(np.all(self._temp[idx] == 0.0)
                    and np.all(self._rep_pen[idx] == 1.0))

    def _set_sampling(self, slot: int) -> None:
        """Mirror a slot's request sampling params into the batched
        per-slot vectors the jitted steps consume."""
        sp = self.sched.running[slot].req.sampling or sampler.GREEDY
        self._temp[slot] = sp.temperature
        self._top_k[slot] = sp.top_k
        self._top_p[slot] = sp.top_p
        self._rep_pen[slot] = sp.repetition_penalty
        self._seed[slot] = sp.seed

    def _rebuild_presence(self, slot: int) -> None:
        """Recompute a slot's repetition-penalty context from its full
        token stream (admission / replay after preemption)."""
        self._presence[slot] = False
        toks = np.asarray(self.sched.running[slot].tokens(), np.int64)
        self._presence[slot, toks] = True

    # ----------------------------------------------------------- prefill
    def _run_chunks(self, chunks: list[PrefillChunk], finished: list):
        """Run this step's prefill chunks, batched by padded length (one
        jit trace per (group size, padded length) pair).  Final chunks
        yield the sequence's first new token(s): the prefill jit returns
        the last-position logits, sequence groups fan out their width
        branches over ``fork`` (sharing every prompt page), and all
        first tokens - one per plain request, one per branch - are drawn
        in a single shared sampling call."""
        for ck in chunks:
            self._set_sampling(ck.slot)
        groups: dict[int, list[PrefillChunk]] = {}
        for ck in chunks:
            lpad = -(-len(ck.tokens) // self.page_size) * self.page_size
            groups.setdefault(lpad, []).append(ck)
        for lpad, grp in sorted(groups.items()):
            bsz = len(grp)
            width = self._pow2_width(max(
                self.cache.pages_for(ck.start + len(ck.tokens))
                for ck in grp))
            toks = np.zeros((bsz, lpad), np.int32)
            rows = np.zeros((bsz, width), np.int32)
            start = np.zeros((bsz,), np.int32)
            last = np.zeros((bsz,), np.int32)
            for i, ck in enumerate(grp):
                toks[i, :len(ck.tokens)] = ck.tokens
                rows[i] = self.cache.page_table[ck.slot, :width]
                start[i] = ck.start
                last[i] = len(ck.tokens) - 1
            # Any logprobs request in the batch routes the whole group
            # through the prompt-logprobs prefill (full-position LM
            # head); groups without one stay on the gathered fast path.
            want_plp = any(self.sched.running[ck.slot].req.logprobs
                           for ck in grp)
            if want_plp:
                logits, plp, self.layers = self._prefill_lp(
                    self.params, self.layers, jnp.asarray(toks),
                    jnp.asarray(rows), jnp.asarray(start),
                    jnp.asarray(last), jnp.asarray(
                        self._prompt_targets(grp, lpad)))
                self._record_prompt_lps(grp, np.asarray(plp))
            else:
                logits, self.layers = self._prefill(
                    self.params, self.layers, jnp.asarray(toks),
                    jnp.asarray(rows), jnp.asarray(start), jnp.asarray(last))
            self.stats["prefills"] += 1
            self._count_triplets(bsz, lpad)
            finals = []
            for i, ck in enumerate(grp):
                self.stats["prefill_chunks"] += 1
                self.stats["prefill_tokens"] += len(ck.tokens)
                self.sched.complete_chunk(ck)
                if self.prefix_caching:
                    self.cache.register_pages(
                        ck.slot, self.sched.running[ck.slot].tokens())
                if ck.is_final:
                    finals.append((i, ck.slot))
            if finals:
                self._finish_prefills(logits, finals, finished)

    def _prompt_targets(self, grp, lpad: int) -> np.ndarray:
        """Next-token target per chunk position: ``targets[i, j]`` is
        chunk i's stream token at absolute position start + j + 1 (what
        the logit at position j predicts), 0 where out of range."""
        targets = np.zeros((len(grp), lpad), np.int32)
        for i, ck in enumerate(grp):
            stream = self.sched.running[ck.slot].tokens()
            hi = min(len(ck.tokens), len(stream) - ck.start - 1)
            if hi > 0:
                targets[i, :hi] = stream[ck.start + 1:ck.start + 1 + hi]
        return targets

    def _record_prompt_lps(self, grp, plp: np.ndarray) -> None:
        """Fill each logprobs request's prompt_lps from this group's
        per-position logprobs: position j of a chunk scores the prompt
        token at stream index start + j + 1.  Indices past the prompt
        (replayed generated tokens) and the final chunk's last position
        (it predicts the first *generated* token - the sampler's lp
        path owns that) are skipped."""
        for i, ck in enumerate(grp):
            st = self.sched.running[ck.slot]
            if not st.req.logprobs:
                continue
            plen = len(st.req.prompt)
            n = len(ck.tokens)
            valid = n - 1 if ck.is_final else n
            for j in range(valid):
                t = ck.start + j + 1
                if 1 <= t < plen:
                    st.prompt_lps[t] = float(plp[i, j])

    def _finish_prefills(self, logits, finals: list, finished: list):
        """First tokens for every sequence whose prefill just completed:
        fan sequence groups out into their branches, then draw one token
        per (plain request | parallel branch) in a single sampling call
        over replicated logits rows, and hand beam roots their top-2k
        expansion."""
        rows: list[tuple[int, int]] = []     # (logits row, slot)
        beams: list[tuple[int, int]] = []
        for i, slot in finals:
            st = self.sched.running[slot]
            if st.group is None:
                self._rebuild_presence(slot)
                rows.append((i, slot))
            elif st.group.beam:
                self.stats["groups"] += 1
                beams.append((i, slot))
            else:
                self.stats["groups"] += 1
                base = st.req.sampling or sampler.GREEDY
                branches = self.sched.fan_out(slot)
                self.stats["forks"] += len(branches) - 1
                for bslot, b in branches:
                    self._set_branch_sampling(bslot, base, b)
                    self._rebuild_presence(bslot)
                    rows.append((i, bslot))
        if rows:
            self._sample_first_tokens(logits, rows, finished)
        for i, slot in beams:
            self._expand_beam_root(logits, i, slot, finished)

    def _sample_first_tokens(self, logits, rows, finished):
        """One sampling call covering every first token: row j draws for
        ``rows[j] = (logits row, slot)`` at the slot's stream position
        under the slot's (branch) seed - the same code path a decode
        step's sampler uses, padded to a power-of-two row count."""
        n = 1
        while n < len(rows):
            n *= 2
        src = np.zeros((n,), np.int32)
        slots = np.zeros((n,), np.int64)
        pos = np.zeros((n,), np.int32)
        for j, (i, slot) in enumerate(rows):
            src[j] = i
            slots[j] = slot
            # The sampled token's stream index is the prompt length plus
            # any generated tokens replayed after a preemption - i.e.
            # the stream length itself.
            pos[j] = self.sched.running[slot].target
        greedy = self._all_greedy(slot for _, slot in rows)
        want_lp = self._want_logprobs()
        pres = _NO_PRESENCE if greedy else self._presence[slots]
        lrows = jnp.take(logits, jnp.asarray(src), axis=0)
        toks, lps = self._sample(
            lrows, jnp.asarray(pres), jnp.asarray(self._seed[slots]),
            jnp.asarray(pos), jnp.asarray(self._temp[slots]),
            jnp.asarray(self._top_k[slots]),
            jnp.asarray(self._top_p[slots]),
            jnp.asarray(self._rep_pen[slots]), greedy, want_lp)
        toks = np.asarray(toks)
        lps = np.asarray(lps)
        for j, (i, slot) in enumerate(rows):
            tok = int(toks[j])
            self.stats["generated_tokens"] += 1
            st = self.sched.running[slot]
            status = self.sched.record_token(slot, tok)
            st.cum_logprob += float(lps[j])
            if st.req.logprobs:
                st.token_logprobs.append(float(lps[j]))
            self._presence[slot, tok] = True
            if status != "running":
                fr = self.sched.finish(slot, status)
                if fr is not None:
                    finished.append(fr)

    def _expand_beam_root(self, logits, i, slot, finished):
        """First beam expansion: top-2*width (logprob, token) candidates
        from the prompt's last-position logits seed the beam."""
        group = self.sched.running[slot].group
        vals, idx = self._topk(logits[i:i + 1], 2 * group.width)
        cands = list(zip(np.asarray(idx)[0].tolist(),
                         np.asarray(vals)[0].tolist()))
        before_tok = self.sched.tokens_emitted
        before_forks = self.sched.forks
        fr = self.sched.fan_out_beam(slot, cands)
        self.stats["generated_tokens"] += \
            self.sched.tokens_emitted - before_tok
        self.stats["forks"] += self.sched.forks - before_forks
        if fr is not None:
            finished.append(fr)
        else:
            self._reset_beam_slots(group)

    def _set_branch_sampling(self, slot: int, sp, branch: int) -> None:
        """Branch ``branch`` samples under ``branch_seed(seed, branch)``
        - otherwise the request's own sampling params."""
        self._temp[slot] = sp.temperature
        self._top_k[slot] = sp.top_k
        self._top_p[slot] = sp.top_p
        self._rep_pen[slot] = sp.repetition_penalty
        self._seed[slot] = sampler.branch_seed(sp.seed, branch)

    def _reset_beam_slots(self, group) -> None:
        """Pin every live beam slot's sampling vectors to greedy: a
        reorder forks branches into slots whose vectors may still hold
        a previous occupant's sampled params, and one stale
        temperature would silently knock the whole batch off the
        greedy (sampling-free) fast path."""
        for slot in group.slots:
            self._set_branch_sampling(slot, sampler.GREEDY, 0)

    def _want_logprobs(self) -> bool:
        """True when any parallel-sampling group is live (branches
        accumulate the chosen-token logprob so completions come back
        scored, and best_of > n can rank on it) or any live request
        asked for per-token logprobs.  Plain serving never pays for the
        extra log_softmax."""
        return any((st.group is not None and not st.group.beam)
                   or st.req.logprobs
                   for st in self.sched.running.values())

    # ------------------------------------------------------------ decode
    def _run_decode(self, finished: list) -> None:
        """One batched verify step: feed each decoding slot its carry
        token plus up to ``spec_k`` prompt-lookup drafts, sample the
        target token at every position on device, and keep the longest
        prefix whose drafts the sampler confirmed.  Rejected columns
        roll the paged KV back to the accepted prefix.  Beam branches
        ride along with a single carry column: their next tokens come
        from the per-group top-2k reorder after the call, never from
        the sampler."""
        k = self.spec_k
        if self.auto_spec:
            # Draft-count auto-tune: spend draft compute proportional to
            # the measured accept rate (floor 1 keeps measuring after a
            # cold start or a workload shift kills the EMA).
            ema = self.stats["accept_rate_ema"]
            k = max(1, min(self.spec_k, round(ema * (self.spec_k + 1))))
        self.stats["spec_k_last"] = k
        steps = self.sched.schedule_decode(k)
        if not steps:
            return
        kw = k + 1
        toks = np.zeros((self.max_batch, kw), np.int32)
        dl = np.zeros((self.max_batch,), np.int32)
        cl = np.zeros((self.max_batch,), np.int32)
        beam_groups: dict[int, object] = {}
        for step in steps:
            slot = step.slot
            st = self.sched.running[slot]
            if st.group is not None and st.group.beam:
                beam_groups.setdefault(id(st.group), st.group)
            sl = int(self.cache.seq_lens[slot])
            c = len(step.tokens)
            if c > 1 and not self.cache.ensure_capacity(slot, sl + c):
                # Pool pressure / per-seq ceiling: shrink the step to
                # the writable pages (the capacity pass guaranteed at
                # least the one-token append).
                c = max(1, min(
                    c, self.cache.writable_token_capacity(slot) - sl))
                del step.tokens[c:]
                del step.drafts[max(0, c - 1):]
            dl[slot] = sl
            cl[slot] = c
            toks[slot, :c] = step.tokens
        width = self._pow2_width(max(
            self.cache.pages_for(int(dl[s.slot] + cl[s.slot]))
            for s in steps))
        self._apply_pending_copies()
        step_fn = self._decode if kw == 1 else self._verify
        greedy = self._all_greedy(s.slot for s in steps)
        beam_k = 2 * max((g.width for g in beam_groups.values()),
                         default=0)
        want_lp = self._want_logprobs()
        sampled, tkv, tki, lps, self.layers = step_fn(
            self.params, self.layers, jnp.asarray(toks),
            jnp.asarray(self.cache.page_table[:, :width]),
            jnp.asarray(dl), jnp.asarray(cl),
            jnp.asarray(self._seed), jnp.asarray(self._temp),
            jnp.asarray(self._top_k), jnp.asarray(self._top_p),
            jnp.asarray(self._rep_pen),
            jnp.asarray(_NO_PRESENCE if greedy else self._presence),
            greedy, beam_k, want_lp)
        sampled = np.asarray(sampled)
        lps = np.asarray(lps)
        self.stats["decode_steps"] += 1
        self.stats["decode_slot_steps"] += len(steps)
        self._count_triplets(self.max_batch, kw)
        step_drafted = step_accepted = 0
        for step in steps:
            slot = step.slot
            st = self.sched.running[slot]
            c = len(step.tokens)
            t = sampled[slot]
            sl = int(self.cache.seq_lens[slot])
            # KV for all c inputs is on device; commit it, then roll
            # back past the accepted prefix below.  Sharp edge: between
            # this mark_prefilled and the rollback, seq_lens over-counts
            # by the rejected columns - nothing in this window may
            # register pages, and a fork must truncate at the accepted
            # length (see the rollback x refcount contract in
            # repro.serving.paged_cache).
            self.cache.mark_prefilled(slot, sl + c)
            if st.group is not None and st.group.beam:
                # Carry KV committed (c == 1, speculation disabled);
                # the group's reorder below picks the next tokens.
                if self.prefix_caching:
                    self.cache.register_pages(slot, st.tokens())
                continue
            # Accept drafts while they equal the sampled target token at
            # their position - exact (lossless) acceptance: t[j-1] is
            # the token the no-spec loop would have emitted where the
            # step fed draft step.tokens[j].
            a = 1
            while a < c and int(t[a - 1]) == step.tokens[a]:
                a += 1
            self.stats["draft_tokens"] += c - 1
            self.stats["draft_accepted"] += a - 1
            st.drafted += c - 1
            st.accepted += a - 1
            step_drafted += c - 1
            step_accepted += a - 1
            status, used = "running", 0
            for j in range(a):
                tok = int(t[j])
                used += 1
                self.stats["generated_tokens"] += 1
                self.stats["decode_tokens"] += 1
                status = self.sched.record_token(slot, tok)
                st.cum_logprob += float(lps[slot, j])
                if st.req.logprobs:
                    st.token_logprobs.append(float(lps[slot, j]))
                self._presence[slot, tok] = True
                if status != "running":
                    break
            if status != "running":
                fr = self.sched.finish(slot, status)
                if fr is not None:
                    finished.append(fr)
                continue
            if used < c:
                # Paged rollback: decrement seq_len to the accepted
                # prefix and free now-empty tail pages (refcounts
                # respected - a forked sibling only loses this slot's
                # reference).
                self.cache.rollback(slot, sl + used)
                self.stats["rollbacks"] += 1
            if self.prefix_caching:
                self.cache.register_pages(
                    slot, self.sched.running[slot].tokens())
        if step_drafted:
            rate = step_accepted / step_drafted
            ema = self.stats["accept_rate_ema"]
            self.stats["accept_rate_ema"] = rate if ema == 0.0 \
                else 0.8 * ema + 0.2 * rate
        if beam_groups:
            tkv = np.asarray(tkv)
            tki = np.asarray(tki)
            for group in beam_groups.values():
                if not group.slots:
                    continue
                # Each group sees exactly its own top-2*width slice, so
                # its expansion is independent of what other live beam
                # groups made the call compute.
                k = 2 * group.width
                per_slot = {
                    s: list(zip(tki[s, 0, :k].tolist(),
                                tkv[s, 0, :k].tolist()))
                    for s in group.slots}
                before_tok = self.sched.tokens_emitted
                before_forks = self.sched.forks
                before_stops = self.sched.beam_early_stops
                fr = self.sched.beam_reorder(group, per_slot)
                self.stats["generated_tokens"] += \
                    self.sched.tokens_emitted - before_tok
                self.stats["forks"] += self.sched.forks - before_forks
                self.stats["beam_early_stops"] += \
                    self.sched.beam_early_stops - before_stops
                self.stats["beam_steps"] += 1
                if fr is not None:
                    finished.append(fr)
                else:
                    self._reset_beam_slots(group)

    def _pow2_width(self, need: int) -> int:
        """Page-table width covering ``need`` pages, rounded up to a
        power of two so jit sees a handful of shapes.

        This is where paging pays on the compute side too: decode and
        prefill-chunk attention cover only the KV that exists, not the
        max_seq reservation the dense cache burns every step.
        """
        width = 1
        while width < need:
            width *= 2
        return min(width, self.pages_per_seq)

    # --------------------------------------------------------------- run
    def run(self, arrivals: list[tuple[int, Request]],
            max_steps: int | None = None) -> list[FinishedRequest]:
        """Drive to completion. arrivals: [(arrival_step, request)].

        A request whose prompt + budget cannot ever fit a sequence's
        page allowance is rejected (``reason="rejected"``) instead of
        killing the serving loop.
        """
        pending = sorted(arrivals, key=lambda a: a[0])
        finished: list[FinishedRequest] = []
        step = 0
        while pending or self.sched.has_work:
            while pending and pending[0][0] <= step:
                _, req = pending.pop(0)
                try:
                    self.submit(req)
                except InvalidRequestError:
                    raise        # contradictory knobs: client misuse
                except ValueError:
                    # resource rejection (prompt/width over capacity)
                    self.stats["rejected"] += 1
                    finished.append(FinishedRequest(
                        rid=req.rid, prompt=req.prompt, tokens=[],
                        reason="rejected"))
            before = self.stats["generated_tokens"]
            finished.extend(self.step())
            step += 1
            if max_steps is not None and step >= max_steps:
                break
            if (self.stats["generated_tokens"] == before
                    and not self.sched.running and not pending
                    and self.sched.waiting):
                raise RuntimeError(
                    "serving stalled: page pool too small for the "
                    "smallest waiting request")
        return finished
