"""ServingEngine: continuous-batching greedy decode over paged KV.

One engine step = admit+prefill new arrivals, then a single batched
decode step over every running slot:

  * prefill runs per admitted request (exact KV, padded to a page
    multiple so jit retraces are bounded by pages_per_seq shapes), and
    its last-position logits yield the first generated token;
  * decode is one jitted call over all ``max_batch`` slots - free slots
    ride along masked (seq_lens == 0), so the trace is unique and
    requests join/leave without recompilation;
  * sequences that outgrow the page pool are preempted back to the
    scheduler queue and resumed later by replaying their tokens.

Greedy argmax happens on-device inside the jitted step; only the
(max_batch,) token vector crosses to the host per step.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.serving.paged_cache import PagedKVCache
from repro.serving.scheduler import FinishedRequest, Request, Scheduler


def _serving_jits(model):
    """Jitted greedy prefill/decode, cached on the model so every engine
    over the same model shares one compile cache (benchmarks and tests
    spin up several engines).  Cache donation is skipped on CPU, where
    it is unsupported and only adds dispatch overhead."""
    jits = getattr(model, "_serving_jits", None)
    if jits is not None:
        return jits

    def prefill_fn(params, layers, tokens, page_table, last_pos):
        logits, layers = model.paged_prefill(params, layers, tokens,
                                             page_table, last_pos)
        return (jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32),
                layers)

    def decode_fn(params, layers, tokens, page_table, seq_lens):
        logits, layers = model.paged_decode_step(
            params, layers, tokens, page_table, seq_lens)
        return (jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32),
                layers)

    donate = (1,) if jax.default_backend() != "cpu" else ()
    jits = (jax.jit(prefill_fn, donate_argnums=donate),
            jax.jit(decode_fn, donate_argnums=donate))
    model._serving_jits = jits
    return jits


class ServingEngine:
    def __init__(self, model, params, *, max_batch: int = 8,
                 page_size: int = 16, num_pages: int | None = None,
                 max_seq: int | None = None):
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.model = model
        self.params = params
        self.page_size = page_size
        self.max_batch = max_batch
        max_seq = max_seq if max_seq is not None else model.cfg.max_seq
        self.pages_per_seq = -(-max_seq // page_size)
        if num_pages is None:
            num_pages = max_batch * self.pages_per_seq
        self.cache = PagedKVCache(num_pages, page_size, max_batch,
                                  self.pages_per_seq)
        self.sched = Scheduler(self.cache)
        self.layers = model.init_paged_cache(num_pages, page_size)
        self._next_tok = np.zeros((max_batch,), np.int32)
        self.stats = {"steps": 0, "prefills": 0, "prefill_tokens": 0,
                      "generated_tokens": 0, "preemptions": 0}
        self._prefill, self._decode = _serving_jits(model)

    # ------------------------------------------------------------- intake
    def submit(self, req: Request) -> None:
        limit = self.pages_per_seq * self.page_size
        need = len(req.prompt) + req.max_new_tokens
        if need > limit:
            raise ValueError(
                f"request {req.rid}: prompt+budget {need} exceeds the "
                f"per-sequence ceiling {limit} (pages_per_seq * page_size)")
        self.sched.submit(req)

    # -------------------------------------------------------------- step
    def step(self) -> list[FinishedRequest]:
        """Admit + prefill arrivals, run one decode step; returns the
        requests that finished during this step."""
        finished = []
        # Running slots claim their next page BEFORE arrivals are
        # admitted - otherwise a new request can grab the last free
        # pages and evict an in-flight sequence into a costly
        # prompt+generated replay (recompute-preemption thrash).
        for slot in sorted(self.sched.running):
            if not self.cache.ensure_append_capacity(slot):
                self.sched.preempt(slot)
                self.stats["preemptions"] += 1

        groups: dict[int, list[tuple[int, list[int]]]] = {}
        for slot, tokens in self.sched.admit():
            npages = self.cache.pages_for(len(tokens))
            groups.setdefault(npages, []).append((slot, tokens))
        for npages, grp in sorted(groups.items()):
            self._prefill_group(npages, grp, finished)

        # Second (idempotent) capacity pass: newly admitted slots also
        # append a token this step, and a prompt ending exactly on a
        # page boundary needs its next page before the decode scatter.
        for slot in sorted(self.sched.running):
            if not self.cache.ensure_append_capacity(slot):
                self.sched.preempt(slot)
                self.stats["preemptions"] += 1

        if self.sched.running:
            toks = jnp.asarray(self._next_tok[:, None])
            nxt, self.layers = self._decode(
                self.params, self.layers, toks,
                jnp.asarray(self.cache.page_table[:, :self._table_width()]),
                jnp.asarray(self.cache.seq_lens))
            nxt = np.asarray(nxt)
            for slot in sorted(self.sched.running):
                self.cache.advance(slot)
                tok = int(nxt[slot])
                self.stats["generated_tokens"] += 1
                status = self.sched.record_token(slot, tok)
                if status == "running":
                    self._next_tok[slot] = tok
                else:
                    finished.append(self.sched.retire(slot, status))
        self.stats["steps"] += 1
        return finished

    def _table_width(self) -> int:
        """Page-table width for this decode step: enough pages for the
        longest running sequence (incl. the token being appended),
        rounded up to a power of two so jit sees a handful of shapes.

        This is where paging pays on the compute side too: attention
        covers only the KV that exists, not the max_seq reservation the
        dense cache burns every step.
        """
        need = max(self.cache.pages_for(int(self.cache.seq_lens[s]) + 1)
                   for s in self.sched.running)
        width = 1
        while width < need:
            width *= 2
        return min(width, self.pages_per_seq)

    def _prefill_group(self, npages: int, grp: list, finished: list):
        """One batched prefill for all admitted requests spanning the
        same page count (they pad to the same length => one jit trace
        per (group size, page count) pair)."""
        lpad = npages * self.page_size
        bsz = len(grp)
        toks = np.zeros((bsz, lpad), np.int32)
        rows = np.zeros((bsz, self.pages_per_seq), np.int32)
        last = np.zeros((bsz,), np.int32)
        for i, (slot, tokens) in enumerate(grp):
            toks[i, :len(tokens)] = tokens
            rows[i] = self.cache.page_table[slot]
            last[i] = len(tokens) - 1
        greedy, self.layers = self._prefill(
            self.params, self.layers, jnp.asarray(toks), jnp.asarray(rows),
            jnp.asarray(last))
        greedy = np.asarray(greedy)
        self.stats["prefills"] += 1
        for i, (slot, tokens) in enumerate(grp):
            self.stats["prefill_tokens"] += len(tokens)
            tok = int(greedy[i])
            self.stats["generated_tokens"] += 1
            status = self.sched.record_token(slot, tok)
            if status == "running":
                self._next_tok[slot] = tok
            else:
                finished.append(self.sched.retire(slot, status))

    # --------------------------------------------------------------- run
    def run(self, arrivals: list[tuple[int, Request]],
            max_steps: int | None = None) -> list[FinishedRequest]:
        """Drive to completion. arrivals: [(arrival_step, request)]."""
        pending = sorted(arrivals, key=lambda a: a[0])
        finished: list[FinishedRequest] = []
        step = 0
        while pending or self.sched.has_work:
            while pending and pending[0][0] <= step:
                self.submit(pending.pop(0)[1])
            before = self.stats["generated_tokens"]
            finished.extend(self.step())
            step += 1
            if max_steps is not None and step >= max_steps:
                break
            if (self.stats["generated_tokens"] == before
                    and not self.sched.running and not pending
                    and self.sched.waiting):
                raise RuntimeError(
                    "serving stalled: page pool too small for the "
                    "smallest waiting request")
        return finished
