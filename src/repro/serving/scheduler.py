"""Continuous-batching request scheduler over a PagedKVCache.

Lifecycle: submit -> (waiting) -> admit -> chunked prefill (one bounded
token-budget chunk per engine step, Sarathi-style, so a long prompt
never stalls running decodes) -> (decoding) -> one token per engine
step -> retire on EOS / length budget.

Under page-pool pressure:

  * a mid-prefill sequence *pauses in place* - it keeps its slot and
    pages and simply schedules no chunk until pages free up, then
    resumes prefill at pos > 0 (no recompute);
  * a decoding sequence that cannot append forces a preemption: the
    victim is the running sequence with the *least accumulated work*
    (fewest KV tokens materialized - cheapest replay), its pages are
    freed (published prefix pages stay claimable in the cache's LRU,
    so the replay usually resumes from the last full prompt page) and
    it re-queues at the front, vLLM recompute-style.

Pure host logic - fully testable without jax.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from repro.serving import spec
from repro.serving.paged_cache import PagedKVCache
from repro.serving.sampler import SamplingParams


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: int | None = None
    sampling: SamplingParams | None = None     # None = greedy


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    prompt: list[int]
    tokens: list[int]          # generated tokens (includes eos if hit)
    reason: str                # "eos" | "length" | "rejected"
    preemptions: int = 0


@dataclasses.dataclass
class _Running:
    req: Request
    generated: list[int]
    seq_no: int = 0            # admission order (FCFS tie-break)
    computed: int = 0          # KV tokens materialized (incl. reused prefix)
    decoding: bool = False     # prefill complete, generating
    preemptions: int = 0

    def __post_init__(self):
        # Maintained incrementally by record_token: tokens() is on the
        # per-step scheduling/registration path, and rebuilding the
        # concatenation there would cost O(len) per call.
        self._stream = list(self.req.prompt) + list(self.generated)

    def tokens(self) -> list[int]:
        """Token stream whose KV backs this sequence: prompt plus any
        generated tokens carried over a preemption (replaying them
        rebuilds the KV state the evicted sequence had).  Shared
        internal list - callers must not mutate it."""
        return self._stream

    @property
    def target(self) -> int:
        return len(self.req.prompt) + len(self.generated)


@dataclasses.dataclass
class DecodeStep:
    """One slot's work item for a (possibly speculative) decode step:
    feed ``tokens`` = [carry token] + ``drafts`` at positions
    ``seq_lens[slot]..``, verify all of them in one paged-attention
    call, and keep the longest prefix the sampler confirms.  A
    non-speculative step is simply ``drafts == []``."""
    slot: int
    tokens: list[int]
    drafts: list[int]


@dataclasses.dataclass
class PrefillChunk:
    """One bounded prefill chunk: write KV for ``tokens`` at positions
    [start, start + len(tokens)) of ``slot``.  The final chunk's
    last-position logits yield the sequence's next token."""
    slot: int
    tokens: list[int]
    start: int
    is_final: bool


class Scheduler:
    """Admission / chunked prefill / preemption / retirement."""

    def __init__(self, cache: PagedKVCache):
        self.cache = cache
        self.waiting: deque[_Running] = deque()
        self.running: dict[int, _Running] = {}     # slot -> state
        self._seq_no = 0

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        assert len(req.prompt) >= 1, "empty prompt"
        assert req.max_new_tokens >= 1
        self.waiting.append(_Running(req, []))

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def decoding_slots(self) -> list[int]:
        return sorted(s for s, st in self.running.items() if st.decoding)

    def prefilling_slots(self) -> list[int]:
        return sorted(s for s, st in self.running.items() if not st.decoding)

    # --------------------------------------------------------- admission
    def schedule_prefill(self, budget: int | None) -> tuple[
            list[PrefillChunk], int]:
        """Plan this step's prefill work under a token ``budget``
        (None = unbounded).

        In-flight prefills continue first (oldest admission first), then
        waiting requests are admitted FCFS while budget, a slot, and
        pages for prompt + one decode append remain - each admission
        claims the longest cached prompt prefix (full pages) instead of
        recomputing it.  A sequence whose next chunk cannot get pages is
        paused in place (no chunk, keeps pages).

        Returns (chunks, prefix_tokens_reused_by_new_admissions).
        """
        chunks: list[PrefillChunk] = []
        left = budget if budget is not None else None
        reused = 0
        live = [(st.seq_no, slot) for slot, st in self.running.items()
                if not st.decoding]
        for _, slot in sorted(live):
            if left is not None and left <= 0:
                return chunks, reused
            ck = self._chunk_for(slot, left)
            if ck is not None:
                chunks.append(ck)
                if left is not None:
                    left -= len(ck.tokens)
        while self.waiting and (left is None or left > 0):
            st = self.waiting[0]
            toks = st.tokens()
            shared = self.cache.lookup_prefix(toks)
            if not self.cache.can_admit(len(toks), shared):
                break                      # FCFS: head blocks the queue
            self.waiting.popleft()
            slot = self.cache.alloc_slot(len(toks), shared, lazy=True)
            st.computed = len(shared) * self.cache.page_size
            st.decoding = False
            st.seq_no = self._seq_no
            self._seq_no += 1
            self.running[slot] = st
            reused += st.computed
            ck = self._chunk_for(slot, left)
            if ck is not None:
                chunks.append(ck)
                if left is not None:
                    left -= len(ck.tokens)
        return chunks, reused

    def _chunk_for(self, slot: int, left: int | None) -> PrefillChunk | None:
        """Next prefill chunk for ``slot`` under the remaining budget,
        shrunk to the pages actually obtainable (pause-in-place when the
        pool is dry)."""
        st = self.running[slot]
        toks = st.tokens()
        remaining = st.target - st.computed
        n = remaining if left is None else min(remaining, left)
        if n <= 0:
            return None
        if not self.cache.ensure_capacity(slot, st.computed + n):
            # Shrink to pages that are actually writable - a shared page
            # whose copy-on-write failed for lack of a free page must
            # NOT be written (a forked sibling still reads it).
            n = min(n, self.cache.writable_token_capacity(slot)
                    - st.computed)
            if n <= 0:
                return None                # paused in place, pages kept
        return PrefillChunk(
            slot=slot, tokens=toks[st.computed:st.computed + n],
            start=st.computed, is_final=(st.computed + n == st.target))

    def complete_chunk(self, chunk: PrefillChunk) -> None:
        """Record that ``chunk``'s KV is on device; the final chunk
        flips the sequence into the decode phase."""
        st = self.running[chunk.slot]
        assert st.computed == chunk.start, (st.computed, chunk.start)
        st.computed += len(chunk.tokens)
        self.cache.mark_prefilled(chunk.slot, st.computed)
        if chunk.is_final:
            assert st.computed == st.target
            st.decoding = True

    def admit(self) -> list[tuple[int, list[int]]]:
        """Legacy all-at-once admission (no chunking): admit waiting
        requests while slots + pages allow (FCFS), allocating every page
        up front.  Returns [(slot, tokens_to_prefill)].

        Kept for host-only scheduler tests; the engine admits through
        :meth:`schedule_prefill`.  Both paths share ``can_admit`` (the
        decode-page reserve) and ``alloc_slot``.
        """
        out = []
        while self.waiting:
            st = self.waiting[0]
            toks = st.tokens()
            if not self.cache.can_admit(len(toks)):
                break
            self.waiting.popleft()
            slot = self.cache.alloc_slot(len(toks))
            st.computed = st.target
            st.decoding = True
            st.seq_no = self._seq_no
            self._seq_no += 1
            self.running[slot] = st
            out.append((slot, toks))
        return out

    # ----------------------------------------------------- decode planning
    def schedule_decode(self, spec_k: int = 0) -> list[DecodeStep]:
        """Plan this step's decode work: one :class:`DecodeStep` per
        decoding slot.  With ``spec_k > 0`` the prompt-lookup proposer
        drafts up to ``spec_k`` continuation tokens from the request's
        own token history (never past the remaining generation budget -
        a token beyond it could only be discarded).  The carry token is
        the stream's last generated token, whose KV lands at
        ``seq_lens[slot]`` during the verify step.
        """
        out: list[DecodeStep] = []
        for slot in self.decoding_slots():
            st = self.running[slot]
            stream = st.tokens()
            remaining = st.req.max_new_tokens - len(st.generated)
            n_draft = min(spec_k, max(0, remaining - 1))
            drafts = spec.propose_draft(stream, n_draft) if n_draft else []
            out.append(DecodeStep(slot=slot, tokens=[stream[-1]] + drafts,
                                  drafts=drafts))
        return out

    # ------------------------------------------------------- progression
    def record_token(self, slot: int, tok: int) -> str:
        """Append a generated token; returns "running"|"eos"|"length"."""
        st = self.running[slot]
        st.generated.append(tok)
        st._stream.append(tok)
        if st.req.eos_id is not None and tok == st.req.eos_id:
            return "eos"
        if len(st.generated) >= st.req.max_new_tokens:
            return "length"
        return "running"

    def choose_victim(self) -> int | None:
        """Preemption victim: the running sequence with the least
        accumulated work (fewest materialized KV tokens - cheapest to
        replay); newest admission loses ties (FCFS fairness)."""
        if not self.running:
            return None
        return min(self.running,
                   key=lambda s: (int(self.cache.seq_lens[s]),
                                  -self.running[s].seq_no))

    def preempt(self, slot: int) -> None:
        """Evict a running sequence (page-pool pressure); progress is
        kept as tokens: the resumed prefill replays prompt + generated
        (minus whatever prefix pages are still cached).

        Re-queued at the *front*: oldest work resumes first, and a
        preempted sequence never starves behind new arrivals.
        """
        st = self.running.pop(slot)
        st.preemptions += 1
        st.computed = 0
        st.decoding = False
        self.cache.free_slot(slot)
        self.waiting.appendleft(st)

    def retire(self, slot: int, reason: str) -> FinishedRequest:
        st = self.running.pop(slot)
        self.cache.free_slot(slot)
        return FinishedRequest(rid=st.req.rid, prompt=st.req.prompt,
                               tokens=st.generated, reason=reason,
                               preemptions=st.preemptions)
