"""Continuous-batching request scheduler over a PagedKVCache.

Lifecycle: submit -> (waiting) -> admit/prefill -> (running) -> one
token per engine step -> retire on EOS / length budget, or preempt back
to waiting when the page pool runs dry (progress is kept: the resumed
prefill replays prompt + generated-so-far, vLLM-style recompute
preemption).  Pure host logic - fully testable without jax.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from repro.serving.paged_cache import PagedKVCache


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: int | None = None


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    prompt: list[int]
    tokens: list[int]          # generated tokens (includes eos if hit)
    reason: str                # "eos" | "length"
    preemptions: int = 0


@dataclasses.dataclass
class _Running:
    req: Request
    generated: list[int]
    preemptions: int = 0


class Scheduler:
    """Admission / preemption / retirement; token progress per request."""

    def __init__(self, cache: PagedKVCache):
        self.cache = cache
        self.waiting: deque[_Running] = deque()
        self.running: dict[int, _Running] = {}     # slot -> state

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        assert len(req.prompt) >= 1, "empty prompt"
        assert req.max_new_tokens >= 1
        self.waiting.append(_Running(req, []))

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    # --------------------------------------------------------- admission
    def admit(self) -> list[tuple[int, list[int]]]:
        """Admit waiting requests while slots + pages allow (FCFS).

        Returns [(slot, tokens_to_prefill)]: prompt plus any generated
        tokens carried over from a preemption - replaying them rebuilds
        the KV state the evicted sequence had.
        """
        out = []
        while self.waiting:
            st = self.waiting[0]
            tokens = st.req.prompt + st.generated
            if not self.cache.can_admit(len(tokens)):
                break
            self.waiting.popleft()
            slot = self.cache.alloc_slot(len(tokens))
            self.running[slot] = st
            out.append((slot, tokens))
        return out

    # ------------------------------------------------------- progression
    def record_token(self, slot: int, tok: int) -> str:
        """Append a generated token; returns "running"|"eos"|"length"."""
        st = self.running[slot]
        st.generated.append(tok)
        if st.req.eos_id is not None and tok == st.req.eos_id:
            return "eos"
        if len(st.generated) >= st.req.max_new_tokens:
            return "length"
        return "running"

    def preempt(self, slot: int) -> None:
        """Evict a running sequence (page-pool pressure); keep progress.

        Re-queued at the *front*: oldest work resumes first, and a
        preempted sequence never starves behind new arrivals.
        """
        st = self.running.pop(slot)
        st.preemptions += 1
        self.cache.free_slot(slot)
        self.waiting.appendleft(st)

    def retire(self, slot: int, reason: str) -> FinishedRequest:
        st = self.running.pop(slot)
        self.cache.free_slot(slot)
        return FinishedRequest(rid=st.req.rid, prompt=st.req.prompt,
                               tokens=st.generated, reason=reason,
                               preemptions=st.preemptions)
