"""Continuous-batching request scheduler over a PagedKVCache.

Lifecycle: submit -> (waiting) -> admit -> chunked prefill (one bounded
token-budget chunk per engine step, Sarathi-style, so a long prompt
never stalls running decodes) -> (decoding) -> one token per engine
step -> retire on EOS / length budget.

Under page-pool pressure:

  * a mid-prefill sequence *pauses in place* - it keeps its slot and
    pages and simply schedules no chunk until pages free up, then
    resumes prefill at pos > 0 (no recompute);
  * a decoding sequence that cannot append forces a preemption: the
    victim is the running sequence with the *least accumulated work*
    (fewest KV tokens materialized - cheapest replay), its pages are
    freed (published prefix pages stay claimable in the cache's LRU,
    so the replay usually resumes from the last full prompt page) and
    it re-queues at the front, vLLM recompute-style.

Sequence groups (parallel sampling / beam search): a request with
``n > 1`` (or ``best_of``, or ``beam_width > 0``) is admitted as ONE
prefill and fanned out into ``width`` branch slots over
``PagedKVCache.fork`` - a fork costs one page-table row plus refcount
bumps, never a KV copy, so n-best serving scales with *distinct*
tokens, not with n.  Parallel-sampling branches then decode like
independent requests (per-branch seeds); beam branches are reordered
every step (top-2k expansion, fork the parents that keep multiple
children, free the childless ones).  Preemption is group-aware: the
whole group is evicted and the request re-queued - regeneration is
deterministic (seeded keys / beam scores are pure functions of the
request), so the group re-derives the same completions from whatever
shared prefix pages survive in the cache LRU.

Latency classes (SLA-aware scheduling): every request carries a
:class:`LatencyClass` - a TTFT target (admission to first token), a
TPOT target (gap between subsequent tokens) and a priority rank.
Admission is priority-ordered across classes (FCFS within a class, and
the best-ranked waiting request head-blocks the queue so a big
interactive prompt is never starved by a stream of batch arrivals),
preemption evicts the least-urgent class first, and
:meth:`Scheduler.adaptive_prefill_budget` derives the per-step chunked
prefill budget from the decode batch's TPOT headroom instead of a
fixed ``--prefill-budget``: the tighter the most-urgent decoding slot's
next-token deadline, the fewer prompt tokens ride along in its step.

Cancellation (:meth:`Scheduler.cancel`): an abandoned stream is removed
wherever it is - waiting, mid-prefill, mid-decode, or a fanned-out
sequence group - and every slot/page reference it held is released
refcount-clean (published prefix pages park in the cache LRU as on any
retirement).

Pure host logic - fully testable without jax.  Wall-clock is injected
(``clock``) so SLA behavior is deterministic under test.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

from repro.serving import spec
from repro.serving.paged_cache import PagedKVCache
from repro.serving.sampler import SamplingParams


@dataclasses.dataclass(frozen=True)
class LatencyClass:
    """One SLA tier: targets in seconds, lower ``priority`` = more
    urgent.  The targets are *scheduling inputs* (headroom / ordering),
    not hard guarantees - the open-loop benchmark reports the achieved
    p50/p99 TTFT and TPOT per class against them."""
    name: str
    ttft_target: float      # admission -> first token, seconds
    tpot_target: float      # per-token gap while decoding, seconds
    priority: int           # admission / eviction rank (0 = most urgent)


INTERACTIVE = LatencyClass("interactive", ttft_target=0.5,
                           tpot_target=0.05, priority=0)
STANDARD = LatencyClass("standard", ttft_target=2.0,
                        tpot_target=0.2, priority=1)
BATCH = LatencyClass("batch", ttft_target=30.0,
                     tpot_target=2.0, priority=2)
LATENCY_CLASSES = {c.name: c for c in (INTERACTIVE, STANDARD, BATCH)}


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    eos_id: int | None = None
    sampling: SamplingParams | None = None     # None = greedy
    latency_class: LatencyClass = STANDARD     # SLA tier (see above)
    # -- sequence-group knobs (parallel sampling / beam search) -----------
    n: int = 1                    # completions returned
    best_of: int | None = None    # branches sampled (>= n); None = n
    beam_width: int = 0           # > 0: length-normalized beam search
    length_penalty: float = 1.0   # score = cum_logprob / len**length_penalty
    # Return per-token logprobs: generated-token logprobs on every
    # completion, prompt-token logprobs on the FinishedRequest (None at
    # position 0 and at positions restored from the prefix cache, whose
    # logits were never computed).
    logprobs: bool = False
    # Beam search only: stop expanding once ``n`` hypotheses are
    # finished and no live branch's score upper bound can beat the
    # n-th best finished score (results provably unchanged; saves the
    # tail decode steps).  Off = run until ``beam_width`` hypotheses
    # finish or every branch exhausts its budget.
    beam_early_stop: bool = True
    # Multi-tenant fairness: waiting requests of the same latency class
    # are round-robined across tenants (see Scheduler._waiting_key);
    # the default "" (everything one tenant) degrades to plain FCFS
    # within the class.  The HTTP transport fills this from the
    # ``x-tenant`` request header.
    tenant: str = ""


@dataclasses.dataclass
class Completion:
    """One finished branch of a sequence group."""
    tokens: list[int]          # generated tokens (includes eos if hit)
    branch: int                # branch id (seed fold for parallel sampling)
    reason: str                # "eos" | "length"
    score: float = 0.0         # length-normalized cumulative logprob
    # Per generated token log p(token | prefix); only when the request
    # set ``logprobs`` (None otherwise - never an empty list).
    token_logprobs: list[float] | None = None


@dataclasses.dataclass
class FinishedRequest:
    rid: int
    prompt: list[int]
    tokens: list[int]          # generated tokens (includes eos if hit)
    reason: str                # "eos" | "length" | "rejected" | "cancelled"
    preemptions: int = 0
    # Scheduler-side time to first token (seconds, submit -> first
    # recorded token); None for rejected/cancelled-before-first-token
    # and for sequence groups (the async frontend measures groups and
    # client-visible latency itself).
    ttft: float | None = None
    # Sequence groups only: the n returned completions (tokens/reason
    # above mirror completions[0]).  Ordered by branch id for plain
    # n-parallel sampling, by score (desc) when ranking applies
    # (best_of > n, or beam search).
    completions: list[Completion] | None = None
    # ``Request.logprobs`` only (None otherwise): per-token logprobs.
    # prompt_logprobs[i] = log p(prompt[i] | prompt[:i]); None at i = 0
    # and at positions whose KV came from the prefix cache (their
    # logits were never computed).  token_logprobs mirrors
    # completions[0] for groups.
    prompt_logprobs: list[float | None] | None = None
    token_logprobs: list[float] | None = None
    # Speculative-decode draft quality for THIS request: accepted /
    # proposed over its lifetime (survives preemption replay).  None
    # when no draft was ever proposed for it (spec_k == 0, groups,
    # too-short streams) - never NaN.
    accept_rate: float | None = None


@dataclasses.dataclass
class SequenceGroup:
    """Host bookkeeping for one parallel-sampling / beam request.

    One prefill, ``width`` branch slots sharing every prompt page by
    refcount.  ``slots`` tracks the live branches; ``finished`` collects
    completions until the group retires (all branches done, or - for
    beam - ``width`` hypotheses finished).
    """
    req: Request
    width: int                 # branches fanned out of the shared prefill
    beam: bool
    slots: set[int] = dataclasses.field(default_factory=set)
    finished: list[Completion] = dataclasses.field(default_factory=list)
    # Parent's full prompt pages at fan-out: branches never write below
    # the prompt, so these stay physically shared for the group's life
    # (the shared-prefix invariant the property suite checks).
    prefix_pages: tuple[int, ...] = ()
    fanned_out: bool = False
    preemptions: int = 0
    next_branch: int = 0
    # ``Request.logprobs``: the shared prompt's logprobs, stashed off
    # the parent branch at fan-out (branch slots never recompute them).
    prompt_lps: list[float | None] = dataclasses.field(
        default_factory=list)

    @property
    def ranked(self) -> bool:
        """Completions are ranked by score (vs returned by branch id)."""
        return self.beam or self.width > self.req.n

    def score(self, cum_logprob: float, length: int) -> float:
        return cum_logprob / (max(length, 1) ** self.req.length_penalty)


class InvalidRequestError(ValueError):
    """Contradictory request knobs (client misuse).  Deliberately NOT
    absorbed by ``ServingEngine.run``'s per-request rejection path -
    unlike a resource rejection (prompt/width over the engine's
    capacity), a self-contradictory request should fail loudly, not
    come back as ``reason="rejected"``."""


def _make_group(req: Request) -> SequenceGroup | None:
    """Validate the group knobs; None when the request is a plain
    single-stream one."""
    if req.n < 1:
        raise InvalidRequestError(
            f"request {req.rid}: n must be >= 1, got {req.n}")
    if req.beam_width > 0:
        if req.best_of is not None:
            raise InvalidRequestError(
                f"request {req.rid}: best_of is a parallel-sampling knob, "
                f"incompatible with beam_width")
        if req.n > req.beam_width:
            raise InvalidRequestError(
                f"request {req.rid}: n={req.n} exceeds beam_width="
                f"{req.beam_width}")
        if req.sampling is not None and req.sampling.temperature > 0:
            raise InvalidRequestError(
                f"request {req.rid}: beam search is deterministic - "
                f"temperature must be 0")
        return SequenceGroup(req, req.beam_width, beam=True)
    width = req.best_of if req.best_of is not None else req.n
    if width < req.n:
        raise InvalidRequestError(
            f"request {req.rid}: best_of={width} < n={req.n}")
    if width == 1:
        return None
    return SequenceGroup(req, width, beam=False)


@dataclasses.dataclass
class _Running:
    req: Request
    generated: list[int]
    seq_no: int = 0            # admission order (FCFS tie-break)
    computed: int = 0          # KV tokens materialized (incl. reused prefix)
    decoding: bool = False     # prefill complete, generating
    preemptions: int = 0
    group: SequenceGroup | None = None
    branch: int = 0            # branch id within the group
    cum_logprob: float = 0.0   # beam / best_of ranking state
    # -- SLA bookkeeping (scheduler clock) --------------------------------
    submit_time: float = 0.0          # original submission (survives
    #                                   preemption replay)
    first_token_time: float | None = None
    last_token_time: float = 0.0      # base of the next-token deadline
    queue_seq: int = 0                # waiting order within a class
    fair_round: int = 0               # tenant round-robin round (see
    #                                   Scheduler._waiting_key)
    # Speculative-draft quality (engine fills these in its accept loop):
    drafted: int = 0                  # draft tokens proposed for this slot
    accepted: int = 0                 # ... of which the sampler confirmed

    def __post_init__(self):
        # Maintained incrementally by record_token: tokens() is on the
        # per-step scheduling/registration path, and rebuilding the
        # concatenation there would cost O(len) per call.
        self._stream = list(self.req.prompt) + list(self.generated)
        # ``Request.logprobs`` bookkeeping.  token_logprobs survives
        # preemption alongside ``generated`` (the replay prefill does
        # not re-sample); prompt_lps fills in as prefill chunks compute
        # each position's logits (cache-reused positions stay None).
        self.token_logprobs: list[float] = []
        self.prompt_lps: list[float | None] = \
            [None] * len(self.req.prompt) if self.req.logprobs else []

    def tokens(self) -> list[int]:
        """Token stream whose KV backs this sequence: prompt plus any
        generated tokens carried over a preemption (replaying them
        rebuilds the KV state the evicted sequence had).  Shared
        internal list - callers must not mutate it."""
        return self._stream

    @property
    def target(self) -> int:
        return len(self.req.prompt) + len(self.generated)


@dataclasses.dataclass
class DecodeStep:
    """One slot's work item for a (possibly speculative) decode step:
    feed ``tokens`` = [carry token] + ``drafts`` at positions
    ``seq_lens[slot]..``, verify all of them in one paged-attention
    call, and keep the longest prefix the sampler confirms.  A
    non-speculative step is simply ``drafts == []``."""
    slot: int
    tokens: list[int]
    drafts: list[int]


@dataclasses.dataclass
class PrefillChunk:
    """One bounded prefill chunk: write KV for ``tokens`` at positions
    [start, start + len(tokens)) of ``slot``.  The final chunk's
    last-position logits yield the sequence's next token."""
    slot: int
    tokens: list[int]
    start: int
    is_final: bool


class Scheduler:
    """Admission / chunked prefill / preemption / retirement.

    ``clock`` is the monotonic time source for the SLA bookkeeping
    (defaults to ``time.monotonic``); tests inject a fake."""

    def __init__(self, cache: PagedKVCache, clock=time.monotonic):
        self.cache = cache
        self.clock = clock
        self.waiting: deque[_Running] = deque()
        self.running: dict[int, _Running] = {}     # slot -> state
        self._seq_no = 0
        # Waiting order: requests are admitted by (class priority,
        # queue_seq).  Fresh submissions draw increasing seqs (FCFS
        # within a class); preempted work draws decreasing ones, so it
        # resumes ahead of every later arrival of its class.
        self._queue_seq_next = 0
        self._queue_seq_front = -1
        # Per-tenant fairness within a class: start-time fair queuing
        # with unit service times.  A submission's fair_round is
        # max(the tenant's own next round, the class's virtual time =
        # the highest round already admitted), so a bursting tenant
        # runs its rounds up while a freshly-arriving tenant enters at
        # the current virtual time: admission round-robins across
        # tenants and stays FCFS within one (a single tenant's rounds
        # are monotone, so the key degrades to (priority, queue_seq)).
        self._tenant_round: dict[tuple[int, str], int] = {}
        self._class_vt: dict[int, int] = {}
        # Monotone accounting the engine reads as deltas around group
        # operations (beam reorders emit tokens and fork slots deep
        # inside the scheduler).
        self.tokens_emitted = 0
        self.forks = 0
        self.beam_early_stops = 0

    # ------------------------------------------------------------ intake
    def submit(self, req: Request) -> None:
        assert len(req.prompt) >= 1, "empty prompt"
        assert req.max_new_tokens >= 1
        now = self.clock()
        st = _Running(req, [], group=_make_group(req))
        st.submit_time = st.last_token_time = now
        st.queue_seq = self._queue_seq_next
        self._queue_seq_next += 1
        ckey = req.latency_class.priority
        st.fair_round = max(self._tenant_round.get((ckey, req.tenant), 0),
                            self._class_vt.get(ckey, 0))
        self._tenant_round[(ckey, req.tenant)] = st.fair_round + 1
        self.waiting.append(st)

    @staticmethod
    def _waiting_key(st: _Running) -> tuple[int, int, int]:
        # Class priority first, then the tenant round-robin round, then
        # arrival order: within a class, tenants take turns; within a
        # tenant (and with a single tenant), FCFS by queue_seq.
        # Preempted work carries fair_round = -1 (see _requeue_front),
        # so it resumes ahead of every fresh arrival of its class.
        return (st.req.latency_class.priority, st.fair_round, st.queue_seq)

    def _advance_vt(self, st: _Running) -> None:
        """Advance the class's virtual time to an admitted request's
        round, and drop tenant entries at/below it (max(round, vt)
        makes them indistinguishable from absent - pruning keeps the
        table bounded by the number of *backlogged* tenants)."""
        ckey = st.req.latency_class.priority
        if st.fair_round > self._class_vt.get(ckey, 0):
            self._class_vt[ckey] = vt = st.fair_round
            for k in [k for k, r in self._tenant_round.items()
                      if k[0] == ckey and r <= vt]:
                del self._tenant_round[k]

    def _next_waiting(self) -> _Running | None:
        """Best waiting candidate: most urgent class first, FCFS within
        a class, preempted work ahead of fresh arrivals.  This is the
        *only* candidate admission tries - a blocked urgent request
        head-blocks the queue (no lower-class bypass) so it cannot be
        starved by a stream of small batch-class arrivals."""
        if not self.waiting:
            return None
        return min(self.waiting, key=self._waiting_key)

    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)

    def decoding_slots(self) -> list[int]:
        return sorted(s for s, st in self.running.items() if st.decoding)

    def prefilling_slots(self) -> list[int]:
        return sorted(s for s, st in self.running.items() if not st.decoding)

    def group_slots(self) -> set[int]:
        """Slots currently owned by sequence-group branches."""
        return {s for s, st in self.running.items() if st.group is not None}

    def _reserved_slots(self) -> int:
        """Slots admission must keep free for live groups: the pending
        fan-out of a mid-prefill group parent (width - 1 forks land the
        step its prefill completes), and beam regrowth headroom (an
        eos-finished hypothesis frees a slot the next reorder may
        re-fill up to width)."""
        groups: dict[int, SequenceGroup] = {}
        for st in self.running.values():
            if st.group is not None:
                groups[id(st.group)] = st.group
        total = 0
        for g in groups.values():
            if not g.fanned_out:
                total += g.width - 1
            elif g.beam:
                total += g.width - len(g.slots)
        return total

    # --------------------------------------------------------- admission
    def schedule_prefill(self, budget: int | None) -> tuple[
            list[PrefillChunk], int]:
        """Plan this step's prefill work under a token ``budget``
        (None = unbounded).

        In-flight prefills continue first (oldest admission first), then
        waiting requests are admitted FCFS while budget, a slot, and
        pages for prompt + one decode append remain - each admission
        claims the longest cached prompt prefix (full pages) instead of
        recomputing it.  A sequence whose next chunk cannot get pages is
        paused in place (no chunk, keeps pages).

        Returns (chunks, prefix_tokens_reused_by_new_admissions).
        """
        chunks: list[PrefillChunk] = []
        left = budget if budget is not None else None
        reused = 0
        live = [(st.seq_no, slot) for slot, st in self.running.items()
                if not st.decoding]
        for _, slot in sorted(live):
            if left is not None and left <= 0:
                return chunks, reused
            ck = self._chunk_for(slot, left)
            if ck is not None:
                chunks.append(ck)
                if left is not None:
                    left -= len(ck.tokens)
        while self.waiting and (left is None or left > 0):
            st = self._next_waiting()
            toks = st.tokens()
            shared = self.cache.lookup_prefix(toks)
            if not self.cache.can_admit(len(toks), shared):
                break          # priority head blocks the queue (no
                #                lower-class bypass - starvation-free)
            # Group-aware slot budget: a group needs its full fan-out
            # width, and slots reserved for other live groups (pending
            # fan-outs, beam regrowth) are off-limits.
            need_slots = st.group.width if st.group is not None else 1
            if self.cache.free_slot_count - self._reserved_slots() \
                    < need_slots:
                break
            self.waiting.remove(st)
            self._advance_vt(st)
            slot = self.cache.alloc_slot(len(toks), shared, lazy=True)
            st.computed = len(shared) * self.cache.page_size
            st.decoding = False
            st.seq_no = self._seq_no
            self._seq_no += 1
            self.running[slot] = st
            reused += st.computed
            ck = self._chunk_for(slot, left)
            if ck is not None:
                chunks.append(ck)
                if left is not None:
                    left -= len(ck.tokens)
        return chunks, reused

    def _chunk_for(self, slot: int, left: int | None) -> PrefillChunk | None:
        """Next prefill chunk for ``slot`` under the remaining budget,
        shrunk to the pages actually obtainable (pause-in-place when the
        pool is dry)."""
        st = self.running[slot]
        toks = st.tokens()
        remaining = st.target - st.computed
        n = remaining if left is None else min(remaining, left)
        if n <= 0:
            return None
        if not self.cache.ensure_capacity(slot, st.computed + n):
            # Shrink to pages that are actually writable - a shared page
            # whose copy-on-write failed for lack of a free page must
            # NOT be written (a forked sibling still reads it).
            n = min(n, self.cache.writable_token_capacity(slot)
                    - st.computed)
            if n <= 0:
                return None                # paused in place, pages kept
        return PrefillChunk(
            slot=slot, tokens=toks[st.computed:st.computed + n],
            start=st.computed, is_final=(st.computed + n == st.target))

    def complete_chunk(self, chunk: PrefillChunk) -> None:
        """Record that ``chunk``'s KV is on device; the final chunk
        flips the sequence into the decode phase."""
        st = self.running[chunk.slot]
        assert st.computed == chunk.start, (st.computed, chunk.start)
        st.computed += len(chunk.tokens)
        self.cache.mark_prefilled(chunk.slot, st.computed)
        if chunk.is_final:
            assert st.computed == st.target
            st.decoding = True

    def admit(self) -> list[tuple[int, list[int]]]:
        """Legacy all-at-once admission (no chunking): admit waiting
        requests while slots + pages allow (FCFS), allocating every page
        up front.  Returns [(slot, tokens_to_prefill)].

        Kept for host-only scheduler tests; the engine admits through
        :meth:`schedule_prefill`.  Both paths share ``can_admit`` (the
        decode-page reserve) and ``alloc_slot``.
        """
        out = []
        while self.waiting:
            st = self._next_waiting()
            toks = st.tokens()
            if not self.cache.can_admit(len(toks)):
                break
            need_slots = st.group.width if st.group is not None else 1
            if self.cache.free_slot_count - self._reserved_slots() \
                    < need_slots:
                break
            self.waiting.remove(st)
            self._advance_vt(st)
            slot = self.cache.alloc_slot(len(toks))
            st.computed = st.target
            st.decoding = True
            st.seq_no = self._seq_no
            self._seq_no += 1
            self.running[slot] = st
            out.append((slot, toks))
        return out

    # ----------------------------------------------------- decode planning
    def schedule_decode(self, spec_k: int = 0) -> list[DecodeStep]:
        """Plan this step's decode work: one :class:`DecodeStep` per
        decoding slot.  With ``spec_k > 0`` the prompt-lookup proposer
        drafts up to ``spec_k`` continuation tokens from the request's
        own token history (never past the remaining generation budget -
        a token beyond it could only be discarded).  The carry token is
        the stream's last generated token, whose KV lands at
        ``seq_lens[slot]`` during the verify step.
        """
        out: list[DecodeStep] = []
        for slot in self.decoding_slots():
            st = self.running[slot]
            stream = st.tokens()
            remaining = st.req.max_new_tokens - len(st.generated)
            n_draft = min(spec_k, max(0, remaining - 1))
            if st.group is not None and st.group.beam:
                # Beam branches take their next token from the reorder
                # (top-2k expansion), not from acceptance against a
                # draft - speculation is auto-disabled inside beam
                # groups.  Parallel-sampling branches keep exact-accept
                # speculation: each branch verifies like an independent
                # seeded request.
                n_draft = 0
            drafts = spec.propose_draft(stream, n_draft) if n_draft else []
            out.append(DecodeStep(slot=slot, tokens=[stream[-1]] + drafts,
                                  drafts=drafts))
        return out

    # ------------------------------------------------------- progression
    def record_token(self, slot: int, tok: int) -> str:
        """Append a generated token; returns "running"|"eos"|"length"."""
        st = self.running[slot]
        self.tokens_emitted += 1
        now = self.clock()
        if st.first_token_time is None:
            st.first_token_time = now
        st.last_token_time = now
        st.generated.append(tok)
        st._stream.append(tok)
        if st.req.eos_id is not None and tok == st.req.eos_id:
            return "eos"
        if len(st.generated) >= st.req.max_new_tokens:
            return "length"
        return "running"

    def choose_victim(self) -> int | None:
        """Preemption victim: the least-urgent latency class first
        (evicting a batch request to keep an interactive decode alive is
        the whole point of the classes), then the sequence with the
        least accumulated work (fewest materialized KV tokens - cheapest
        to replay); newest admission loses ties (FCFS fairness)."""
        if not self.running:
            return None
        return min(self.running,
                   key=lambda s: (-self.running[s].req.latency_class
                                  .priority,
                                  int(self.cache.seq_lens[s]),
                                  -self.running[s].seq_no))

    def preempt(self, slot: int) -> None:
        """Evict a running sequence (page-pool pressure); progress is
        kept as tokens: the resumed prefill replays prompt + generated
        (minus whatever prefix pages are still cached).

        Re-queued at the *front of its class* (a decreasing queue_seq):
        oldest work resumes first, and a preempted sequence never
        starves behind new arrivals of the same class.

        A slot belonging to a sequence group evicts the *whole group*
        (branch streams diverge right after the shared prefill, so no
        single replay prefill could restore them all).
        """
        st = self.running[slot]
        if st.group is not None:
            self.preempt_group(st.group)
            return
        self.running.pop(slot)
        st.preemptions += 1
        st.computed = 0
        st.decoding = False
        self.cache.free_slot(slot)
        self._requeue_front(st)

    def _requeue_front(self, st: _Running) -> None:
        st.queue_seq = self._queue_seq_front
        self._queue_seq_front -= 1
        # Preempted work outranks every fresh arrival of its class, no
        # matter which tenant it belongs to (it already held pages).
        st.fair_round = -1
        self.waiting.append(st)

    def preempt_group(self, group: SequenceGroup) -> None:
        """Evict every live branch of ``group`` and re-queue the request
        at the front.  All branch progress is dropped: regeneration is
        deterministic (sampling keys are fold_in(seed, branch) x
        position, beam scores are pure functions of the logits), so the
        group re-derives the same completions after re-admission,
        resuming from whatever shared prefix pages survive in the
        cache's LRU."""
        submit_time = None
        for s, st in list(self.running.items()):
            if st.group is group:           # branches + mid-prefill parent
                self.running.pop(s)
                self.cache.free_slot(s)
                submit_time = st.submit_time if submit_time is None \
                    else min(submit_time, st.submit_time)
        group.slots.clear()
        group.finished.clear()
        group.fanned_out = False
        group.prefix_pages = ()
        group.prompt_lps = []
        group.next_branch = 0
        group.preemptions += 1
        nst = _Running(group.req, [], group=group)
        nst.submit_time = submit_time if submit_time is not None \
            else self.clock()
        nst.last_token_time = nst.submit_time
        self._requeue_front(nst)

    def retire(self, slot: int, reason: str) -> FinishedRequest:
        st = self.running.pop(slot)
        self.cache.free_slot(slot)
        ttft = None
        if st.first_token_time is not None:
            ttft = st.first_token_time - st.submit_time
        lp = st.req.logprobs
        rate = st.accepted / st.drafted if st.drafted else None
        return FinishedRequest(rid=st.req.rid, prompt=st.req.prompt,
                               tokens=st.generated, reason=reason,
                               preemptions=st.preemptions, ttft=ttft,
                               prompt_logprobs=st.prompt_lps if lp else None,
                               token_logprobs=list(st.token_logprobs)
                               if lp else None,
                               accept_rate=rate)

    def finish(self, slot: int, reason: str) -> FinishedRequest | None:
        """Group-aware retirement: a plain sequence retires immediately;
        a group branch records its completion, and the group's single
        FinishedRequest is emitted only when the whole group is done."""
        st = self.running[slot]
        if st.group is None:
            return self.retire(slot, reason)
        group = st.group
        self._retire_branch(slot, reason)
        return self._maybe_retire_group(group)

    # ----------------------------------------------- SLA / cancellation
    def sla_headroom(self, now: float | None = None) -> float | None:
        """Seconds until the most-urgent decoding slot blows its TPOT
        target: min over decoding slots of
        ``last_token_time + tpot_target - now``.  None when nothing is
        decoding (no deadline to protect).  Negative = already late."""
        if now is None:
            now = self.clock()
        deadlines = [st.last_token_time + st.req.latency_class.tpot_target
                     for st in self.running.values() if st.decoding]
        if not deadlines:
            return None
        return min(deadlines) - now

    def adaptive_prefill_budget(self, prefill_rate: float, floor: int,
                                ceiling: int,
                                now: float | None = None) -> int:
        """Per-step chunked-prefill token budget from the decode batch's
        SLA headroom: roughly the prompt tokens the engine can process
        (at the measured ``prefill_rate`` tokens/sec) before the tightest
        decoding slot's next-token deadline.  Clamped to
        [``floor``, ``ceiling``]: the floor keeps prefill from starving
        outright when decodes are already late, the ceiling bounds a
        step's latency when nothing is decoding (full ceiling)."""
        assert 1 <= floor <= ceiling
        headroom = self.sla_headroom(now)
        if headroom is None:
            return ceiling
        budget = int(max(0.0, headroom) * max(prefill_rate, 0.0))
        return max(floor, min(ceiling, budget))

    def cancel(self, rid: int) -> bool:
        """Remove request ``rid`` wherever it is - waiting, mid-prefill,
        mid-decode, or a fanned-out sequence group - freeing every slot
        it holds refcount-clean.  Returns True if anything was removed.

        The engine must flush pending COW copies *before* calling this
        (a queued device copy targeting a freed-and-reallocated page
        would clobber the new owner's KV)."""
        hit = False
        for st in [w for w in self.waiting if w.req.rid == rid]:
            self.waiting.remove(st)
            hit = True
        group = None
        for s, st in list(self.running.items()):
            if st.req.rid == rid:
                self.running.pop(s)
                self.cache.free_slot(s)
                group = st.group or group
                hit = True
        if group is not None:
            group.slots.clear()
            group.finished.clear()
        return hit

    # ------------------------------------------------- sequence groups
    def fan_out(self, slot: int) -> list[tuple[int, int]]:
        """Fan a freshly-prefilled parallel-sampling group parent out
        into its ``width`` branches: the parent becomes branch 0 and
        each extra branch forks the parent's slot (COW - one page-table
        row + refcount bumps, zero KV copied).  Must be called right
        after the final prefill chunk completes, *before* any first
        token is recorded: at that instant the slot's pages hold
        exactly the prompt KV, so every branch shares all of it.
        Returns [(slot, branch)] for all width branches, parent first.
        """
        st = self.running[slot]
        group = st.group
        assert group is not None and not group.beam
        assert not group.fanned_out
        assert st.decoding and st.computed == st.target, \
            "fan_out before prefill completed"
        self._record_prefix_pages(group, slot)
        st.branch = 0
        group.slots = {slot}
        out = [(slot, 0)]
        for b in range(1, group.width):
            ns = self.cache.fork(slot)
            self.forks += 1
            bst = _Running(st.req, [], seq_no=self._seq_no,
                           computed=st.computed, decoding=True,
                           group=group, branch=b)
            self._seq_no += 1
            self.running[ns] = bst
            group.slots.add(ns)
            out.append((ns, b))
        group.fanned_out = True
        group.next_branch = group.width
        return out

    def fan_out_beam(self, slot: int,
                     candidates: list[tuple[int, float]]) \
            -> FinishedRequest | None:
        """First beam expansion, from the prompt's last-position logits:
        ``candidates`` is the top-2*width (token, logprob) list, sorted
        by logprob descending.  Selects up to ``width`` continuations
        (eos candidates finish as 1-token hypotheses and take no slot);
        the best continuation keeps the parent's slot, the rest fork it.
        Returns the group's FinishedRequest if it already converged
        (e.g. beam_width 1 and the top token is eos).
        """
        st = self.running[slot]
        group = st.group
        assert group is not None and group.beam and not group.fanned_out
        assert st.decoding and st.computed == st.target
        self._record_prefix_pages(group, slot)
        group.fanned_out = True
        # Branch id 0 is reserved for the continuation that keeps the
        # parent slot (_beam_place hands it st.branch == 0); eos
        # hypotheses and forked children draw fresh ids from 1 up so
        # completions never collide on branch id.
        group.next_branch = 1
        live, fin = self._beam_select(
            group, [(lp, 0, tok, slot) for tok, lp in candidates],
            st.req.eos_id)
        for cum, _, tok, _ in fin:
            self.tokens_emitted += 1
            group.finished.append(Completion(
                [tok], group.next_branch, "eos", group.score(cum, 1),
                token_logprobs=[cum] if st.req.logprobs else None))
            group.next_branch += 1
        group.slots = {slot}
        if not live:
            self.drop_branch(slot)
        else:
            self._beam_place(group, {slot: st}, live)
        return self._maybe_retire_group(group)

    def beam_reorder(self, group: SequenceGroup,
                     per_slot: dict[int, list[tuple[int, float]]]) \
            -> FinishedRequest | None:
        """One beam step: every live branch contributes its top-2*width
        (token, logprob) candidates (scored at the branch's last
        committed position); the 2k expansion is ranked by cumulative
        logprob, eos candidates finish as hypotheses, and the top
        ``width`` continuations become the new beams - reordered over
        the slots via fork (a parent keeping several children) and
        free (a childless parent).  Candidate ordering is a pure
        function of (score, branch id, token), never of slot numbers,
        so beam results are invariant to slot permutation.
        Returns the group's FinishedRequest when it converges
        (``width`` finished hypotheses, or no live branch left).
        """
        states = {s: self.running[s] for s in group.slots}
        cands = []
        for s, st in states.items():
            for tok, lp in per_slot[s]:
                cands.append((st.cum_logprob + lp, st.branch, tok, s))
        cands.sort(key=lambda c: (-c[0], c[1], c[2]))
        live, fin = self._beam_select(group, cands, group.req.eos_id)
        for cum, _, tok, s in fin:
            st = states[s]
            self.tokens_emitted += 1
            group.finished.append(Completion(
                st.generated + [tok], group.next_branch, "eos",
                group.score(cum, len(st.generated) + 1),
                token_logprobs=st.token_logprobs + [cum - st.cum_logprob]
                if st.req.logprobs else None))
            group.next_branch += 1
        if len(group.finished) >= group.width:
            live = []
        elif live and self._beam_converged(group, states, live):
            # Early stop: >= n hypotheses are in and no live branch's
            # score upper bound can displace the n-th best - the
            # remaining decode steps cannot change the returned
            # completions, so drop every live branch now.
            self.beam_early_stops += 1
            live = []
        # Reorder: drop childless parents first (frees slots), then fork
        # multi-child parents into them.
        keep = {c[3] for c in live}
        for s in sorted(group.slots - keep):
            self.drop_branch(s)
        self._beam_place(group, states, live)
        return self._maybe_retire_group(group)

    def _beam_converged(self, group, states, live) -> bool:
        """Beam early-stopping test (results provably unchanged): True
        when the group already holds >= ``n`` finished hypotheses and
        the best score any live continuation could *ever* reach is
        strictly below the n-th best finished score.

        Upper bound per live candidate: logprobs are <= 0, so a
        branch's cumulative logprob never increases with length -
        ``score(cum, L) = cum / L**length_penalty`` is therefore
        monotone in L for fixed cum, and its supremum over the
        remaining lengths is at one of the endpoints: the length after
        this token, or the full ``max_new_tokens`` budget.  Strict
        comparison keeps ties alive (a tying branch could still change
        completion ordering), so early-stopped results are identical
        to run-to-exhaustion results, which the regression test pins.
        """
        req = group.req
        if not req.beam_early_stop or len(group.finished) < req.n:
            return False
        nth_best = sorted(
            (c.score for c in group.finished), reverse=True)[req.n - 1]
        for cum, _, tok, s in live:
            length = len(states[s].generated) + 1
            bound = max(group.score(cum, length),
                        group.score(cum, req.max_new_tokens))
            if bound >= nth_best:
                return False
        return True

    def _beam_select(self, group, cands, eos_id):
        """Split ranked candidates into up-to-width continuations and
        newly finished (eos) hypotheses."""
        live, fin = [], []
        for cand in cands:
            if eos_id is not None and cand[2] == eos_id:
                if len(group.finished) + len(fin) < group.width:
                    fin.append(cand)
            elif len(live) < group.width:
                live.append(cand)
        return live, fin

    def _beam_place(self, group, states, live):
        """Materialize the selected continuations: per parent (in global
        candidate order), the first child continues in the parent's
        slot and keeps its branch id; every further child forks the
        parent *before* its token is recorded (the carry token's KV is
        already committed, the new token's is not - so the fork shares
        the full stream so far) and takes a fresh branch id."""
        by_parent: dict[int, list[tuple[float, int, int]]] = {}
        for cum, _, tok, s in live:
            bid = states[s].branch if s not in by_parent \
                else group.next_branch
            if s in by_parent:
                group.next_branch += 1
            by_parent.setdefault(s, []).append((cum, bid, tok))
        for s, children in sorted(by_parent.items()):
            st = states[s]
            base_gen = list(st.generated)
            base_lps = list(st.token_logprobs)
            want_lp = st.req.logprobs
            for cum, bid, tok in children[1:]:
                ns = self.cache.fork(s)
                self.forks += 1
                self.tokens_emitted += 1
                nst = _Running(st.req, base_gen + [tok],
                               seq_no=self._seq_no, computed=st.computed,
                               decoding=True, group=group, branch=bid,
                               cum_logprob=cum)
                if want_lp:
                    # The step's logprob is the candidate's cumulative
                    # minus the shared parent's (st.cum_logprob is
                    # still the pre-step value here).
                    nst.token_logprobs = base_lps + [cum - st.cum_logprob]
                self._seq_no += 1
                self.running[ns] = nst
                group.slots.add(ns)
                if len(nst.generated) >= st.req.max_new_tokens:
                    self._retire_branch(ns, "length")
            cum, bid, tok = children[0]
            if want_lp:
                st.token_logprobs.append(cum - st.cum_logprob)
            st.cum_logprob = cum
            status = self.record_token(s, tok)
            if status != "running":
                self._retire_branch(s, status)

    def _record_prefix_pages(self, group, slot):
        plen = len(group.req.prompt)
        group.prefix_pages = self.cache.slot_pages(slot)[
            :plen // self.cache.page_size]
        group.prompt_lps = self.running[slot].prompt_lps

    def _retire_branch(self, slot: int, reason: str) -> None:
        """Free a finished branch's slot and record its completion."""
        st = self.running.pop(slot)
        group = st.group
        group.slots.discard(slot)
        self.cache.free_slot(slot)
        group.finished.append(Completion(
            list(st.generated), st.branch, reason,
            group.score(st.cum_logprob, len(st.generated)),
            token_logprobs=list(st.token_logprobs)
            if st.req.logprobs else None))

    def drop_branch(self, slot: int) -> None:
        """Free a branch that yields no completion (beam reorder left it
        childless, or the group retired with surplus live branches)."""
        st = self.running.pop(slot)
        st.group.slots.discard(slot)
        self.cache.free_slot(slot)

    def _maybe_retire_group(self, group: SequenceGroup) \
            -> FinishedRequest | None:
        """Emit the group's FinishedRequest once it is done: every
        branch finished (parallel sampling), or - beam - ``width``
        hypotheses collected / no live branch left."""
        done = group.fanned_out and (
            not group.slots
            or (group.beam and len(group.finished) >= group.width))
        if not done:
            return None
        for s in sorted(group.slots):       # beam early stop: surplus
            self.drop_branch(s)
        comps = sorted(group.finished,
                       key=(lambda c: (-c.score, c.branch)) if group.ranked
                       else (lambda c: c.branch))
        comps = comps[:group.req.n]
        lp = group.req.logprobs
        return FinishedRequest(
            rid=group.req.rid, prompt=group.req.prompt,
            tokens=comps[0].tokens, reason=comps[0].reason,
            preemptions=group.preemptions, completions=comps,
            prompt_logprobs=group.prompt_lps if lp else None,
            token_logprobs=comps[0].token_logprobs if lp else None)
