"""Prefix-aware multi-replica router over N serving replicas.

Two layers, split so the placement policy is testable without an event
loop:

  * :class:`RouterCore` - pure bookkeeping.  Each replica is
    represented by its chain-hash table (anything supporting ``in``;
    the live system passes the replica cache's ``_hash_page`` dict, the
    property suite passes plain sets).  Placement routes a request to
    the live replica holding the *longest* chain-hash prefix of its
    prompt; with no prefix hit anywhere it falls back to the
    least-loaded live replica (ties to the lowest index).  Events:
    ``place`` / ``finish`` / ``down`` / ``up``; ``down`` returns the
    in-flight rids that must be re-placed.  Invariants (no request
    lost or double-placed, prefix-hit placement whenever a matching
    replica is live, least-loaded fallback) are pinned by
    tests/test_router_prop.py.

  * :class:`Router` - the asyncio front door: wraps N
    :class:`AsyncFrontend` replicas and duck-types the slice of the
    frontend surface the HTTP transport consumes (``engine``,
    ``failed``/``closed``, ``submit``/``result``/``queue_depth``/
    ``drain``/``close``), so ``serve_http --replicas N`` plugs it into
    the unmodified :class:`repro.serving.http.HttpServer`.  A replica
    whose frontend fails is marked down and its future traffic
    re-routes; submission races a failure by retrying on the next live
    replica.

Prefix hits compose with disaggregated serving (:mod:`.disagg`): a
handoff publishes the prompt's pages into the decode worker's
chain-hash table, which is exactly the table the router consults - so
follow-up requests with the same system prompt land on the replica
that already holds its KV.
"""
from __future__ import annotations

from repro.serving.frontend import AsyncFrontend
from repro.serving.scheduler import Request


class RouterCore:
    """Pure placement logic over per-replica chain-hash tables."""

    def __init__(self, tables):
        self.tables = list(tables)
        self.n = len(self.tables)
        if self.n < 1:
            raise ValueError("router needs at least one replica")
        self.live: set[int] = set(range(self.n))
        self.load = [0] * self.n              # in-flight per replica
        self.placement: dict[int, int] = {}   # rid -> replica

    def prefix_hits(self, replica: int, hashes: list[int]) -> int:
        """Leading chain hashes of ``hashes`` present in the replica's
        table - the pages its admission path would claim."""
        k = 0
        for h in hashes:
            if h not in self.tables[replica]:
                break
            k += 1
        return k

    def place(self, rid: int, hashes: list[int]) -> int:
        """Choose a live replica for ``rid``: longest prefix hit first,
        then least loaded, then lowest index."""
        if rid in self.placement:
            raise ValueError(f"rid {rid} already placed")
        if not self.live:
            raise RuntimeError("router: no live replica")
        best, best_key = None, None
        for i in sorted(self.live):
            key = (-self.prefix_hits(i, hashes), self.load[i], i)
            if best_key is None or key < best_key:
                best, best_key = i, key
        self.placement[rid] = best
        self.load[best] += 1
        return best

    def finish(self, rid: int) -> int:
        """A placed request finished (or was cancelled): drop it."""
        replica = self.placement.pop(rid)
        if replica in self.live:
            self.load[replica] -= 1
        return replica

    def down(self, replica: int) -> list[int]:
        """Replica died: remove it from rotation and return the rids
        that were placed on it (the caller re-places or fails them).
        Idempotent."""
        self.live.discard(replica)
        lost = sorted(rid for rid, r in self.placement.items()
                      if r == replica)
        for rid in lost:
            del self.placement[rid]
        self.load[replica] = 0
        return lost

    def up(self, replica: int) -> None:
        """(Re)join a replica with a fresh load count."""
        if replica not in self.live:
            self.live.add(replica)
            self.load[replica] = sum(
                1 for r in self.placement.values() if r == replica)


class Router:
    """Asyncio front door over N :class:`AsyncFrontend` replicas,
    duck-typing the frontend surface :class:`~repro.serving.http.
    HttpServer` consumes.  Replicas must be homogeneous (same model /
    page size / ceilings): ``engine`` exposes replica 0's for the
    transport's admission-ceiling checks."""

    def __init__(self, frontends: list[AsyncFrontend]):
        if not frontends:
            raise ValueError("router needs at least one frontend")
        self.frontends = list(frontends)
        self.core = RouterCore(
            [fe.engine.cache._hash_page for fe in self.frontends])
        self.stats = {"routed": 0, "prefix_routed": 0,
                      "replicas_down": 0}

    # ------------------------------------------------- frontend surface
    @property
    def engine(self):
        return self.frontends[0].engine

    @property
    def failed(self) -> bool:
        self._refresh_live()
        return not self.core.live and any(
            fe.failed for fe in self.frontends)

    @property
    def closed(self) -> bool:
        return all(fe.closed for fe in self.frontends)

    def _refresh_live(self) -> None:
        for i, fe in enumerate(self.frontends):
            if (fe.failed or fe.closed) and i in self.core.live:
                self.core.down(i)
                self.stats["replicas_down"] += 1

    def _prompt_hashes(self, prompt: list[int]) -> list[int]:
        """Chain hashes of the prompt's *claimable* full pages - the
        same cap admission's ``lookup_prefix`` applies (at least one
        token is always left to compute)."""
        cache = self.engine.cache
        return cache._chain_hashes(list(prompt[:len(prompt) - 1]))

    def submit(self, req: Request):
        """Place ``req`` on a replica and return its token stream.  A
        replica that fails at submission is marked down and the next
        live one tried; RuntimeError when none is left (the transport
        maps it to 503)."""
        self._refresh_live()
        hashes = self._prompt_hashes(req.prompt)
        while True:
            if not self.core.live:
                raise RuntimeError("router: no live replica")
            replica = self.core.place(req.rid, hashes)
            fe = self.frontends[replica]
            try:
                gen = fe.submit(req)
            except RuntimeError:
                self.core.finish(req.rid)
                self.core.down(replica)
                self.stats["replicas_down"] += 1
                continue
            self.stats["routed"] += 1
            if self.core.prefix_hits(replica, hashes):
                self.stats["prefix_routed"] += 1
            return self._wrap(gen, req.rid)

    async def _wrap(self, gen, rid: int):
        try:
            async for tok in gen:
                yield tok
        finally:
            if rid in self.core.placement:
                self.core.finish(rid)

    def result(self, rid: int):
        for fe in self.frontends:
            fr = fe.result(rid)
            if fr is not None:
                return fr
        return None

    def queue_depth(self, cls_name: str) -> int:
        """Admission gating depth: the *least* backlog among live
        replicas (that is where the next request of the class lands
        absent a prefix hit)."""
        self._refresh_live()
        depths = [self.frontends[i].queue_depth(cls_name)
                  for i in sorted(self.core.live)]
        return min(depths) if depths else 0

    async def drain(self) -> None:
        for fe in self.frontends:
            if not (fe.failed or fe.closed):
                await fe.drain()

    async def close(self, drain: bool = True) -> None:
        for fe in self.frontends:
            if not (fe.failed or fe.closed):
                await fe.close(drain)
