"""Dependency-free HTTP/1.1 + SSE transport over :class:`AsyncFrontend`.

The wire layer of the serving stack: a raw-``asyncio`` server (no
aiohttp/fastapi - the container is stdlib-only) that turns the
in-process token streams of :mod:`repro.serving.frontend` into
Server-Sent Events over a real socket.

Endpoints (one request per connection; every response carries
``connection: close``):

  * ``POST /v1/generate`` - JSON body mapped to a
    :class:`repro.serving.scheduler.Request` (prompt, budget, sampling,
    n/best_of/beam, logprobs, latency_class; see
    :func:`request_from_json`).  Response is an SSE stream: one
    ``data: {"index": i, "token": t}`` event per generated token and a
    terminal ``event: done`` whose data is the full FinishedRequest
    payload (tokens, reason, ttft, completions, logprobs).  With
    ``"stream": false`` the terminal payload comes back as one JSON
    response instead.
  * ``GET /healthz`` - 200 while serving, 503 once the frontend failed
    or closed.
  * ``GET /stats`` - engine counters, pool occupancy, per-class queue
    depths and caps, HTTP counters.

Flow control and failure mapping:

  * bounded admission: per-latency-class queue-depth caps; a class at
    its cap answers 429 (with ``retry-after``) without touching
    in-flight streams.  Engine down (frontend failed/closed) answers
    503.
  * multi-tenant fairness: the ``x-tenant`` request header lands in
    ``Request.tenant``; the scheduler round-robins waiting requests of
    the same latency class across tenants (see
    ``Scheduler._waiting_key``).
  * disconnect-driven cancellation: a watcher task reads the socket for
    EOF; a client that goes away mid-stream cancels the request through
    the generator's existing cancel-intent path, so slot and pages come
    back refcount-clean.  A reader that stalls (TCP backpressure) first
    hits the frontend's bounded per-stream queue (cancel-on-overflow),
    then the connection's ``drain_timeout``.
  * client misuse maps to 400 (malformed JSON, unknown fields, bad
    types, contradictory knobs, prompt/width over the engine's
    ceilings); an unroutable path to 404.

The module also ships the matching stdlib client
(:func:`stream_generate`, :func:`http_json`) used by the benchmark's
HTTP open-loop mode, the ``serve_http --smoke`` gate, and the socket
tests.
"""
from __future__ import annotations

import asyncio
import contextlib
import json
import socket

from repro.serving.frontend import AsyncFrontend
from repro.serving.sampler import SamplingParams
from repro.serving.scheduler import (LATENCY_CLASSES, FinishedRequest,
                                     InvalidRequestError, Request)

TENANT_HEADER = "x-tenant"

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            413: "Payload Too Large", 429: "Too Many Requests",
            500: "Internal Server Error", 503: "Service Unavailable"}

_SAMPLING_FIELDS = ("temperature", "top_k", "top_p",
                    "repetition_penalty", "seed")
_REQUEST_FIELDS = frozenset({
    "prompt", "max_new_tokens", "eos_id", "latency_class", "n",
    "best_of", "beam_width", "length_penalty", "beam_early_stop",
    "logprobs", "stream", "id", *_SAMPLING_FIELDS})


class HttpError(Exception):
    """A client-visible HTTP failure (status + JSON error message)."""

    def __init__(self, status: int, message: str):
        super().__init__(message)
        self.status = status
        self.message = message


def request_from_json(spec: dict, *, rid: int, tenant: str = "",
                      engine=None) -> Request:
    """Validate a ``POST /v1/generate`` JSON body into a
    :class:`Request`; raises :class:`HttpError` (400) on misuse.  With
    ``engine``, the engine's resource ceilings (per-sequence token
    allowance, group width vs max_batch, vocab range) are checked at
    the door too - submitting past them would only stream back
    ``reason="rejected"``."""
    if not isinstance(spec, dict):
        raise HttpError(400, "body must be a JSON object")
    unknown = sorted(set(spec) - _REQUEST_FIELDS)
    if unknown:
        raise HttpError(400, f"unknown fields: {unknown}")

    def _int(name, default, lo=None):
        v = spec.get(name, default)
        if isinstance(v, bool) or not isinstance(v, int) or \
                (lo is not None and v < lo):
            bound = f" >= {lo}" if lo is not None else ""
            raise HttpError(400, f"{name} must be an int{bound}")
        return v

    def _num(name, default):
        v = spec.get(name, default)
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            raise HttpError(400, f"{name} must be a number")
        return float(v)

    def _bool(name, default):
        v = spec.get(name, default)
        if not isinstance(v, bool):
            raise HttpError(400, f"{name} must be a boolean")
        return v

    prompt = spec.get("prompt")
    if not isinstance(prompt, list) or not prompt or not all(
            isinstance(t, int) and not isinstance(t, bool) and t >= 0
            for t in prompt):
        raise HttpError(400, "prompt must be a non-empty list of "
                             "non-negative token ids")
    cls_name = spec.get("latency_class", "standard")
    if cls_name not in LATENCY_CLASSES:
        raise HttpError(400, f"unknown latency_class {cls_name!r} "
                             f"(have {sorted(LATENCY_CLASSES)})")
    eos = spec.get("eos_id")
    if eos is not None and (isinstance(eos, bool)
                            or not isinstance(eos, int)):
        raise HttpError(400, "eos_id must be an int or null")
    sampling = None
    if any(f in spec for f in _SAMPLING_FIELDS):
        try:
            sampling = SamplingParams(
                temperature=_num("temperature", 0.0),
                top_k=_int("top_k", 0, lo=0),
                top_p=_num("top_p", 1.0),
                repetition_penalty=_num("repetition_penalty", 1.0),
                seed=_int("seed", 0))
        except AssertionError as e:
            raise HttpError(400, f"bad sampling params: {e}") from e
    best_of = None
    if spec.get("best_of") is not None:
        best_of = _int("best_of", 1, lo=1)
    req = Request(
        rid=rid, prompt=list(prompt),
        max_new_tokens=_int("max_new_tokens", 16, lo=1),
        eos_id=eos, sampling=sampling,
        latency_class=LATENCY_CLASSES[cls_name],
        n=_int("n", 1, lo=1), best_of=best_of,
        beam_width=_int("beam_width", 0, lo=0),
        length_penalty=_num("length_penalty", 1.0),
        logprobs=_bool("logprobs", False),
        beam_early_stop=_bool("beam_early_stop", True),
        tenant=tenant)
    if engine is not None:
        limit = engine.pages_per_seq * engine.page_size
        need = len(req.prompt) + req.max_new_tokens
        if need > limit:
            raise HttpError(400, f"prompt+budget {need} exceeds the "
                                 f"per-sequence ceiling {limit}")
        width = req.beam_width if req.beam_width > 0 else \
            (req.best_of if req.best_of is not None else req.n)
        if width > engine.max_batch:
            raise HttpError(400, f"group width {width} exceeds "
                                 f"max_batch {engine.max_batch}")
        vocab = engine.model.cfg.vocab_size
        if any(t >= vocab for t in req.prompt):
            raise HttpError(400, f"prompt token id out of range "
                                 f"(vocab_size {vocab})")
    return req


def finished_payload(fr: FinishedRequest, tag=None) -> dict:
    """The ``event: done`` data: a JSON-safe FinishedRequest.  ``tag``
    echoes the request's client-chosen ``id`` field."""
    d = {"rid": fr.rid, "tokens": list(fr.tokens), "reason": fr.reason,
         "preemptions": fr.preemptions, "ttft": fr.ttft}
    if tag is not None:
        d["id"] = tag
    if fr.completions is not None:
        d["completions"] = [
            {"tokens": list(c.tokens), "branch": c.branch,
             "reason": c.reason, "score": c.score,
             "token_logprobs": c.token_logprobs}
            for c in fr.completions]
    if fr.prompt_logprobs is not None:
        d["prompt_logprobs"] = fr.prompt_logprobs
    if fr.token_logprobs is not None:
        d["token_logprobs"] = fr.token_logprobs
    return d


class HttpServer:
    """The asyncio HTTP/1.1 + SSE server over one AsyncFrontend.

    ``queue_caps``: per-class admission bound on not-yet-running
    requests - an int applies to every class, a {class: cap} dict
    overrides per class, None defaults to ``4 * engine.max_batch``.
    Depth at/over the cap answers 429 (cap 0 = admit nothing).

    ``drain_timeout``: per-write bound on how long a client may stall
    the socket before the connection is treated as dead.  ``sndbuf``
    (socket send-buffer bytes) and ``event_pad`` (an SSE comment of
    that many bytes after each event - the classic anti-buffering
    padding for proxies) are serving knobs the slow-reader tests also
    lean on to exercise TCP backpressure at test scale.

    The server does not own the frontend: ``stop()`` closes the
    listener and aborts live connections, the caller closes the
    frontend."""

    def __init__(self, frontend: AsyncFrontend, *,
                 host: str = "127.0.0.1", port: int = 0,
                 queue_caps: int | dict | None = None,
                 tenant_header: str = TENANT_HEADER,
                 drain_timeout: float = 30.0,
                 max_body: int = 1 << 20,
                 event_pad: int = 0, sndbuf: int | None = None):
        self.frontend = frontend
        self.host = host
        self.port = port
        self.tenant_header = tenant_header.lower()
        self.drain_timeout = drain_timeout
        self.max_body = max_body
        self.event_pad = event_pad
        self.sndbuf = sndbuf
        self.queue_caps = self._resolve_caps(queue_caps)
        self.http_stats = {"requests": 0, "streams": 0,
                           "rejected_429": 0, "unavailable_503": 0,
                           "bad_request_400": 0, "disconnects": 0,
                           "open_connections": 0}
        self._server: asyncio.AbstractServer | None = None
        self._conns: set[asyncio.Task] = set()
        self._next_rid = 0

    def _resolve_caps(self, caps) -> dict[str, int]:
        default = 4 * self.frontend.engine.max_batch
        if caps is None:
            caps = default
        if isinstance(caps, int):
            return {name: caps for name in LATENCY_CLASSES}
        out = {name: default for name in LATENCY_CLASSES}
        for name, v in caps.items():
            if name not in LATENCY_CLASSES:
                raise ValueError(f"unknown latency class {name!r} "
                                 f"(have {sorted(LATENCY_CLASSES)})")
            out[name] = int(v)
        return out

    # ---------------------------------------------------------- lifecycle
    async def start(self) -> "HttpServer":
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        # Learn the kernel-assigned port when started with port 0.
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def stop(self) -> None:
        """Close the listener and abort live connections (aborted
        streams cancel their requests through the generator cleanup)."""
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        for task in list(self._conns):
            task.cancel()
        for task in list(self._conns):
            with contextlib.suppress(BaseException):
                await task
        self._conns.clear()

    # --------------------------------------------------------- connection
    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        task = asyncio.current_task()
        self._conns.add(task)
        self.http_stats["open_connections"] += 1
        if self.sndbuf:
            sock = writer.get_extra_info("socket")
            if sock is not None:
                sock.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF,
                                self.sndbuf)
            # Keep asyncio's own write buffer out of the picture so
            # drain() reflects what the kernel (and the client) accept.
            writer.transport.set_write_buffer_limits(high=0)
        try:
            parsed = await self._read_request(reader)
            if parsed is None:
                return
            method, path, headers, body = parsed
            self.http_stats["requests"] += 1
            if method == "GET" and path == "/healthz":
                status, payload = self._healthz()
                await self._respond_json(writer, status, payload)
            elif method == "GET" and path == "/stats":
                await self._respond_json(writer, 200,
                                         self._stats_payload())
            elif method == "POST" and path == "/v1/generate":
                await self._generate(reader, writer, headers, body)
            else:
                await self._respond_json(
                    writer, 404, {"error": f"no route {method} {path}"})
        except asyncio.CancelledError:
            raise
        except HttpError as e:
            self.http_stats["bad_request_400"] += 1
            with contextlib.suppress(Exception):
                await self._respond_json(writer, e.status,
                                         {"error": e.message})
        except (ConnectionResetError, BrokenPipeError,
                asyncio.IncompleteReadError):
            self.http_stats["disconnects"] += 1
        except Exception as e:   # noqa: BLE001 - keep the server alive
            with contextlib.suppress(Exception):
                await self._respond_json(writer, 500, {"error": repr(e)})
        finally:
            self.http_stats["open_connections"] -= 1
            self._conns.discard(task)
            writer.close()
            with contextlib.suppress(Exception):
                await writer.wait_closed()

    async def _read_request(self, reader):
        """(method, path, headers, body) | None on immediate EOF."""
        try:
            line = await reader.readline()
        except (ConnectionResetError, ValueError):
            return None
        if not line.strip():
            return None
        parts = line.decode("latin-1").split()
        if len(parts) != 3 or not parts[2].startswith("HTTP/1."):
            raise HttpError(400, "malformed request line")
        method, path = parts[0].upper(), parts[1]
        headers = {}
        while True:
            line = await reader.readline()
            if line in (b"\r\n", b"\n", b""):
                break
            name, _, value = line.decode("latin-1").partition(":")
            headers[name.strip().lower()] = value.strip()
        length = int(headers.get("content-length", "0") or 0)
        if length > self.max_body:
            raise HttpError(413, f"body over {self.max_body} bytes")
        body = await reader.readexactly(length) if length else b""
        return method, path, headers, body

    # ------------------------------------------------------------- routes
    def _healthz(self) -> tuple[int, dict]:
        fe = self.frontend
        if fe.failed:
            return 503, {"status": "failed"}
        if fe.closed:
            return 503, {"status": "closed"}
        return 200, {"status": "ok", "steps": fe.engine.stats["steps"]}

    def _stats_payload(self) -> dict:
        fe = self.frontend
        eng = fe.engine
        return {"engine": dict(eng.stats),
                "pool": {"num_pages": eng.cache.num_pages,
                         "free_pages": eng.cache.available_page_count,
                         "free_slots": eng.cache.free_slot_count},
                "queues": {name: fe.queue_depth(name)
                           for name in LATENCY_CLASSES},
                "caps": dict(self.queue_caps),
                "http": dict(self.http_stats)}

    async def _generate(self, reader, writer, headers, body) -> None:
        fe = self.frontend
        if fe.failed or fe.closed:
            self.http_stats["unavailable_503"] += 1
            await self._respond_json(writer, 503,
                                     {"error": "engine unavailable"})
            return
        try:
            spec = json.loads(body.decode("utf-8")) if body else None
        except (UnicodeDecodeError, json.JSONDecodeError) as e:
            raise HttpError(400, f"bad JSON body: {e}") from e
        tenant = headers.get(self.tenant_header, "")
        rid = self._next_rid
        self._next_rid += 1
        req = request_from_json(spec, rid=rid, tenant=tenant,
                                engine=fe.engine)
        cls = req.latency_class.name
        cap = self.queue_caps.get(cls)
        if cap is not None and fe.queue_depth(cls) >= cap:
            self.http_stats["rejected_429"] += 1
            await self._respond_json(
                writer, 429,
                {"error": f"queue full for class {cls!r}",
                 "class": cls, "cap": cap},
                extra=("retry-after: 1",))
            return
        try:
            gen = fe.submit(req)
        except RuntimeError as e:       # failed/closed raced the check
            self.http_stats["unavailable_503"] += 1
            await self._respond_json(writer, 503, {"error": str(e)})
            return
        self.http_stats["streams"] += 1
        eof = asyncio.ensure_future(self._watch_eof(reader))
        pump = asyncio.ensure_future(self._pump(
            gen, writer, rid, spec.get("id"), spec.get("stream", True)))
        try:
            await asyncio.wait({eof, pump},
                               return_when=asyncio.FIRST_COMPLETED)
            if not pump.done():
                # Socket EOF/reset while the stream is live: the
                # cleanup below closes the generator, whose finally
                # files the cancel intent - slot and pages come back
                # refcount-clean on the next drive iteration.
                self.http_stats["disconnects"] += 1
            else:
                pump.result()        # re-raise HttpError / reset
        finally:
            for t in (pump, eof):
                if not t.done():
                    t.cancel()
            with contextlib.suppress(BaseException):
                await pump
            with contextlib.suppress(BaseException):
                await eof
            with contextlib.suppress(BaseException):
                await gen.aclose()

    @staticmethod
    async def _watch_eof(reader) -> None:
        """Resolve when the client half-closes or resets: the client
        sends nothing after the request body, so any read completing
        means the connection is gone."""
        with contextlib.suppress(Exception):
            while await reader.read(4096):
                pass

    async def _pump(self, gen, writer, rid: int, tag, stream: bool):
        """Consume the token generator into SSE frames (or one JSON
        response for ``stream=false``)."""
        toks = []
        started = False
        try:
            async for tok in gen:
                if stream:
                    if not started:
                        self._write_head(writer, 200,
                                         "text/event-stream")
                        started = True
                    self._write_event(writer, None,
                                      {"index": len(toks), "token": tok})
                    await self._drain(writer)
                toks.append(tok)
        except InvalidRequestError as e:
            if started:
                raise            # headers already sent; drop the stream
            raise HttpError(400, str(e)) from e
        fr = self.frontend.result(rid)
        payload = finished_payload(fr, tag) if fr is not None else \
            {"rid": rid, "tokens": toks, "reason": "unknown"}
        if not stream:
            await self._respond_json(writer, 200, payload)
            return
        if not started:
            self._write_head(writer, 200, "text/event-stream")
        self._write_event(writer, "done", payload)
        await self._drain(writer)

    # -------------------------------------------------------- wire format
    def _write_head(self, writer, status: int, ctype: str, *,
                    length: int | None = None, extra=()) -> None:
        lines = [f"HTTP/1.1 {status} {_REASONS.get(status, 'OK')}",
                 f"content-type: {ctype}", "cache-control: no-store",
                 "connection: close"]
        if length is not None:
            lines.append(f"content-length: {length}")
        lines.extend(extra)
        writer.write(("\r\n".join(lines) + "\r\n\r\n").encode("latin-1"))

    def _write_event(self, writer, event: str | None, data: dict) -> None:
        buf = []
        if event:
            buf.append(f"event: {event}\n")
        buf.append(f"data: {json.dumps(data)}\n")
        if self.event_pad:
            buf.append(":" + " " * self.event_pad + "\n")
        buf.append("\n")
        writer.write("".join(buf).encode("utf-8"))

    async def _respond_json(self, writer, status: int, payload: dict,
                            extra=()) -> None:
        body = (json.dumps(payload, sort_keys=True) + "\n").encode()
        self._write_head(writer, status, "application/json",
                         length=len(body), extra=extra)
        writer.write(body)
        await self._drain(writer)

    async def _drain(self, writer) -> None:
        try:
            await asyncio.wait_for(writer.drain(), self.drain_timeout)
        except asyncio.TimeoutError:
            raise ConnectionResetError(
                f"client stalled past drain_timeout "
                f"({self.drain_timeout}s)") from None


# ------------------------------------------------------------ client side
async def http_json(host: str, port: int, method: str, path: str,
                    payload: dict | None = None,
                    headers=()) -> tuple[int, dict]:
    """One-shot JSON request; returns (status, decoded body)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = b"" if payload is None else json.dumps(payload).encode()
        lines = [f"{method} {path} HTTP/1.1", f"host: {host}",
                 "connection: close", f"content-length: {len(body)}"]
        if body:
            lines.append("content-type: application/json")
        lines.extend(headers)
        writer.write(("\r\n".join(lines) + "\r\n\r\n").encode() + body)
        await writer.drain()
        status, hdrs = await _read_head(reader)
        raw = await _read_plain_body(reader, hdrs)
        return status, (json.loads(raw) if raw else {})
    finally:
        writer.close()
        with contextlib.suppress(Exception):
            await writer.wait_closed()


async def stream_generate(host: str, port: int, payload: dict, *,
                          tenant: str | None = None):
    """POST /v1/generate and decode the response into an async stream
    of ``("token", {...})`` events followed by one ``("done", {...})``
    - or a single ``("error", {"status": ..., "body": ...})`` for a
    non-2xx answer.  Closing the generator mid-stream closes the
    socket, which the server treats as a client disconnect (the
    request is cancelled, freeing its slot/pages)."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = json.dumps(payload).encode()
        lines = ["POST /v1/generate HTTP/1.1", f"host: {host}",
                 "connection: close", "content-type: application/json",
                 f"content-length: {len(body)}"]
        if tenant:
            lines.append(f"{TENANT_HEADER}: {tenant}")
        writer.write(("\r\n".join(lines) + "\r\n\r\n").encode() + body)
        await writer.drain()
        status, hdrs = await _read_head(reader)
        if status != 200 or "text/event-stream" not in \
                hdrs.get("content-type", ""):
            raw = await _read_plain_body(reader, hdrs)
            data = json.loads(raw) if raw else {}
            if status == 200:
                yield "done", data       # "stream": false JSON answer
            else:
                yield "error", {"status": status, "body": data}
            return
        async for event, data in _read_sse(reader):
            if event == "done":
                yield "done", data
                return
            yield "token", data
    finally:
        writer.close()
        with contextlib.suppress(Exception):
            await writer.wait_closed()


async def _read_head(reader) -> tuple[int, dict]:
    line = await reader.readline()
    parts = line.decode("latin-1").split(None, 2)
    if len(parts) < 2 or not parts[0].startswith("HTTP/"):
        raise ConnectionError(f"malformed status line: {line!r}")
    headers = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        name, _, value = line.decode("latin-1").partition(":")
        headers[name.strip().lower()] = value.strip()
    return int(parts[1]), headers


async def _read_plain_body(reader, headers) -> bytes:
    n = headers.get("content-length")
    if n is not None:
        return await reader.readexactly(int(n))
    return await reader.read()           # connection: close delimits


async def _read_sse(reader):
    """Decode SSE frames into (event_name, json_data) pairs; comment
    (padding) lines are skipped per the spec."""
    event, data = None, []
    while True:
        line = await reader.readline()
        if not line:
            return                       # connection closed
        text = line.rstrip(b"\r\n").decode("utf-8")
        if not text:
            if data:
                yield (event or "message"), json.loads("\n".join(data))
            event, data = None, []
            continue
        if text.startswith(":"):
            continue
        name, _, value = text.partition(":")
        if value.startswith(" "):
            value = value[1:]
        if name == "event":
            event = value
        elif name == "data":
            data.append(value)
