"""Host-side block-pool manager for the paged KV cache.

Pure numpy/python bookkeeping: which pages belong to which slot, what
each slot's current length is, and the ``(max_batch, pages_per_seq)``
page table the device kernels consume.  The actual KV pools are jax
arrays owned by the engine (``LM.init_paged_cache``); this class never
touches them - it only hands the engine a list of pending page copies
(copy-on-write) to apply before the next device write.

Sharing model (vLLM-style prefix caching + COW):

  * Every page carries a refcount = number of slot page tables that
    reference it.  ``fork`` clones a slot by bumping refcounts instead
    of copying KV; a write into a shared page triggers copy-on-write
    (fresh page + a pending device copy + table swap).
  * Full pages whose token content is known are registered in a
    chain-hash table (hash of (parent_hash, page_tokens)), so a new
    prompt can claim the longest already-materialized prefix and skip
    recomputing it.
  * When the last reference to a *registered* page is dropped the page
    is parked in a cached-LRU pool instead of being scrubbed: it is
    still claimable by a later prompt with the same prefix, and it is
    evicted (hash entries dropped) only when the allocator runs out of
    strictly-free pages.
  * Admission reserves room for one decode append beyond the prompt
    (``can_admit`` checks ``pages_for(n + 1)``): a prompt that exactly
    fills its pages would otherwise prefill, fail to append, and be
    preempted into a full replay - a quadratic livelock under a tight
    pool.

Rollback x refcount sharp edge (speculative decode)
---------------------------------------------------

The engine's verify step commits KV for *all* K+1 speculative columns
before acceptance is known (``mark_prefilled(sl + c)`` followed by
``rollback(sl + used)``), which puts four load-bearing constraints on
this class - they are asserted/honoured in :meth:`rollback` and
:meth:`_cow`, and violating any of them corrupts shared state silently:

1. **Rollback drops only this slot's references.**  A fork taken
   mid-step keeps reading the old tail page; ``rollback`` must go
   through :meth:`_drop_ref` (never the free list directly), so a page
   another slot still references survives, and a published
   last-reference page parks in the cached LRU exactly as on
   :meth:`free_slot`.
2. **Rollback must re-trim the slot's hash chain.**  The chain caches
   "pages already examined" per slot; if a rejected draft rolled
   ``seq_lens`` back across a page boundary, a later
   :meth:`register_pages` would otherwise *skip re-hashing* a page
   whose content has since been overwritten - publishing a stale hash
   that a future prompt could claim.  Hence ``del chain[n_tokens //
   page_size:]``.
3. **A COW performed for a column that is then rejected is kept.**  The
   copy is wasted work, never a correctness issue: the new page is
   exclusively owned, unpublished, and the next append simply
   overwrites it.  Undoing the copy would require re-taking the shared
   page reference *after* the fork may have diverged - strictly worse.
4. **Junk KV from rejected columns stays inside kept pages** at
   positions ``>= seq_lens``.  That is safe because every mask in the
   stack (decode, chunked prefill, verify, Pallas and jnp paths alike)
   cuts at ``seq_lens``, and the next append overwrites the junk in
   place.  No scrubbing pass exists, by design - do not add one that
   reads ``seq_lens`` concurrently with a pending rollback.

5. **A fork taken inside the commit/rollback window must be truncated.**
   Between ``mark_prefilled(sl + c)`` and ``rollback(sl + used)`` the
   slot's ``seq_lens`` counts rejected columns, so a plain
   :meth:`fork` would inherit junk tokens as real and keep references
   on tail pages about to be rolled back.  ``fork(slot, n_tokens)``
   shares only the pages covering the pre-commit (or accepted) prefix
   and re-trims the fork's hash chain, so refcounts stay conserved
   through the parent's rollback and a later :meth:`register_pages` on
   either slot re-hashes any page whose rolled-over content was
   overwritten.  Sequence-group fan-out (parallel sampling / beam)
   forks exactly this way.

Tensor parallelism note: under ``--tp`` the device pools are
KV-head-sharded, but this class is *oblivious* to it - page tables and
every mechanism above are replicated on the host, and each shard
applies the same table-driven writes to its head slice.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np


class PagedKVCache:
    """Fixed-size page pool + per-slot page tables.

    Lifecycle: alloc (optionally claiming shared prefix pages and
    optionally lazy, for chunked prefill) -> ensure_capacity/advance/
    mark_prefilled as KV is written -> free.  ``check_invariants``
    validates the full refcount/hash/LRU state.
    """

    def __init__(self, num_pages: int, page_size: int, max_batch: int,
                 pages_per_seq: int, max_cached_pages: int | None = None):
        assert num_pages >= 1 and page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_batch = max_batch
        self.pages_per_seq = pages_per_seq
        # Cap on the cached-page LRU (None = uncapped): under
        # long-running multi-tenant churn every retired prefix parks its
        # pages here, and without a bound the *entire* free pool ends up
        # as dead single-use prefixes - each later allocation then pays
        # an LRU eviction + hash retraction instead of a free-list pop,
        # and a cold burst finds no strictly-free pages at all.  Excess
        # entries age out oldest-first at park time.
        if max_cached_pages is not None:
            assert max_cached_pages >= 0, max_cached_pages
        self.max_cached_pages = max_cached_pages
        self.page_table = np.zeros((max_batch, pages_per_seq), np.int32)
        self.seq_lens = np.zeros((max_batch,), np.int32)
        self._free_pages: list[int] = list(range(num_pages - 1, -1, -1))
        self._free_slots: list[int] = list(range(max_batch - 1, -1, -1))
        self._slot_pages: dict[int, list[int]] = {}
        # -- sharing state ------------------------------------------------
        self._refcount = np.zeros((num_pages,), np.int32)
        self._page_hash: dict[int, int] = {}     # page id -> chain hash
        self._hash_page: dict[int, int] = {}     # chain hash -> page id
        self._cached: OrderedDict[int, None] = OrderedDict()  # LRU, ref==0
        self._pending_copies: list[tuple[int, int]] = []      # (src, dst)
        # Per-slot prefix of already-examined chain hashes, so the
        # register_pages calls the engine makes after every chunk / page
        # fill stay O(new pages) instead of rehashing from position 0
        # (quadratic over a long sequence's lifetime).
        self._slot_chain: dict[int, list[int]] = {}
        # -- disaggregated handoff state ----------------------------------
        # Export pins (source side): pages whose contents are being
        # device-copied to another worker's pools.  A pinned page must
        # keep its bytes until the copy lands, so eviction (LRU take,
        # park age-out) skips it and an in-place COW of a refcount-1
        # published page is forced onto the copy path.  Pins are a
        # *content* guard, not table references - the refcount ==
        # table-refs conservation law is untouched.
        self._export_pins = np.zeros((num_pages,), np.int32)
        # Staged pages (destination side): taken out of the pool for an
        # in-flight import but not yet published.  They are neither
        # free, cached nor owned until publish_staged/abort_staged.
        self._staged: set[int] = set()

    # ------------------------------------------------------------ queries
    @property
    def free_page_count(self) -> int:
        """Strictly free pages (no reusable content)."""
        return len(self._free_pages)

    @property
    def available_page_count(self) -> int:
        """Pages the allocator can hand out: free + evictable cached
        (export-pinned cached pages are claimable but not evictable)."""
        return len(self._free_pages) + sum(
            1 for p in self._cached if not self._export_pins[p])

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    @property
    def active_slots(self) -> list[int]:
        return sorted(self._slot_pages)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def refcount(self, page: int) -> int:
        return int(self._refcount[page])

    def token_capacity(self, slot: int) -> int:
        """Tokens the slot's currently-allocated pages can hold."""
        return len(self._slot_pages[slot]) * self.page_size

    def slot_pages(self, slot: int) -> tuple[int, ...]:
        """The slot's page ids, in table order (read-only snapshot)."""
        return tuple(self._slot_pages[slot])

    def writable_token_capacity(self, slot: int) -> int:
        """Tokens the slot can hold without allocating OR copying: the
        allocation capacity truncated at the first *shared* page at or
        after seq_lens.  Writing into a refcount > 1 page needs a
        copy-on-write, so after a failed ``ensure_capacity`` a shrunk
        chunk must stop here, not at :meth:`token_capacity` - otherwise
        it would scatter K/V into a page a forked sibling still reads.
        (A published refcount-1 page does not truncate: COW retracts its
        hash without allocating, which cannot fail.)"""
        pages = self._slot_pages[slot]
        for idx in range(int(self.seq_lens[slot]) // self.page_size,
                         len(pages)):
            if self._refcount[pages[idx]] > 1:
                return idx * self.page_size
        return len(pages) * self.page_size

    def can_admit(self, n_tokens: int, shared: tuple[int, ...] = ()) -> bool:
        """True if a ``n_tokens`` sequence (with ``len(shared)`` leading
        prefix pages already materialized) can be admitted.

        Reserves one decode-append slot past the prompt: the first
        generated token must have somewhere to land, otherwise admission
        guarantees an immediate preemption (full-replay livelock on a
        tight pool).
        """
        need_total = self.pages_for(n_tokens + 1)
        need_new = need_total - len(shared)
        shared_cached = sum(1 for p in shared if p in self._cached
                            and not self._export_pins[p])
        avail = self.available_page_count - shared_cached
        return bool(self._free_slots and need_total <= self.pages_per_seq
                    and need_new <= avail)

    # ------------------------------------------------------- prefix cache
    def _chain_hashes(self, tokens: list[int]) -> list[int]:
        """Chain hash per full page of ``tokens`` (page i covers tokens
        [i*page, (i+1)*page)); h_i = hash((h_{i-1}, page_tokens))."""
        out = []
        h = 0
        for i in range(len(tokens) // self.page_size):
            h = hash((h, tuple(
                tokens[i * self.page_size:(i + 1) * self.page_size])))
            out.append(h)
        return out

    def lookup_prefix(self, tokens: list[int]) -> tuple[int, ...]:
        """Longest already-materialized prefix of ``tokens``, as page ids.

        Only full pages are shared, and at least one token is always
        left to compute (its logits produce the next token), so the
        match is capped at ``(len(tokens) - 1) // page_size`` pages.
        """
        out = []
        for h in self._chain_hashes(tokens[:len(tokens) - 1]):
            page = self._hash_page.get(h)
            if page is None:
                break
            out.append(page)
        return tuple(out)

    def register_pages(self, slot: int, tokens: list[int]) -> int:
        """Publish ``slot``'s full, already-written pages to the prefix
        table.  ``tokens`` is the slot's token stream; only pages fully
        covered by both ``tokens`` and ``seq_lens[slot]`` (KV actually
        on device) are eligible.  Each page is examined once per slot
        lifetime (the hash chain is cached and only extends); returns
        #pages registered.
        """
        pages = self._slot_pages[slot]
        chain = self._slot_chain.setdefault(slot, [])
        n_full = min(len(tokens), int(self.seq_lens[slot])) \
            // self.page_size
        registered = 0
        h = chain[-1] if chain else 0
        for i in range(len(chain), n_full):
            h = hash((h, tuple(
                tokens[i * self.page_size:(i + 1) * self.page_size])))
            chain.append(h)
            page = pages[i]
            if page in self._page_hash:
                continue          # already published (or claimed shared)
            if h in self._hash_page:
                continue          # identical content already canonical
            self._page_hash[page] = h
            self._hash_page[h] = page
            registered += 1
        return registered

    def _unregister(self, page: int) -> None:
        h = self._page_hash.pop(page, None)
        if h is not None:
            self._hash_page.pop(h, None)

    # ----------------------------------------------------------- allocator
    def _take_page(self) -> int:
        """Pop a strictly-free page, else evict the LRU (unpinned)
        cached page."""
        if self._free_pages:
            return self._free_pages.pop()
        for page in self._cached:                    # LRU order
            if not self._export_pins[page]:
                del self._cached[page]
                self._unregister(page)
                return page
        raise RuntimeError("page pool exhausted")

    def _park(self, page: int) -> None:
        """Drop a published page whose last reference just fell: park it
        in the cached LRU (still claimable by an identical prefix),
        aging out the oldest unpinned entries beyond
        ``max_cached_pages``."""
        self._cached[page] = None                    # most-recently used
        if self.max_cached_pages is not None:
            over = len(self._cached) - self.max_cached_pages
            if over > 0:
                aged = [p for p in self._cached
                        if not self._export_pins[p]][:over]
                for old in aged:
                    del self._cached[old]
                    self._unregister(old)
                    self._free_pages.append(old)

    def _claim(self, page: int) -> None:
        """Take one reference on a shared/cached page."""
        if self._refcount[page] == 0:
            assert page in self._cached, f"claim of free page {page}"
            del self._cached[page]
        self._refcount[page] += 1

    def take_pending_copies(self) -> list[tuple[int, int]]:
        """Drain (src, dst) page copies the engine must apply to the
        device pools before the next write."""
        out, self._pending_copies = self._pending_copies, []
        return out

    # ---------------------------------------------------------- lifecycle
    def alloc_slot(self, n_tokens: int, shared: tuple[int, ...] = (),
                   lazy: bool = False) -> int:
        """Claim a slot for an ``n_tokens`` sequence.

        ``shared`` are prefix pages (from :meth:`lookup_prefix`) claimed
        by reference - their KV is already on device, so ``seq_lens``
        starts at ``len(shared) * page_size``.  With ``lazy=False`` the
        remaining pages for all ``n_tokens`` are allocated up front and
        ``seq_lens`` is set to ``n_tokens`` (the caller prefills them in
        one shot).  With ``lazy=True`` (chunked prefill) no fresh pages
        are allocated yet; :meth:`ensure_capacity` grows the slot chunk
        by chunk and :meth:`mark_prefilled` advances ``seq_lens``.
        """
        if n_tokens < 1:
            # seq_lens == 0 is the stack-wide "free slot" sentinel; an
            # active slot must own at least one token.
            raise ValueError(f"n_tokens must be >= 1, got {n_tokens}")
        if not self.can_admit(n_tokens, shared):
            raise RuntimeError(
                f"cannot admit sequence of {n_tokens} tokens "
                f"(free slots {self.free_slot_count}, "
                f"available pages {self.available_page_count}, "
                f"shared {len(shared)})")
        assert len(shared) * self.page_size < n_tokens, \
            "shared prefix must leave at least one token to compute"
        assert lazy or not shared, \
            "eager alloc would overwrite the shared prefix pages"
        slot = self._free_slots.pop()
        pages = []
        for p in shared:
            self._claim(p)
            pages.append(p)
        if not lazy:
            while len(pages) < self.pages_for(n_tokens):
                page = self._take_page()
                self._refcount[page] = 1
                pages.append(page)
        self._slot_pages[slot] = pages
        # Seed the hash chain with the claimed prefix (all registered),
        # so later register_pages calls only hash new pages.
        self._slot_chain[slot] = [self._page_hash[p] for p in shared]
        self.page_table[slot] = 0
        self.page_table[slot, :len(pages)] = pages
        self.seq_lens[slot] = (len(shared) * self.page_size if lazy
                               else n_tokens)
        return slot

    def fork(self, slot: int, n_tokens: int | None = None) -> int:
        """Clone ``slot`` into a fresh slot sharing every page (beam /
        parallel-sampling style).  No KV is copied; the first divergent
        append into a shared page triggers copy-on-write.

        ``n_tokens`` truncates the fork: it shares only the pages
        covering the first ``n_tokens`` of the parent and starts with
        ``seq_lens == n_tokens``.  This is what makes a fork taken
        inside the speculative-verify window safe (constraint 5 of the
        rollback x refcount contract above): between the engine's
        ``mark_prefilled(sl + c)`` and ``rollback(sl + used)`` the
        parent's ``seq_lens`` over-counts by the rejected columns, so a
        fork intended to share only the *accepted* prefix must be taken
        with ``n_tokens = sl + used``.  The truncated fork takes no
        reference on pages past ``pages_for(n_tokens)`` (they may be
        rolled back and freed under it), and its hash chain is
        re-trimmed to the full pages below ``n_tokens`` so a later
        :meth:`register_pages` re-hashes any page whose rolled-over
        content has since been overwritten.
        """
        if not self._free_slots:
            raise RuntimeError("no free slot to fork into")
        if n_tokens is None:
            n_tokens = int(self.seq_lens[slot])
        assert 1 <= n_tokens <= int(self.seq_lens[slot]), \
            (n_tokens, int(self.seq_lens[slot]))
        pages = self._slot_pages[slot][:self.pages_for(n_tokens)]
        new = self._free_slots.pop()
        for p in pages:
            self._refcount[p] += 1
        self._slot_pages[new] = list(pages)
        chain = self._slot_chain.get(slot, [])
        self._slot_chain[new] = chain[:n_tokens // self.page_size]
        self.page_table[new] = 0
        self.page_table[new, :len(pages)] = pages
        self.seq_lens[new] = n_tokens
        return new

    def _cow(self, slot: int, idx: int) -> bool:
        """Make page ``idx`` of ``slot`` exclusively owned (copy-on-write).
        Returns False when no page can be allocated for the copy."""
        pages = self._slot_pages[slot]
        old = pages[idx]
        pinned = bool(self._export_pins[old])
        if self._refcount[old] == 1 and not pinned:
            if old not in self._page_hash:
                return True
            # Sole owner but published: writes would corrupt the cached
            # prefix other requests may claim, so retract it instead of
            # copying (content diverges from the registered hash).
            self._unregister(old)
            return True
        try:
            new = self._take_page()
        except RuntimeError:
            return False
        self._refcount[new] = 1
        self._pending_copies.append((old, new))
        pages[idx] = new
        self.page_table[slot, idx] = new
        if pinned and self._refcount[old] == 1:
            # Export-pinned sole owner: the bytes must survive until the
            # cross-worker copy lands, so even the refcount-1 case goes
            # through a real copy and the original parks/frees via the
            # normal last-reference path (still pinned, never evicted).
            self._drop_ref(old)
        else:
            self._refcount[old] -= 1
        return True

    def ensure_capacity(self, slot: int, n_tokens: int) -> bool:
        """Back ``slot`` with writable pages for ``n_tokens`` total
        tokens.  Positions in ``[seq_lens, n_tokens)`` are about to be
        written, so any shared (or published) page in that range is
        copy-on-write'd and missing tail pages are allocated.

        Allocates as much as it can before giving up: on False the slot
        keeps whatever pages it gained (``token_capacity`` tells the
        caller how far a shrunk chunk can still go).
        """
        pages = self._slot_pages[slot]
        need = self.pages_for(n_tokens)
        if need > self.pages_per_seq:
            return False
        # COW any existing page the write range touches (only the page
        # holding seq_lens can be shared mid-page - full shared prefix
        # pages sit strictly below seq_lens).
        first_write = int(self.seq_lens[slot]) // self.page_size
        for idx in range(first_write, min(need, len(pages))):
            if not self._cow(slot, idx):
                return False
        while len(pages) < need:
            try:
                page = self._take_page()
            except RuntimeError:
                return False
            self._refcount[page] = 1
            pages.append(page)
            self.page_table[slot, len(pages) - 1] = page
        return True

    def ensure_append_capacity(self, slot: int) -> bool:
        """Make room for one more token in ``slot`` (decode append).

        The next token lands at position seq_lens[slot]; if that crosses
        into an unallocated page, grab one, and if it lands in a shared
        page, copy-on-write it.  Returns False (slot keeps its pages)
        when the pool is exhausted or the sequence is at the
        pages_per_seq ceiling - the caller preempts or retires.
        """
        return self.ensure_capacity(slot, int(self.seq_lens[slot]) + 1)

    def advance(self, slot: int) -> None:
        """Record that one token's KV was appended to ``slot``."""
        assert self.pages_for(int(self.seq_lens[slot]) + 1) <= len(
            self._slot_pages[slot]), "advance() without capacity"
        self.seq_lens[slot] += 1

    def mark_prefilled(self, slot: int, n_tokens: int) -> None:
        """Record that KV for positions [seq_lens, n_tokens) was written
        (one chunked-prefill step)."""
        assert n_tokens >= int(self.seq_lens[slot])
        assert n_tokens == int(self.seq_lens[slot]) or \
            n_tokens <= self.writable_token_capacity(slot), \
            "mark_prefilled() into an unallocated or still-shared page"
        self.seq_lens[slot] = n_tokens

    def free_slot(self, slot: int) -> None:
        """Retire a slot: drop its page references, zero its table row.

        A page whose last reference drops is recycled - into the cached
        LRU when it is a published prefix page (claimable by a later
        identical prompt), onto the free list otherwise.
        """
        pages = self._slot_pages.pop(slot)
        self._slot_chain.pop(slot, None)
        for p in pages:
            self._drop_ref(p)
        self._free_slots.append(slot)
        self.page_table[slot] = 0
        self.seq_lens[slot] = 0

    def _drop_ref(self, page: int) -> None:
        self._refcount[page] -= 1
        if self._refcount[page] == 0:
            if page in self._page_hash:
                self._park(page)
            else:
                self._free_pages.append(page)

    def rollback(self, slot: int, n_tokens: int) -> None:
        """Truncate ``slot`` back to ``n_tokens`` (speculative-decode
        rollback): positions past the accepted prefix hold rejected
        drafts' KV, so decrement ``seq_lens`` and drop the slot's
        reference on every page past ``pages_for(n_tokens)``.

        Refcounts are respected: a tail page a fork still reads only
        loses this slot's reference; a published last-reference page
        parks in the cached LRU exactly as on :meth:`free_slot`.  The
        hash chain is re-trimmed so later ``register_pages`` calls
        re-hash any page whose content the rollback invalidated.  The
        junk KV left inside kept pages (positions >= n_tokens) is never
        attended - every mask in the stack cuts at seq_lens - and the
        next append overwrites it in place.
        """
        assert 1 <= n_tokens <= int(self.seq_lens[slot]), \
            (n_tokens, int(self.seq_lens[slot]))
        keep = self.pages_for(n_tokens)
        pages = self._slot_pages[slot]
        while len(pages) > keep:
            p = pages.pop()
            self.page_table[slot, len(pages)] = 0
            self._drop_ref(p)
        chain = self._slot_chain.get(slot)
        if chain is not None:
            del chain[n_tokens // self.page_size:]
        self.seq_lens[slot] = n_tokens

    # ------------------------------------------------- disaggregated handoff
    def export_prefix(self, tokens: list[int]) -> tuple[list[int],
                                                        list[int]]:
        """Source side of a prefill->decode handoff: the longest
        already-materialized run of full pages covering ``tokens``, as
        parallel ``(pages, hashes)`` lists, with every returned page
        *export-pinned*.

        Unlike :meth:`lookup_prefix` the match is NOT capped at
        ``(len - 1) // page_size``: the importer claims through its own
        admission path, which re-applies the one-token-to-compute cap -
        shipping the final full page too lets the decode worker prefill
        only the partial tail.  Pins nest (a page may back several
        in-flight exports) and must be released with
        :meth:`release_export` once the device copy has landed (or the
        handoff is abandoned).  Pinned pages are never evicted, never
        age out of the LRU, and never have their bytes overwritten by an
        in-place COW - the content stays valid for the whole window.
        """
        pages: list[int] = []
        hashes: list[int] = []
        for h in self._chain_hashes(tokens):
            page = self._hash_page.get(h)
            if page is None:
                break
            pages.append(page)
            hashes.append(h)
        for p in pages:
            self._export_pins[p] += 1
        return pages, hashes

    def release_export(self, pages: list[int]) -> None:
        """Drop one export pin from each page (copy landed / abandoned)."""
        for p in pages:
            assert self._export_pins[p] > 0, \
                f"release_export of unpinned page {p}"
            self._export_pins[p] -= 1

    def stage_pages(self, n: int) -> list[int]:
        """Destination side: take ``n`` pages out of the pool for an
        in-flight import.  Staged pages are neither free, cached nor
        owned (refcount 0, unpublished) until :meth:`publish_staged`
        inserts them into the prefix table or :meth:`abort_staged`
        returns them.  Raises RuntimeError when the pool cannot supply
        ``n`` pages (the caller falls back to a plain submit)."""
        if n < 0:
            raise ValueError(f"n must be >= 0, got {n}")
        if n > self.available_page_count:
            raise RuntimeError(
                f"cannot stage {n} pages "
                f"(available {self.available_page_count})")
        out = [self._take_page() for _ in range(n)]
        self._staged.update(out)
        return out

    def publish_staged(self, pages: list[int],
                       hashes: list[int]) -> list[int]:
        """Commit an import: the device copy into ``pages`` has landed
        and ``hashes`` are their chain hashes (from the exporter's
        :meth:`export_prefix`).  Each page is published into the prefix
        table and parked in the cached LRU - claimable by the very next
        admission exactly like a locally-retired prefix.  A hash this
        pool already holds keeps its canonical page; the duplicate
        staged page is freed.  Returns the pages actually published.
        """
        assert len(pages) == len(hashes)
        published = []
        for page, h in zip(pages, hashes):
            assert page in self._staged, f"publish of unstaged page {page}"
            self._staged.discard(page)
            if h in self._hash_page:
                self._free_pages.append(page)
                continue
            self._page_hash[page] = h
            self._hash_page[h] = page
            self._park(page)
            published.append(page)
        return published

    def abort_staged(self, pages: list[int]) -> None:
        """Mid-handoff cancellation: return staged pages to the free
        list without publishing (their contents are garbage)."""
        for page in pages:
            assert page in self._staged, f"abort of unstaged page {page}"
            self._staged.discard(page)
            self._free_pages.append(page)

    # ---------------------------------------------------------- integrity
    def check_invariants(self) -> None:
        """Raises AssertionError if the pool bookkeeping is inconsistent."""
        refs: dict[int, int] = {}
        for pages in self._slot_pages.values():
            for p in pages:
                refs[p] = refs.get(p, 0) + 1
        # refcount conservation: stored refcounts == table references
        for p in range(self.num_pages):
            assert int(self._refcount[p]) == refs.get(p, 0), \
                f"page {p}: refcount {int(self._refcount[p])} != " \
                f"{refs.get(p, 0)} table references"
        free = set(self._free_pages)
        cached = set(self._cached)
        owned = set(refs)
        staged = set(self._staged)
        assert len(free) == len(self._free_pages), "duplicate free page"
        assert not (free & owned), "page both free and owned"
        assert not (cached & owned), "page both cached and owned"
        assert not (free & cached), "page both free and cached"
        assert not (staged & (free | cached | owned)), \
            "staged page also free/cached/owned"
        assert len(free) + len(cached) + len(owned) + len(staged) == \
            self.num_pages, "page leak"
        for p in cached:
            assert p in self._page_hash, "cached page without a hash"
        if self.max_cached_pages is not None:
            pinned_cached = sum(1 for p in cached if self._export_pins[p])
            assert len(cached) - pinned_cached <= self.max_cached_pages, \
                f"cached LRU over its cap: {len(cached)} > " \
                f"{self.max_cached_pages} (+{pinned_cached} pinned)"
        for p in free:
            assert p not in self._page_hash, "free page still published"
        for p in staged:
            assert p not in self._page_hash, "staged page published"
            assert int(self._refcount[p]) == 0, "staged page referenced"
        assert (self._export_pins >= 0).all(), "negative export pin"
        for p in np.nonzero(self._export_pins)[0].tolist():
            assert p not in free, f"export-pinned page {p} on free list"
            assert p in self._page_hash, \
                f"export-pinned page {p} unpublished"
        assert {p: h for h, p in self._hash_page.items()} == \
            self._page_hash, "hash table not a bijection"
        assert not (set(self._free_slots) & set(self._slot_pages)), \
            "slot both free and active"
        assert len(self._free_slots) + len(self._slot_pages) == \
            self.max_batch, "slot leak"
        for slot, pages in self._slot_pages.items():
            assert len(pages) >= self.pages_for(int(self.seq_lens[slot]))
            assert len(pages) <= self.pages_per_seq
            assert list(self.page_table[slot, :len(pages)]) == pages
            assert not any(self.page_table[slot, len(pages):])
        for slot in self._free_slots:
            assert self.seq_lens[slot] == 0
