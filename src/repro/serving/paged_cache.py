"""Host-side block-pool manager for the paged KV cache.

Pure numpy/python bookkeeping: which pages belong to which slot, what
each slot's current length is, and the ``(max_batch, pages_per_seq)``
page table the device kernels consume.  The actual KV pools are jax
arrays owned by the engine (``LM.init_paged_cache``); this class never
touches them - freeing a slot just returns its page ids to the free
list, and stale KV in those pages is overwritten by the next owner
(positions are always written before they become visible via seq_lens).
"""
from __future__ import annotations

import numpy as np


class PagedKVCache:
    """Fixed-size page pool + per-slot page tables (alloc/append/free)."""

    def __init__(self, num_pages: int, page_size: int, max_batch: int,
                 pages_per_seq: int):
        assert num_pages >= 1 and page_size >= 1
        self.num_pages = num_pages
        self.page_size = page_size
        self.max_batch = max_batch
        self.pages_per_seq = pages_per_seq
        self.page_table = np.zeros((max_batch, pages_per_seq), np.int32)
        self.seq_lens = np.zeros((max_batch,), np.int32)
        self._free_pages: list[int] = list(range(num_pages - 1, -1, -1))
        self._free_slots: list[int] = list(range(max_batch - 1, -1, -1))
        self._slot_pages: dict[int, list[int]] = {}

    # ------------------------------------------------------------ queries
    @property
    def free_page_count(self) -> int:
        return len(self._free_pages)

    @property
    def free_slot_count(self) -> int:
        return len(self._free_slots)

    @property
    def active_slots(self) -> list[int]:
        return sorted(self._slot_pages)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def can_admit(self, prompt_len: int) -> bool:
        need = self.pages_for(prompt_len)
        return bool(self._free_slots and need <= self.pages_per_seq
                    and need <= len(self._free_pages))

    # ---------------------------------------------------------- lifecycle
    def alloc_slot(self, prompt_len: int) -> int:
        """Claim a slot + pages for a ``prompt_len``-token prefill.

        seq_lens is set to prompt_len: the engine writes those positions
        during prefill.  Raises if :meth:`can_admit` is False.
        """
        if prompt_len < 1:
            # seq_lens == 0 is the stack-wide "free slot" sentinel; an
            # active slot must own at least one token.
            raise ValueError(f"prompt_len must be >= 1, got {prompt_len}")
        if not self.can_admit(prompt_len):
            raise RuntimeError(
                f"cannot admit prompt of {prompt_len} tokens "
                f"(free slots {self.free_slot_count}, "
                f"free pages {self.free_page_count})")
        slot = self._free_slots.pop()
        pages = [self._free_pages.pop()
                 for _ in range(self.pages_for(prompt_len))]
        self._slot_pages[slot] = pages
        self.page_table[slot] = 0
        self.page_table[slot, :len(pages)] = pages
        self.seq_lens[slot] = prompt_len
        return slot

    def ensure_append_capacity(self, slot: int) -> bool:
        """Make room for one more token in ``slot``.

        The next token lands at position seq_lens[slot]; if that crosses
        into an unallocated page, grab one.  Returns False (slot left
        untouched) when the pool is exhausted or the sequence is at the
        pages_per_seq ceiling - the caller preempts or retires.
        """
        pages = self._slot_pages[slot]
        need = self.pages_for(int(self.seq_lens[slot]) + 1)
        if need <= len(pages):
            return True
        if need > self.pages_per_seq or not self._free_pages:
            return False
        page = self._free_pages.pop()
        pages.append(page)
        self.page_table[slot, len(pages) - 1] = page
        return True

    def advance(self, slot: int) -> None:
        """Record that one token's KV was appended to ``slot``."""
        assert self.pages_for(int(self.seq_lens[slot]) + 1) <= len(
            self._slot_pages[slot]), "advance() without capacity"
        self.seq_lens[slot] += 1

    def free_slot(self, slot: int) -> None:
        """Retire a slot: recycle its pages, zero its table row."""
        pages = self._slot_pages.pop(slot)
        self._free_pages.extend(reversed(pages))
        self._free_slots.append(slot)
        self.page_table[slot] = 0
        self.seq_lens[slot] = 0

    # ---------------------------------------------------------- integrity
    def check_invariants(self) -> None:
        """Raises AssertionError if the pool bookkeeping is inconsistent."""
        used = [p for pages in self._slot_pages.values() for p in pages]
        assert len(used) == len(set(used)), "page owned by two slots"
        free = set(self._free_pages)
        assert len(free) == len(self._free_pages), "duplicate free page"
        assert not (free & set(used)), "page both free and owned"
        assert len(free) + len(used) == self.num_pages, "page leak"
        assert not (set(self._free_slots) & set(self._slot_pages)), \
            "slot both free and active"
        assert len(self._free_slots) + len(self._slot_pages) == \
            self.max_batch, "slot leak"
        for slot, pages in self._slot_pages.items():
            assert len(pages) >= self.pages_for(int(self.seq_lens[slot]))
            assert list(self.page_table[slot, :len(pages)]) == pages
        for slot in self._free_slots:
            assert self.seq_lens[slot] == 0
