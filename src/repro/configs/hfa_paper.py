"""hfa-paper-mini: Phi-3.5-mini-like dense config (the paper's own eval
model family, Table I) with the H-FA attention kernel enabled end-to-end."""
from repro.configs.base import ModelConfig, register

HFA_PAPER_MINI = register(ModelConfig(
    name="hfa-paper-mini",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_head=96,
    d_ff=8192,
    vocab_size=32064,
    attn_impl="hfa_pallas",
    param_dtype="bfloat16",
))
