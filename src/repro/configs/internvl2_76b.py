"""internvl2-76b: VLM = InternViT frontend (STUB) + LLM backbone
[arXiv:2404.16821; unverified].

Per the task spec only the transformer BACKBONE is modeled; the vision
frontend is a stub - ``input_specs()`` supplies precomputed patch
embeddings of shape (batch, n_patches, d_model) that are concatenated in
front of the token embeddings.
"""
from repro.configs.base import ModelConfig, register

INTERNVL2_76B = register(ModelConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=28672,
    vocab_size=128256,
    n_patches=256,
    attn_impl="fa2",
    param_dtype="bfloat16",
    optimizer="adafactor",
    microbatches=4,
))
