"""command-r-plus-104b: large dense GQA, no biases [hf:CohereForAI; unverified]."""
from repro.configs.base import ModelConfig, register

COMMAND_R_PLUS_104B = register(ModelConfig(
    name="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_head=128,
    d_ff=33792,
    vocab_size=256000,
    attn_impl="fa2",
    param_dtype="bfloat16",
    optimizer="adafactor",   # >= 100B: factored second moment (DESIGN.md §5)
    microbatches=4,
))
