"""Assigned architecture configs (10) + the paper's own eval family."""
from repro.configs.base import ModelConfig, get_config, REGISTRY  # noqa: F401
from repro.configs import (  # noqa: F401
    minitron_8b,
    qwen3_1_7b,
    qwen1_5_4b,
    command_r_plus_104b,
    jamba_1_5_large_398b,
    internvl2_76b,
    mamba2_2_7b,
    whisper_medium,
    granite_moe_1b,
    phi3_5_moe_42b,
    hfa_paper,
)

ASSIGNED = [
    "minitron-8b",
    "qwen3-1.7b",
    "qwen1.5-4b",
    "command-r-plus-104b",
    "jamba-1.5-large-398b",
    "internvl2-76b",
    "mamba2-2.7b",
    "whisper-medium",
    "granite-moe-1b-a400m",
    "phi3.5-moe-42b-a6.6b",
]
