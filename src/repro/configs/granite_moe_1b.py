"""granite-moe-1b-a400m: 32 experts top-8, every layer
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.configs.base import ModelConfig, register

GRANITE_MOE_1B = register(ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    d_head=64,
    d_ff=512,
    vocab_size=49155,
    n_experts=32,
    moe_top_k=8,
    moe_every=1,
    attn_impl="fa2",
    param_dtype="bfloat16",
))
