"""whisper-medium: encoder-decoder audio transformer [arXiv:2212.04356;
unverified].

The conv frontend is a STUB per the task spec: ``input_specs()`` provides
precomputed frame embeddings (batch, enc_seq, d_model).  24 encoder + 24
decoder layers, LayerNorm + GELU, learned positions in the decoder,
sinusoidal in the encoder.  Decode shapes exercise the decoder with a
self-attention KV cache plus cross-attention to the encoder output.
"""
from repro.configs.base import ModelConfig, register

WHISPER_MEDIUM = register(ModelConfig(
    name="whisper-medium",
    family="encdec",
    n_layers=24,
    n_enc_layers=24,
    enc_seq=1500,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_head=64,
    d_ff=4096,
    vocab_size=51865,
    pos_emb="learned",
    norm_type="layernorm",
    mlp_type="gelu",
    attn_impl="fa2",
    param_dtype="bfloat16",
))
