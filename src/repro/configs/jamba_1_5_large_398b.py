"""jamba-1.5-large-398b: hybrid Mamba+attention 1:7, MoE 16e top-2
[arXiv:2403.19887; hf].

72 layers = 9 periods of 8; the attention layer sits at offset 4 of each
period (Jamba places one attention layer per 8-layer block); MoE FFN every
second layer.
"""
from repro.configs.base import ModelConfig, register

JAMBA_1_5_LARGE_398B = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=24576,
    vocab_size=65536,
    n_experts=16,
    moe_top_k=2,
    moe_every=2,
    attn_every=8,
    attn_offset=4,
    m_expand=2,
    m_headdim=64,
    m_dstate=128,
    attn_impl="fa2",
    param_dtype="bfloat16",
    optimizer="adafactor",   # ~400B params
    microbatches=4,
))
