"""phi3.5-moe-42b-a6.6b: 16 experts top-2, every layer
[hf:microsoft/Phi-3.5-MoE-instruct; hf].

Closest family to the paper's own Phi-3.5 accuracy evaluation; this is the
default arch for the H-FA representative perf cell.
"""
from repro.configs.base import ModelConfig, register

PHI3_5_MOE_42B = register(ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=6400,
    vocab_size=32064,
    n_experts=16,
    moe_top_k=2,
    moe_every=1,
    attn_impl="fa2",
    param_dtype="bfloat16",
))
