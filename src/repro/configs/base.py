"""Model/config system: one dataclass, one file per assigned architecture.

``ModelConfig`` covers every family in the assigned pool (dense GQA, MoE,
hybrid Mamba+attn, pure SSM, encoder-decoder audio, VLM backbone).  The
``layer_kinds()`` method expands the per-layer pattern used by the hybrid
archs.  ``reduced()`` returns the smoke-test scale-down of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family = "dense"
    n_layers: int = 12
    d_model: int = 1024
    n_heads: int = 16
    n_kv_heads: int = 16
    d_head: int = 64
    d_ff: int = 4096
    vocab_size: int = 32000

    # attention options
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_impl: str = "fa2"          # see repro.kernels.ops.IMPLS
    attn_block: int = 128           # flash KV/Q block size
    serve_attn: str = "xla"         # xla | shardmap_merge (paper ACC merge)
    rope_theta: float = 10000.0
    pos_emb: str = "rope"            # rope | learned | sinusoidal
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    mlp_type: str = "swiglu"         # swiglu | gelu

    # MoE
    n_experts: int = 0
    moe_top_k: int = 2
    capacity_factor: float = 1.25
    moe_every: int = 1               # MoE FFN every k-th layer
    moe_group: int = 0               # dispatch group tokens; 0 = auto

    # hybrid (Jamba): attention every k-th layer, rest Mamba
    attn_every: int = 0              # 0 = all layers attention
    attn_offset: int = 4             # index of the attn layer in the period

    # Mamba/SSD
    m_expand: int = 2
    m_headdim: int = 64
    m_dstate: int = 128
    m_ngroups: int = 1
    m_conv: int = 4
    m_chunk: int = 128

    # enc-dec (Whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500              # precomputed frame embeddings (stub)

    # VLM
    n_patches: int = 0               # precomputed patch embeddings (stub)

    # training / numerics
    vocab_pad_multiple: int = 2048   # pad tables to 128 lanes x 16 shards
    max_seq: int = 4096
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    scan_layers: bool = True
    unroll_microbatches: bool = False  # cost-probe knob
    remat: str = "full"              # full | none
    # distribution knobs (consumed by launch/ + parallel/)
    optimizer: str = "adamw"         # adamw | adafactor
    microbatches: int = 1

    def layer_kinds(self) -> list[str]:
        """Per-layer mixer kind: 'attn' or 'mamba'."""
        if self.family == "ssm":
            return ["mamba"] * self.n_layers
        if self.family == "hybrid" and self.attn_every > 0:
            return ["attn" if (i % self.attn_every) == self.attn_offset
                    else "mamba" for i in range(self.n_layers)]
        return ["attn"] * self.n_layers

    def ffn_kinds(self) -> list[str]:
        """Per-layer FFN kind: 'dense' or 'moe' (or 'none' for pure SSM)."""
        if self.family == "ssm":
            return ["none"] * self.n_layers
        if self.n_experts > 0:
            return ["moe" if (i % self.moe_every) == (self.moe_every - 1)
                    else "dense" for i in range(self.n_layers)]
        return ["dense"] * self.n_layers

    @property
    def padded_vocab(self) -> int:
        """Embedding/head table size: vocab padded to a TP-friendly multiple
        (standard practice - unused tail ids are inert extra tokens)."""
        m = self.vocab_pad_multiple
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def d_inner(self) -> int:
        return self.m_expand * self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state does not grow quadratically (SSM/hybrid-lite).

        Used to gate the long_500k shape (see DESIGN.md).
        """
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        n_attn = sum(1 for k in self.layer_kinds() if k == "attn")
        n_mamba = self.n_layers - n_attn
        attn = n_attn * (d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
                         + self.n_heads * self.d_head * d)
        din = self.d_inner
        gn = self.m_ngroups * self.m_dstate
        h = din // self.m_headdim
        mamba = n_mamba * (2 * d * din + 2 * d * gn + d * h + din * d)
        dense_ffn = 3 * d * ff if self.mlp_type == "swiglu" else 2 * d * ff
        n_moe = sum(1 for k in self.ffn_kinds() if k == "moe")
        n_dense = sum(1 for k in self.ffn_kinds() if k == "dense")
        ffn = n_dense * dense_ffn + n_moe * self.n_experts * 3 * d * ff
        emb = v * d * (1 if self.tie_embeddings else 2)
        enc = self.n_enc_layers * (4 * d * d + 2 * d * ff)
        # enc-dec decoders add a cross-attention block per layer
        cross = (self.n_layers * 4 * d * d) if self.family == "encdec" else 0
        return attn + mamba + ffn + emb + enc + cross

    def active_param_count(self) -> int:
        """Params active per token (MoE: top_k experts instead of all)."""
        if self.n_experts == 0:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        n_moe = sum(1 for k in self.ffn_kinds() if k == "moe")
        full = self.param_count()
        moe_all = n_moe * self.n_experts * 3 * d * ff
        moe_active = n_moe * self.moe_top_k * 3 * d * ff
        return full - moe_all + moe_active

    def reduced(self) -> "ModelConfig":
        """Smoke-test config of the same family (CPU-runnable)."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.family != "hybrid"
                         else max(self.attn_every, 4)),
            d_model=256,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads
            < self.n_heads else 4,
            d_head=64,
            d_ff=512 if self.d_ff else 0,
            vocab_size=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2),
            n_enc_layers=min(self.n_enc_layers, 2),
            enc_seq=64 if self.n_enc_layers else 0,
            n_patches=16 if self.n_patches else 0,
            m_dstate=32,
            m_headdim=32,
            m_chunk=16,
            vocab_pad_multiple=64,
            max_seq=128,
            param_dtype="float32",
            compute_dtype="float32",
            microbatches=1,
        )


REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # Import config modules lazily so REGISTRY is populated.
    from repro import configs as _c  # noqa: F401
    if name not in REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(REGISTRY)}")
    return REGISTRY[name]
