"""qwen1.5-4b: QKV bias, MHA-style GQA kv=20 [hf:Qwen/Qwen1.5 family; hf].

Note: 20 heads do not divide the 16-way model axis; the sharding rules
degrade head sharding to replication for this arch (see
parallel/sharding.py) and TP comes from the MLP + vocab dims.
"""
from repro.configs.base import ModelConfig, register

QWEN1_5_4B = register(ModelConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_head=128,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    attn_impl="fa2",
    param_dtype="bfloat16",
))
