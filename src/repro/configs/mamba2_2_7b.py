"""mamba2-2.7b: attention-free SSD [arXiv:2405.21060; unverified].

H-FA is inapplicable (no softmax) - see DESIGN.md §Arch-applicability.
Supports long_500k: decode state is O(1) in sequence length.
"""
from repro.configs.base import ModelConfig, register

MAMBA2_2_7B = register(ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_head=0,
    d_ff=0,
    vocab_size=50280,
    m_expand=2,
    m_headdim=64,
    m_dstate=128,
    m_conv=4,
    param_dtype="bfloat16",
))
