"""minitron-8b: width-pruned Nemotron-4 [arXiv:2407.14679; hf]."""
from repro.configs.base import ModelConfig, register

MINITRON_8B = register(ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=16384,
    vocab_size=256000,
    attn_impl="fa2",
    param_dtype="bfloat16",
))
