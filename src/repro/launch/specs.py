"""ShapeDtypeStruct stand-ins + sharding-spec trees for every dry-run cell.

``input_specs(cfg, shape_name)`` returns the abstract inputs for the step
being lowered (train / prefill / decode), without allocating anything.
``build_cell(cfg, shape_name, mesh)`` assembles (fn, args, in_shardings,
out_shardings) ready for ``jax.jit(...).lower(...)``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.model import build_model
from repro.optim import build_optimizer
from repro.optim.schedule import warmup_cosine
from repro.parallel import sharding as sh
from repro.runtime.trainer import make_train_step

# shape id -> (mode, seq_len, global_batch)
SHAPES: dict[str, tuple[str, int, int]] = {
    "train_4k": ("train", 4_096, 256),
    "prefill_32k": ("prefill", 32_768, 32),
    "decode_32k": ("decode", 32_768, 128),
    "long_500k": ("decode", 524_288, 1),
}


def shape_applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """long_500k only runs for sub-quadratic (SSM/hybrid) archs."""
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k dense decode skipped per "
                       "task spec (no sub-quadratic attention claimed); see "
                       "DESIGN.md §4")
    return True, ""


def cell_config(cfg: ModelConfig, shape_name: str) -> ModelConfig:
    """Adjust max_seq (learned pos-emb tables, caches) to the cell shape."""
    mode, seq, batch = SHAPES[shape_name]
    return dataclasses.replace(cfg, max_seq=max(seq, cfg.max_seq))


def _token_specs(cfg: ModelConfig, seq: int, batch: int) -> dict[str, Any]:
    out: dict[str, Any] = {}
    n_text = seq
    if cfg.family == "vlm":
        n_text = seq - cfg.n_patches
        out["patches"] = jax.ShapeDtypeStruct(
            (batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    out["tokens"] = jax.ShapeDtypeStruct((batch, n_text), jnp.int32)
    return out


def _batch_logical(batch_specs: dict[str, Any]) -> dict[str, Any]:
    out = {}
    for k, v in batch_specs.items():
        if k == "tokens":
            out[k] = ("batch", None)
        else:
            out[k] = ("batch", None, None)
    return out


def cache_logical_from_shapes(shapes: Any, cfg: ModelConfig, mesh) -> Any:
    """Logical axes for a decode cache tree, chosen per leaf name/shape.

    KV rings shard heads over "model" when divisible, otherwise the cache
    *sequence* is sharded over "model" - the paper's multi-KV-block
    parallel layout (partial attention per shard + online merge).
    """
    kv_heads_divisible = (cfg.n_kv_heads > 0
                          and cfg.n_kv_heads % mesh.shape["model"] == 0)

    def leaf(path, s):
        name = str(getattr(path[-1], "key", path[-1]))
        nd = len(s.shape)
        if name in ("k", "v", "ck", "cv"):
            core = (("kv_batch", None, "kv_heads", "head_dim")
                    if kv_heads_divisible
                    else ("kv_batch", "kv_seq", None, "head_dim"))
            return ("layers",) * (nd - 4) + core
        if name == "ssm":
            return ("layers",) * (nd - 4) + ("kv_batch", "mamba_heads", None, None)
        if name.startswith("conv_"):
            return ("layers",) * (nd - 3) + ("kv_batch", None, "mamba_inner")
        if name == "pos":
            return ()
        return (None,) * nd

    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    return treedef.unflatten([leaf(p, s) for p, s in flat])


def _shardings(mesh, logical_tree, shape_tree, rules):
    specs = sh.tree_specs(logical_tree, shape_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def build_cell(cfg: ModelConfig, shape_name: str, mesh, variant=None):
    """Returns (fn, args_shapes, in_shardings, out_shardings, meta).

    ``variant`` (perf hillclimb): {"cfg": {field: value}, "rules": {...}}
    overrides applied on top of the baseline configuration.
    """
    mode, seq, batch = SHAPES[shape_name]
    cfg = cell_config(cfg, shape_name)
    rule_over = {}
    if variant:
        if variant.get("cfg"):
            cfg = dataclasses.replace(cfg, **variant["cfg"])
        rule_over = variant.get("rules", {})
    model = build_model(cfg)
    param_shapes, param_logical = model.shape_and_logical()
    base_rules = sh.TRAIN_RULES if mode == "train" else sh.SERVE_RULES
    active_rules = dict(base_rules, **rule_over)
    sh.set_context(mesh, active_rules)

    if mode == "train":
        rules = dict(active_rules)
        opt = build_optimizer(cfg, warmup_cosine(3e-4, 100, 10_000))
        opt_shapes = jax.eval_shape(opt.init, param_shapes)
        opt_logical = opt.state_logical(param_logical)
        step = make_train_step(model, opt, microbatches=cfg.microbatches,
                               unroll=cfg.unroll_microbatches)
        batch_specs = _token_specs(cfg, seq, batch)
        carry_shapes = {"params": param_shapes, "opt_state": opt_shapes}
        carry_logical = {"params": param_logical, "opt_state": opt_logical}
        carry_sh = _shardings(mesh, carry_logical, carry_shapes, rules)
        batch_sh = _shardings(mesh, _batch_logical(batch_specs), batch_specs,
                              rules)
        metrics_keys = ["nll", "loss", "load_balance", "router_z",
                        "grad_norm"]
        metrics_sh = {k: NamedSharding(mesh, P()) for k in metrics_keys}
        return (step, (carry_shapes, batch_specs),
                (carry_sh, batch_sh), (carry_sh, metrics_sh),
                {"cfg": cfg, "mode": mode, "seq": seq, "batch": batch})

    rules = dict(active_rules)
    param_sh = _shardings(mesh, param_logical, param_shapes, rules)

    if mode == "prefill":
        if cfg.family == "encdec":
            def fn(params, batch_in):
                enc_out = model._encode(params, batch_in["frames"],
                                        jnp.bfloat16)
                cache = model.init_cache(params, batch, seq, enc_out=enc_out)
                return model.prefill(params, cache, batch_in["tokens"])
        elif cfg.family == "vlm":
            def fn(params, batch_in):
                cache = model.init_cache(params, batch, seq)
                return model.prefill(params, cache, batch_in["tokens"],
                                     prefix_embeds=batch_in["patches"])
        else:
            def fn(params, batch_in):
                cache = model.init_cache(params, batch, seq)
                return model.prefill(params, cache, batch_in["tokens"])
        batch_specs = _token_specs(cfg, seq, batch)
        batch_sh = _shardings(mesh, _batch_logical(batch_specs), batch_specs,
                              rules)
        # outputs: (logits, cache) - logits sharded, cache per its logical.
        cache_shapes = jax.eval_shape(
            lambda p, b: fn(p, b)[1], param_shapes, batch_specs)
        cache_sh = _shardings(
            mesh, cache_logical_from_shapes(cache_shapes, cfg, mesh),
            cache_shapes, rules)
        logits_sh = NamedSharding(mesh, sh.spec_for(
            ("batch", None, "vocab"),
            (batch, 1, cfg.padded_vocab), rules, mesh))
        return (fn, (param_shapes, batch_specs), (param_sh, batch_sh),
                (logits_sh, cache_sh),
                {"cfg": cfg, "mode": mode, "seq": seq, "batch": batch})

    # decode: one new token against a cache of seq_len.
    def fn(params, cache, tokens):
        return model.decode_step(params, cache, tokens)

    enc_out_shape = None
    if cfg.family == "encdec":
        enc_out_shape = jax.ShapeDtypeStruct(
            (batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    cache_shapes = jax.eval_shape(
        functools.partial(model.init_cache, batch=batch, max_seq=seq),
        param_shapes, enc_out=enc_out_shape)
    # the cache arrives mid-generation: pos is a traced scalar
    tok_shape = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    cache_logical = cache_logical_from_shapes(cache_shapes, cfg, mesh)
    cache_sh = _shardings(mesh, cache_logical, cache_shapes, rules)
    tok_sh = NamedSharding(mesh, sh.spec_for(("batch", None), (batch, 1),
                                             rules, mesh))
    logits_sh = NamedSharding(mesh, sh.spec_for(
        ("batch", None, "vocab"), (batch, 1, cfg.padded_vocab), rules, mesh))
    return (fn, (param_shapes, cache_shapes, tok_shape),
            (param_sh, cache_sh, tok_sh), (logits_sh, cache_sh),
            {"cfg": cfg, "mode": mode, "seq": seq, "batch": batch})
