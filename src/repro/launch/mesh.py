"""Production mesh definitions.

A *function*, not a module-level constant: importing this module never
touches jax device state (required so smoke tests see 1 CPU device while
the dry-run sees 512 placeholders).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 chips across two pods.

    Axes: "pod" (DP across pods, ICI/DCN boundary), "data" (DP + FSDP
    weight sharding within a pod), "model" (TP/EP/SP).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(devices: int | None = None):
    """Small mesh over whatever devices exist (tests: 8 fake CPU devices)."""
    n = devices or len(jax.devices())
    model = 2 if n % 2 == 0 else 1
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_tp_mesh(tp: int):
    """Tensor-parallel serving mesh: ("data"=1, "model"=tp) over the
    first ``tp`` devices.  On CPU, simulate devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` *before* jax
    initializes (the serve/benchmark entry points set it for you when
    ``--tp`` is passed)."""
    import numpy as np
    from jax.sharding import Mesh
    devs = jax.devices()
    if len(devs) < tp:
        raise RuntimeError(
            f"tp={tp} needs {tp} devices, found {len(devs)}; on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={tp}")
    return Mesh(np.asarray(devs[:tp]).reshape(1, tp), ("data", "model"))


def make_tp_dp_mesh(tp: int, dp: int):
    """Composed serving mesh: ("data"=dp, "model"=tp) over the first
    ``dp * tp`` devices.  The "model" axis KV-head-shards the paged
    pools (tensor parallelism, PR 4); the "data" axis batch-shards the
    *slot* dimension of every paged attention call, so a step's compute
    splits across data shards while the pools (replicated over "data")
    and the host page tables stay bit-identical on every shard.  On
    CPU, simulate ``dp * tp`` devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` before jax
    initializes (the entry points do this when ``--tp``/``--dp`` are
    passed)."""
    import numpy as np
    from jax.sharding import Mesh
    if tp < 1 or dp < 1:
        raise ValueError(f"tp and dp must be >= 1, got tp={tp} dp={dp}")
    devs = jax.devices()
    need = dp * tp
    if len(devs) < need:
        raise RuntimeError(
            f"tp={tp} x dp={dp} needs {need} devices, found {len(devs)}; "
            f"on CPU set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    return Mesh(np.asarray(devs[:need]).reshape(dp, tp),
                ("data", "model"))
