"""Async streaming serving entry point: Poisson open-loop traffic over
the :class:`repro.serving.frontend.AsyncFrontend`.

Where ``repro.launch.serve`` drives the engine synchronously to
completion, this entry point serves the way real traffic arrives: an
open-loop Poisson process (arrivals do not wait for completions), one
async client coroutine per request consuming its token stream, latency
classes (``interactive`` / ``standard`` / ``batch``) mixed per
``--class-mix``, and optional mid-stream abandonment (``--cancel-every``)
exercising the refcount-clean cancellation path.  Client-side TTFT
(submit -> first token out of the generator) and TPOT (mean gap between
consecutive tokens) are reported as p50/p99 per class against the
class targets.

  PYTHONPATH=src python -m repro.launch.serve_async --arch qwen3-1.7b \
      --reduced --smoke

Jax is imported only after argument parsing (see
:func:`repro.launch.serve.ensure_host_devices`).
"""
import argparse
import asyncio
import time

import numpy as np

from repro.launch.serve import (ensure_host_devices, parse_prefill_budget,
                                _paged_supported)


def parse_class_mix(s: str) -> dict[str, float]:
    """"interactive=0.5,standard=0.3,batch=0.2" -> {name: weight}.
    Weights are normalized; unknown class names fail in main() where
    LATENCY_CLASSES is importable."""
    mix = {}
    for part in s.split(","):
        name, _, w = part.partition("=")
        mix[name.strip()] = float(w) if w else 1.0
    total = sum(mix.values())
    if total <= 0:
        raise argparse.ArgumentTypeError(f"empty class mix: {s!r}")
    return {k: v / total for k, v in mix.items()}


def poisson_gaps(rng, n: int, rate: float) -> list[float]:
    """n exponential inter-arrival gaps for a Poisson process of
    ``rate`` requests/sec (rate <= 0: all arrive at t=0)."""
    if rate <= 0:
        return [0.0] * n
    return rng.exponential(1.0 / rate, size=n).tolist()


async def open_loop(frontend, arrivals, *, cancel_every: int = 0,
                    cancel_after: int = 4) -> list[dict]:
    """Drive an open-loop workload: ``arrivals`` is [(gap_seconds,
    request)]; each request gets a client coroutine that consumes its
    stream and measures client-side latency.  Every ``cancel_every``-th
    client abandons its generator after ``cancel_after`` tokens
    (0 = never), exercising mid-prefill and mid-decode cancellation.

    Returns one record per request:
    {rid, cls, ttft, tpot, tokens, reason, fr}; ttft/tpot are None when
    no token arrived (cancelled pre-first-token / rejected); fr is the
    claimed FinishedRequest (result() removes it from the frontend's
    bounded LRU, so the record carries it for later inspection)."""
    records: list[dict] = []

    async def client(i: int, req) -> None:
        cancel_at = None
        if cancel_every > 0 and i % cancel_every == cancel_every - 1:
            cancel_at = cancel_after
        t_submit = time.perf_counter()
        t_tokens: list[float] = []
        gen = frontend.submit(req)
        try:
            async for _tok in gen:
                t_tokens.append(time.perf_counter())
                if cancel_at is not None and len(t_tokens) >= cancel_at:
                    break                      # abandon mid-stream
        finally:
            await gen.aclose()
        # aclose() files the cancel intent; the result lands once the
        # drive loop applies it.  result() claims (removes) it.
        fr = None
        while fr is None:
            fr = frontend.result(req.rid)
            if fr is None:
                await asyncio.sleep(0.001)
        ttft = t_tokens[0] - t_submit if t_tokens else None
        tpot = (t_tokens[-1] - t_tokens[0]) / (len(t_tokens) - 1) \
            if len(t_tokens) > 1 else None
        records.append({"rid": req.rid, "cls": req.latency_class.name,
                        "ttft": ttft, "tpot": tpot,
                        "tokens": len(t_tokens), "reason": fr.reason,
                        "fr": fr})

    tasks = []
    for i, (gap, req) in enumerate(arrivals):
        if gap:
            await asyncio.sleep(gap)
        tasks.append(asyncio.ensure_future(client(i, req)))
    await asyncio.gather(*tasks)
    await frontend.close()
    return sorted(records, key=lambda r: r["rid"])


def summarize(records: list[dict]) -> dict:
    """Per-class p50/p99 TTFT and TPOT (seconds) plus counts:
    {cls: {n, cancelled, ttft_p50, ttft_p99, tpot_p50, tpot_p99}}."""
    out: dict[str, dict] = {}
    for cls in sorted({r["cls"] for r in records}):
        rs = [r for r in records if r["cls"] == cls]
        ttfts = [r["ttft"] for r in rs if r["ttft"] is not None]
        tpots = [r["tpot"] for r in rs if r["tpot"] is not None]
        ent = {"n": len(rs),
               "cancelled": sum(r["reason"] == "cancelled" for r in rs)}
        for key, vals in (("ttft", ttfts), ("tpot", tpots)):
            ent[f"{key}_p50"] = float(np.percentile(vals, 50)) \
                if vals else None
            ent[f"{key}_p99"] = float(np.percentile(vals, 99)) \
                if vals else None
        out[cls] = ent
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="concurrent decode slots")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32,
                    help="decode tokens per request")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests (default 4x batch)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-budget", type=parse_prefill_budget,
                    default="adaptive",
                    help="int, 'none', or 'adaptive' (default: derive "
                         "the chunked-prefill budget from the decode "
                         "batch's SLA headroom each step)")
    ap.add_argument("--spec-k", type=int, default=0)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=8.0,
                    help="Poisson arrival rate, requests/sec "
                         "(<= 0: all requests arrive at t=0)")
    ap.add_argument("--class-mix", type=parse_class_mix,
                    default="interactive=0.25,standard=0.5,batch=0.25",
                    help="latency-class weights, e.g. "
                         "interactive=0.5,standard=0.3,batch=0.2")
    ap.add_argument("--cancel-every", type=int, default=0,
                    help="every k-th client abandons its stream after "
                         "--cancel-after tokens (0 = never)")
    ap.add_argument("--cancel-after", type=int, default=4)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI configuration (few short requests)")
    args = ap.parse_args()
    if isinstance(args.class_mix, str):
        args.class_mix = parse_class_mix(args.class_mix)
    if args.smoke:
        args.batch = min(args.batch, 4)
        args.prompt_len = min(args.prompt_len, 16)
        args.steps = min(args.steps, 8)
        args.requests = args.requests or 6
        args.rate = 50.0
    ensure_host_devices(args.tp)

    import jax

    from repro.configs import get_config
    from repro.data import DataPipeline
    from repro.models.model import build_model
    from repro.serving import (LATENCY_CLASSES, AsyncFrontend, Request,
                               SamplingParams, ServingEngine)

    for name in args.class_mix:
        if name not in LATENCY_CLASSES:
            raise SystemExit(f"unknown latency class {name!r} (have "
                             f"{sorted(LATENCY_CLASSES)})")
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not _paged_supported(cfg):
        raise SystemExit(f"{cfg.name} is not paged-servable; the async "
                         "front-end has no dense fallback")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_tp_mesh
        mesh = make_tp_mesh(args.tp)
    engine = ServingEngine(model, params, max_batch=args.batch,
                           page_size=args.page_size, max_seq=args.max_seq,
                           prefill_budget=args.prefill_budget,
                           spec_k=args.spec_k, mesh=mesh)

    n_req = args.requests or 4 * args.batch
    pipe = DataPipeline.for_config(cfg, args.prompt_len, args.batch)
    prompts = np.concatenate(
        [pipe.batch(s)["tokens"] for s in range((n_req + args.batch - 1)
                                                // args.batch)])[:n_req]
    rng = np.random.default_rng(args.seed)
    names = sorted(args.class_mix)
    picks = rng.choice(len(names), size=n_req,
                       p=[args.class_mix[n] for n in names])
    gaps = poisson_gaps(rng, n_req, args.rate)
    arrivals = []
    for i in range(n_req):
        sp = SamplingParams(temperature=args.temperature,
                            seed=args.seed + i)
        arrivals.append((gaps[i], Request(
            rid=i, prompt=prompts[i].tolist(),
            max_new_tokens=args.steps, sampling=sp,
            latency_class=LATENCY_CLASSES[names[int(picks[i])]])))

    frontend = AsyncFrontend(engine)
    t0 = time.perf_counter()
    records = asyncio.run(open_loop(frontend, arrivals,
                                    cancel_every=args.cancel_every,
                                    cancel_after=args.cancel_after))
    dt = time.perf_counter() - t0
    engine.cache.check_invariants()

    st = engine.stats
    print(f"open loop: {len(records)} requests in {dt:.2f} s at rate "
          f"{args.rate}/s ({st['steps']} engine steps, "
          f"{st['cancelled']} cancelled, {st['preemptions']} preemptions)")
    if engine.adaptive_prefill:
        print(f"adaptive prefill budget: last {st['adaptive_budget_last']} "
              f"tokens (floor {engine.adaptive_floor}, ceiling "
              f"{engine.adaptive_ceiling})")
    for cls, ent in summarize(records).items():
        tgt = LATENCY_CLASSES[cls]
        fmt = lambda v: "-" if v is None else f"{1e3 * v:.0f}ms"  # noqa: E731
        print(f"  {cls:<12} n={ent['n']:<3} "
              f"ttft p50/p99 {fmt(ent['ttft_p50'])}/{fmt(ent['ttft_p99'])} "
              f"(target {1e3 * tgt.ttft_target:.0f}ms)  "
              f"tpot p50/p99 {fmt(ent['tpot_p50'])}/{fmt(ent['tpot_p99'])} "
              f"(target {1e3 * tgt.tpot_target:.0f}ms)  "
              f"cancelled={ent['cancelled']}")
    done = [r for r in records if r["reason"] in ("eos", "length")]
    if done:
        print("sample:", done[0]["fr"].tokens[:12])


if __name__ == "__main__":
    main()
