"""Production training entry point.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 50 --global-batch 8 --seq 128

On a real cluster this runs under `jax.distributed.initialize()` with the
production mesh; in this container it runs the same code single-host.
XLA flags for collective/compute overlap (latency-hiding scheduler) are
set here - they are the deploy-time defaults.
"""
import argparse
import os

# Latency-hiding scheduler: overlap weight all-gathers / grad reduce-
# scatters with compute (the §Perf collective lever at deploy time).
# TPU-only flags: the CPU backend rejects them.
if os.path.exists("/dev/accel0") or "tpu" in os.environ.get(
        "JAX_PLATFORMS", ""):
    os.environ.setdefault(
        "XLA_FLAGS",
        "--xla_tpu_enable_latency_hiding_scheduler=true "
        "--xla_tpu_enable_async_collective_fusion=true")

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-compression", action="store_true")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.runtime.trainer import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    print(f"{cfg.name}: ~{cfg.param_count()/1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")
    tcfg = TrainerConfig(
        steps=args.steps, ckpt_dir=args.ckpt, peak_lr=args.lr,
        warmup=max(args.steps // 20, 1), seq_len=args.seq,
        global_batch=args.global_batch,
        grad_compression=args.grad_compression)
    res = Trainer(model, tcfg).run()
    losses = [m["loss"] for m in res["metrics"]]
    print(f"steps={res['final_step']} restarts={res['restarts']} "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}")


if __name__ == "__main__":
    main()
