"""Production serving entry point: continuous batching over paged KV.

Default path: the paged serving engine (block-pool KV cache + scheduler,
src/repro/serving/) with requests arriving every step - they join and
leave the batch mid-flight.  ``--dense`` falls back to the legacy
fixed-batch greedy loop over a contiguous cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --batch 2 --steps 4

``--tp N`` serves tensor-parallel: the paged KV pools are KV-head-sharded
over a ("data", "model") mesh and decode/prefill/verify attention runs
the cascaded ACC merge (only (m, l, o~) triplets cross shards).  On CPU
the mesh is simulated - jax must see N devices *before* it initializes,
which this entry point arranges by setting
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (hence jax is
imported only after argument parsing).
"""
import argparse
import os
import time

import numpy as np


def parse_prefill_budget(value: str | None) -> "int | str | None":
    """CLI form of the engine's ``prefill_budget``: "none"/"" -> None
    (unbounded), "adaptive" -> SLA-headroom-derived per-step budget
    (see repro.serving.scheduler.Scheduler.adaptive_prefill_budget),
    else an int token budget.  Lives here (not in the engine) so
    argparse can use it before jax is imported."""
    if value is None or value.lower() in ("", "none"):
        return None
    if value.lower() == "adaptive":
        return "adaptive"
    try:
        return int(value)
    except ValueError:
        raise argparse.ArgumentTypeError(
            f"expected an int, 'none' or 'adaptive', got {value!r}")


_DEV_COUNT_FLAG = "--xla_force_host_platform_device_count"


def merge_xla_flags(flags: str, n: int) -> str:
    """Merge ``--xla_force_host_platform_device_count=n`` into an
    existing ``XLA_FLAGS`` string, preserving every other flag.

    A pre-existing device-count flag is *raised* to ``n`` when it is
    lower (a CI env block pinning count=2 must not silently break a
    --tp 4 run) and kept verbatim when it already covers ``n`` (the
    user asked for more simulated devices than we need - fine)."""
    parts = flags.split()
    for i, part in enumerate(parts):
        if part.startswith(_DEV_COUNT_FLAG + "="):
            try:
                have = int(part.split("=", 1)[1])
            except ValueError:
                have = 0
            if have < n:
                parts[i] = f"{_DEV_COUNT_FLAG}={n}"
            return " ".join(parts)
    parts.append(f"{_DEV_COUNT_FLAG}={n}")
    return " ".join(parts)


def ensure_host_devices(tp: int) -> None:
    """Force at least ``tp`` simulated host devices for --tp runs.

    Must run before jax initializes, which is why this module (and
    benchmarks/serving.py, which imports this helper) defers ``import
    jax`` past argument parsing.  Other pre-existing ``XLA_FLAGS`` are
    preserved; a pre-existing device-count flag is raised to ``tp`` if
    too low and respected otherwise (see :func:`merge_xla_flags`).
    """
    import sys
    if tp <= 1 or "jax" in sys.modules:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    os.environ["XLA_FLAGS"] = merge_xla_flags(flags, tp)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="concurrent decode slots")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32,
                    help="decode tokens per request")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--requests", type=int, default=0,
                    help="total requests (paged mode; default 2x batch)")
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-budget", type=parse_prefill_budget,
                    default=None,
                    help="prefill token budget per engine step (chunked "
                         "prefill, Sarathi-style): an int, 'none' "
                         "(unbounded, the default) or 'adaptive' "
                         "(derived from the decode batch's SLA headroom)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable shared-prefix page reuse")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="max prompt-lookup draft tokens verified per "
                         "decode step (0 = no speculation)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy argmax)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="top-k truncation (0 = disabled)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus truncation (1.0 = disabled)")
    ap.add_argument("--repetition-penalty", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0,
                    help="base sampling seed (request i uses seed + i)")
    ap.add_argument("--n", type=int, default=1,
                    help="parallel samples per request (a sequence group "
                         "fans n branches out of one prefill over COW "
                         "forks; branch b samples under "
                         "branch_seed(seed, b))")
    ap.add_argument("--best-of", type=int, default=None,
                    help="branches sampled per request (>= n); the n "
                         "best by length-normalized cumulative logprob "
                         "are returned")
    ap.add_argument("--beam-width", type=int, default=0,
                    help="> 0: length-normalized beam search with this "
                         "many beams (deterministic; temperature must "
                         "stay 0; returns the n best hypotheses)")
    ap.add_argument("--length-penalty", type=float, default=1.0,
                    help="score = cum_logprob / len**length_penalty "
                         "(1.0 = mean logprob, 0 = raw sum)")
    ap.add_argument("--kv-codec", choices=("fp", "int8", "log16"),
                    default="fp",
                    help="paged KV page codec: 'fp' stores raw "
                         "compute-dtype rows, 'int8' per-row absmax "
                         "quantization with an f32 scale sidecar (~4x "
                         "fewer pool bytes/token), 'log16' 16-bit "
                         "log-domain rows on the HFA rail (2x)")
    ap.add_argument("--logprobs", action="store_true",
                    help="return per-token logprobs: prompt positions "
                         "(full-position LM head during prefill) and "
                         "every generated token")
    ap.add_argument("--tp", type=int, default=1,
                    help="tensor-parallel shards: KV-head-shard the paged "
                         "pools over a 'model' mesh axis (CPU simulates "
                         "the mesh via XLA_FLAGS="
                         "--xla_force_host_platform_device_count)")
    ap.add_argument("--dense", action="store_true",
                    help="legacy fixed-batch loop over a contiguous cache")
    args = ap.parse_args()
    if args.tp < 1:
        ap.error("--tp must be >= 1")
    if args.n < 1:
        ap.error("--n must be >= 1")
    if args.beam_width > 0 and args.temperature > 0:
        ap.error("beam search is deterministic: --temperature must be 0")
    if args.beam_width > 0 and args.best_of is not None:
        ap.error("--best-of is a parallel-sampling knob, incompatible "
                 "with --beam-width")
    width = args.beam_width or (args.best_of or args.n)
    if width > args.batch:
        ap.error(f"group width {width} exceeds --batch {args.batch}")
    ensure_host_devices(args.tp)

    import jax

    from repro.configs import get_config
    from repro.data import DataPipeline
    from repro.models.model import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = DataPipeline.for_config(cfg, args.prompt_len, args.batch)
    batch = pipe.batch(0)

    if args.dense or not _paged_supported(cfg):
        if args.tp > 1:
            raise SystemExit("--tp requires the paged serving path")
        if not args.dense:
            print(f"note: {cfg.name} (family={cfg.family}, "
                  f"pos_emb={cfg.pos_emb}) is not paged-servable yet; "
                  "falling back to the dense fixed-batch loop")
        _serve_dense(model, params, cfg, batch, args)
        return

    from repro.serving import Request, SamplingParams, ServingEngine

    mesh = None
    if args.tp > 1:
        from repro.launch.mesh import make_tp_mesh
        mesh = make_tp_mesh(args.tp)

    n_req = args.requests or 2 * args.batch
    prompts = np.concatenate(
        [pipe.batch(s)["tokens"] for s in range((n_req + args.batch - 1)
                                                // args.batch)])[:n_req]
    engine = ServingEngine(model, params, max_batch=args.batch,
                           page_size=args.page_size, max_seq=args.max_seq,
                           prefill_budget=args.prefill_budget,
                           prefix_caching=not args.no_prefix_cache,
                           spec_k=args.spec_k, mesh=mesh,
                           kv_codec=args.kv_codec)
    # one new arrival per step: requests join and leave mid-flight
    arrivals = [(i, Request(rid=i, prompt=prompts[i].tolist(),
                            max_new_tokens=args.steps,
                            sampling=SamplingParams(
                                temperature=args.temperature,
                                top_k=args.top_k, top_p=args.top_p,
                                repetition_penalty=args.repetition_penalty,
                                seed=args.seed + i),
                            n=args.n, best_of=args.best_of,
                            beam_width=args.beam_width,
                            length_penalty=args.length_penalty,
                            logprobs=args.logprobs))
                for i in range(n_req)]
    t0 = time.perf_counter()
    finished = engine.run(arrivals)
    dt = time.perf_counter() - t0
    engine.cache.check_invariants()
    st = engine.stats
    print(f"served {len(finished)} requests in {st['steps']} steps "
          f"({st['prefill_chunks']} prefill chunks, "
          f"{st['preemptions']} preemptions, page_size={args.page_size})")
    print(f"prefill: {st['prefill_tokens']} tokens computed, "
          f"{st['cached_prefill_tokens']} reused from prefix cache")
    print(f"generated {st['generated_tokens']} tokens in {dt:.2f} s "
          f"-> {st['generated_tokens']/dt:.1f} tok/s")
    print(f"kv codec {engine.kv_codec}: pool {engine.pool_bytes()} B, "
          f"{engine.bytes_per_token()} B/token")
    if args.logprobs:
        fr = finished[0]
        plp = [f"{x:+.2f}" if x is not None else "None"
               for x in (fr.prompt_logprobs or [])[:6]]
        tlp = [f"{x:+.2f}" for x in (fr.token_logprobs or [])[:6]]
        print(f"logprobs rid {fr.rid}: prompt {plp} tokens {tlp}")
    if args.tp > 1:
        print(f"tp={args.tp}: pool {engine.pool_bytes()} B total, "
              f"{engine.pool_bytes_per_shard()} B/shard; "
              f"ACC-merge triplet traffic {st['triplet_bytes']} B")
    if args.spec_k:
        rate = st["draft_accepted"] / max(st["draft_tokens"], 1)
        tps = st["decode_tokens"] / max(st["decode_slot_steps"], 1)
        print(f"speculation: {st['draft_accepted']}/{st['draft_tokens']} "
              f"drafts accepted ({rate:.0%}), "
              f"{tps:.2f} accepted tokens/step, "
              f"{st['rollbacks']} rollbacks")
    if st["groups"]:
        kind = f"beam-{args.beam_width}" if args.beam_width \
            else f"n={args.n}" + (f"/best-of-{args.best_of}"
                                  if args.best_of else "")
        print(f"sequence groups ({kind}): {st['groups']} groups, "
              f"{st['forks']} COW forks (zero KV copied at fork)")
        best = finished[0]
        if best.completions:
            for c in best.completions[:4]:
                print(f"  rid {best.rid} branch {c.branch} "
                      f"score {c.score:+.3f}: {c.tokens[:10]}")
    print("sample:", finished[0].tokens[:12])


def _paged_supported(cfg) -> bool:
    """Archs the paged engine can serve today: rope-positioned,
    attention-only stacks with token-only prompts (no Mamba per-slot
    state, no encoder cross caches, no patch/frame prefixes)."""
    return (cfg.pos_emb == "rope"
            and all(k == "attn" for k in cfg.layer_kinds())
            and cfg.family not in ("encdec", "vlm"))


def _serve_dense(model, params, cfg, batch, args):
    """Legacy path: one fixed batch, dense contiguous KV cache."""
    import jax
    import jax.numpy as jnp
    prompts = jnp.asarray(batch["tokens"])

    enc_out = None
    if cfg.family == "encdec":
        frames = jnp.asarray(batch["frames"])
        enc_out = model._encode(params, frames, jnp.float32)

    cache = model.init_cache(params, args.batch, args.max_seq,
                             enc_out=enc_out)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, cache = prefill(params, cache, prompts)
    jax.block_until_ready(logits)
    print(f"prefill: {1e3*(time.perf_counter()-t0):.1f} ms "
          f"({args.batch}x{args.prompt_len})")

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.perf_counter()
    toks = []
    for _ in range(args.steps):
        toks.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    jax.block_until_ready(logits)
    dt = (time.perf_counter() - t0) / args.steps
    print(f"decode: {dt*1e3:.2f} ms/token; "
          f"throughput {args.batch/dt:.1f} tok/s")
    print("sample:", np.concatenate(toks, 1)[0][:12])


if __name__ == "__main__":
    main()
