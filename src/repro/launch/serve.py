"""Production serving entry point: batched continuous decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --batch 4 --steps 64
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--steps", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.data import DataPipeline
    from repro.models.model import build_model

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = DataPipeline.for_config(cfg, args.prompt_len, args.batch)
    batch = pipe.batch(0)
    prompts = jnp.asarray(batch["tokens"])

    enc_out = None
    if cfg.family == "encdec":
        frames = jnp.asarray(batch["frames"])
        enc_out = model._encode(params, frames, jnp.float32)

    cache = model.init_cache(params, args.batch, args.max_seq,
                             enc_out=enc_out)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, cache = prefill(params, cache, prompts)
    jax.block_until_ready(logits)
    print(f"prefill: {1e3*(time.perf_counter()-t0):.1f} ms "
          f"({args.batch}x{args.prompt_len})")

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    t0 = time.perf_counter()
    toks = []
    for _ in range(args.steps):
        toks.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    jax.block_until_ready(logits)
    dt = (time.perf_counter() - t0) / args.steps
    print(f"decode: {dt*1e3:.2f} ms/token; "
          f"throughput {args.batch/dt:.1f} tok/s")
    print("sample:", np.concatenate(toks, 1)[0][:12])


if __name__ == "__main__":
    main()
