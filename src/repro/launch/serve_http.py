"""HTTP/SSE serving entry point: the network transport over the
asyncio streaming front-end.

Starts :class:`repro.serving.http.HttpServer` (raw-asyncio HTTP/1.1 +
Server-Sent Events, no third-party deps) over an
:class:`AsyncFrontend` and serves until interrupted:

  PYTHONPATH=src python -m repro.launch.serve_http --arch qwen3-1.7b \\
      --reduced --port 8100

  curl -N localhost:8100/v1/generate -H 'x-tenant: alice' \\
      -d '{"prompt": [1, 2, 3], "max_new_tokens": 8}'

``--queue-cap`` bounds per-latency-class admission (429 past the cap):
a bare int applies to every class, or per-class as
``interactive=8,standard=16,batch=64``.  ``--smoke`` binds an
ephemeral port, runs a built-in client (healthz/stats, a greedy and a
sampled+tenant SSE stream, a mid-stream disconnect), checks the paged
pool came back clean, and exits - the CI gate.

Jax is imported only after argument parsing (see
:func:`repro.launch.serve.ensure_host_devices`).
"""
import argparse
import asyncio

from repro.launch.serve import (ensure_host_devices, parse_prefill_budget,
                                _paged_supported)


def parse_queue_caps(s: str):
    """"16" (every class) or "interactive=8,standard=16,batch=64"
    (per-class, unlisted classes keep the default); "none" disables
    the cap parse (server default of 4 x max_batch applies)."""
    s = s.strip()
    if not s or s.lower() == "none":
        return None
    if "=" not in s:
        try:
            return int(s)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"queue cap must be an int or name=int list: {s!r}")
    caps = {}
    for part in s.split(","):
        name, _, v = part.partition("=")
        try:
            caps[name.strip()] = int(v)
        except ValueError:
            raise argparse.ArgumentTypeError(
                f"bad per-class cap {part!r} in {s!r}")
    return caps


async def _smoke_router(server) -> list[str]:
    """--replicas N --smoke extension: spread two concurrent streams
    across replicas (least-loaded fallback), then repeat the first
    prompt and require a prefix-hit route plus token-identical output -
    the multi-replica parity + placement gate.  Every replica's pool
    must come back invariant-clean."""
    from repro.serving.http import stream_generate
    fails = []
    host, port = server.host, server.port
    router = server.frontend

    async def collect(payload):
        toks, done = [], None
        async for kind, data in stream_generate(host, port, payload):
            if kind == "token":
                toks.append(data["token"])
            elif kind == "done":
                done = data
            else:
                fails.append(f"router stream error: {data}")
        return toks, done

    pa = {"prompt": list(range(1, 33)), "max_new_tokens": 12}
    pb = {"prompt": list(range(40, 56)), "max_new_tokens": 8}
    # Hold stream A open past its first token so B's placement sees a
    # loaded replica 0 and falls back to replica 1.
    gen_a = stream_generate(host, port, pa)
    toks_a = []
    async for kind, data in gen_a:
        if kind == "token":
            toks_a.append(data["token"])
            break
    _toks_b, done_b = await collect(pb)
    async for kind, data in gen_a:
        if kind == "token":
            toks_a.append(data["token"])
        elif kind == "done":
            if data["tokens"] != toks_a:
                fails.append(f"stream A tokens {toks_a} != {data['tokens']}")
    await gen_a.aclose()
    if done_b is None or done_b["reason"] not in ("eos", "length"):
        fails.append(f"stream B: done={done_b}")
    # Repeat prompt A: must prefix-route to A's replica and reproduce
    # A's token stream exactly (per-request determinism + shared KV).
    toks_a2, done_a2 = await collect(pa)
    if done_a2 is None or toks_a2 != toks_a:
        fails.append(f"repeat of A not token-identical: "
                     f"{toks_a2} != {toks_a}")
    await router.drain()
    if router.stats["prefix_routed"] < 1:
        fails.append(f"no prefix-hit route: {router.stats}")
    stepped = [fe.engine.stats["steps"] > 0 for fe in router.frontends]
    if not all(stepped):
        fails.append(f"replica(s) never stepped: {stepped}")
    if router.core.placement or any(router.core.load):
        fails.append(f"router leaked placements: {router.core.placement} "
                     f"load={router.core.load}")
    for i, fe in enumerate(router.frontends):
        fe.engine.cache.check_invariants()
        if fe.engine.cache.available_page_count != \
                fe.engine.cache.num_pages:
            fails.append(f"replica {i} leaked pages")
    return fails


async def _smoke_client(server, cfg) -> list[str]:
    """The --smoke self-test: drive the server over real sockets the
    way the conformance tests do; returns a list of failures."""
    from repro.serving.http import http_json, stream_generate
    fails = []
    host, port = server.host, server.port

    status, health = await http_json(host, port, "GET", "/healthz")
    if status != 200 or health.get("status") != "ok":
        fails.append(f"healthz: {status} {health}")

    prompt = list(range(1, 9))
    toks = []
    done = None
    async for kind, data in stream_generate(
            host, port, {"prompt": prompt, "max_new_tokens": 8,
                         "latency_class": "interactive"}):
        if kind == "token":
            toks.append(data["token"])
        elif kind == "done":
            done = data
        else:
            fails.append(f"greedy stream error: {data}")
    if done is None or done["tokens"] != toks or len(toks) == 0:
        fails.append(f"greedy stream: {len(toks)} tokens, done={done}")

    done = None
    async for kind, data in stream_generate(
            host, port, {"prompt": prompt, "max_new_tokens": 6,
                         "temperature": 0.8, "top_k": 8, "seed": 7},
            tenant="smoke-tenant"):
        if kind == "done":
            done = data
    if done is None or done["reason"] not in ("eos", "length"):
        fails.append(f"sampled stream: done={done}")

    # Mid-stream disconnect: close after 2 tokens; the server must
    # cancel the request and free its slot/pages.
    gen = stream_generate(host, port,
                          {"prompt": prompt, "max_new_tokens": 64})
    got = 0
    async for kind, _data in gen:
        if kind == "token":
            got += 1
            if got >= 2:
                break
    await gen.aclose()
    engine = server.frontend.engine
    for _ in range(500):
        if engine.stats["cancelled"] >= 1:
            break
        await asyncio.sleep(0.01)
    await server.frontend.drain()
    engine.cache.check_invariants()
    if engine.stats["cancelled"] < 1:
        fails.append("disconnect did not cancel the request")
    if engine.cache.available_page_count != engine.cache.num_pages:
        fails.append("disconnect leaked pages")

    status, stats = await http_json(host, port, "GET", "/stats")
    if status != 200 or stats.get("engine", {}).get("steps", 0) <= 0:
        fails.append(f"stats: {status} {stats}")
    if stats.get("http", {}).get("disconnects", 0) < 1:
        fails.append(f"stats missed the disconnect: {stats.get('http')}")

    from repro.serving.router import Router
    if isinstance(server.frontend, Router):
        fails.extend(await _smoke_router(server))
    return fails


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4,
                    help="concurrent decode slots")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--prefill-budget", type=parse_prefill_budget,
                    default="adaptive",
                    help="int, 'none', or 'adaptive' (default)")
    ap.add_argument("--spec-k", type=int, default=0)
    ap.add_argument("--kv-codec", choices=("fp", "int8", "log16"),
                    default="fp", help="paged KV page codec")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--dp", type=int, default=1,
                    help="data-parallel degree: batch-shard every paged "
                         "attention call over a 'data' mesh axis "
                         "(simulated on CPU via "
                         "xla_force_host_platform_device_count)")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve N independent engine replicas behind a "
                         "prefix-cache-aware router")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8100,
                    help="listen port (0 = kernel-assigned)")
    ap.add_argument("--queue-cap", type=parse_queue_caps, default=None,
                    help="per-class admission cap before 429: an int "
                         "for every class or "
                         "interactive=8,standard=16,batch=64 "
                         "(default: 4 x --batch)")
    ap.add_argument("--stream-buffer", type=int, default=1024,
                    help="per-stream token queue bound; a reader "
                         "stalled this many tokens behind is treated "
                         "as disconnected and cancelled")
    ap.add_argument("--max-results", type=int, default=1024,
                    help="unclaimed FinishedRequest LRU bound")
    ap.add_argument("--drain-timeout", type=float, default=30.0,
                    help="seconds a client may stall a socket write "
                         "before the connection is dropped")
    ap.add_argument("--smoke", action="store_true",
                    help="bind an ephemeral port, run the built-in "
                         "client self-test, and exit")
    args = ap.parse_args()
    if isinstance(args.queue_cap, str):
        args.queue_cap = parse_queue_caps(args.queue_cap)
    if args.replicas < 1:
        raise SystemExit(f"--replicas must be >= 1, got {args.replicas}")
    ensure_host_devices(args.tp * args.dp)

    import jax

    from repro.configs import get_config
    from repro.models.model import build_model
    from repro.serving import AsyncFrontend, ServingEngine
    from repro.serving.http import HttpServer
    from repro.serving.router import Router

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if not _paged_supported(cfg):
        raise SystemExit(f"{cfg.name} is not paged-servable; the HTTP "
                         "front-end has no dense fallback")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = None
    if args.tp > 1 or args.dp > 1:
        from repro.launch.mesh import make_tp_dp_mesh
        mesh = make_tp_dp_mesh(args.tp, args.dp)
    engines = [ServingEngine(model, params, max_batch=args.batch,
                             page_size=args.page_size,
                             max_seq=args.max_seq,
                             prefill_budget=args.prefill_budget,
                             spec_k=args.spec_k, mesh=mesh,
                             kv_codec=args.kv_codec)
               for _ in range(args.replicas)]
    engine = engines[0]

    async def run() -> int:
        frontends = [AsyncFrontend(e,
                                   stream_buffer=args.stream_buffer,
                                   max_results=args.max_results)
                     for e in engines]
        frontend = frontends[0] if args.replicas == 1 \
            else Router(frontends)
        server = HttpServer(frontend, host=args.host,
                            port=0 if args.smoke else args.port,
                            queue_caps=args.queue_cap,
                            drain_timeout=args.drain_timeout)
        await server.start()
        print(f"serving {cfg.name} on http://{server.host}:{server.port} "
              f"(batch {args.batch}, page {args.page_size}, codec "
              f"{engine.kv_codec}, replicas {args.replicas}, "
              f"tp {args.tp} dp {args.dp}, caps {server.queue_caps})")
        try:
            if args.smoke:
                fails = await _smoke_client(server, cfg)
                st = engine.stats
                print(f"smoke: {st['steps']} steps, "
                      f"{st['generated_tokens']} tokens, "
                      f"{st['cancelled']} cancelled, "
                      f"{server.http_stats['streams']} streams")
                for f in fails:
                    print("SMOKE FAIL:", f)
                print("smoke:", "FAIL" if fails else "OK")
                return 1 if fails else 0
            await asyncio.Event().wait()      # serve until interrupted
            return 0
        finally:
            await server.stop()
            for fe in frontends:
                if not fe.closed:
                    await fe.close()

    try:
        raise SystemExit(asyncio.run(run()))
    except KeyboardInterrupt:
        print("interrupted; shut down")


if __name__ == "__main__":
    main()
