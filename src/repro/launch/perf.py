import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf hillclimb driver (EXPERIMENTS.md §Perf).

Runs named variants of the three hillclimb cells, re-deriving the roofline
terms per variant.  Each record lands in experiments/artifacts/perf/.

  python -m repro.launch.perf --cell A --variant mb1
  python -m repro.launch.perf --list
"""
import argparse
import json
import time
import traceback

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../experiments/artifacts/perf")

# cell id -> (arch, shape)
CELLS = {
    "A": ("command-r-plus-104b", "train_4k"),    # most collective-bound
    "B": ("qwen1.5-4b", "decode_32k"),           # worst cell: 25.7 GiB/dev,
                                                 # memory-bound ring rewrite,
                                                 # kv=20 unshardable heads
    "C": ("command-r-plus-104b", "decode_32k"),  # paper-representative
    "B2": ("qwen3-1.7b", "decode_32k"),          # earlier iteration kept
}

# variant name -> {"cfg": {...}, "rules": {...}}
VARIANTS = {
    "baseline": {},
    # A: gradient-accumulation count scales the per-step FSDP weight
    # all-gather volume linearly; fewer microbatches -> fewer gathers.
    "mb1": {"cfg": {"microbatches": 1}},
    "mb2": {"cfg": {"microbatches": 2}},
    # A: no remat: trades recompute flops/bytes for activation memory.
    "noremat_mb2": {"cfg": {"microbatches": 2, "remat": "none"}},
    # A: remat without sequence parallelism (isolate SP's contribution).
    "no_sp": {"rules": {"seq": None}},
    # B: serving a model whose weights fit per-device: replicate over
    # "data" instead of FSDP - removes the per-token weight all-gather.
    "serve_repl_weights": {"rules": {"fsdp": None}},
    # B/C: paper's ACC merge via shard_map: local ring write (no full-ring
    # rewrite) + partial FAU + log-domain (m, l, o~) merge.
    "shardmap_merge": {"cfg": {"serve_attn": "shardmap_merge"}},
    # C: combine both serving optimizations where weights allow.
    "shardmap_merge_repl": {"cfg": {"serve_attn": "shardmap_merge"},
                            "rules": {"fsdp": None}},
    # C: weight-stationary decode: replicate tiny activations over "data"
    # so XLA psums (B,1,H,dh) partials instead of all-gathering the
    # d-sharded weights (cache stays batch+seq sharded via kv_batch).
    "serve_weight_stationary": {"rules": {"batch": None}},
    "ws_shardmap": {"cfg": {"serve_attn": "shardmap_merge"},
                    "rules": {"batch": None}},
}


def run_variant(cell: str, variant: str, save=True) -> dict:
    import jax

    from repro.analysis import roofline as rl
    from repro.configs import get_config
    from repro.launch import dryrun
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import SHAPES, build_cell

    arch, shape = CELLS[cell]
    cfg = get_config(arch)
    mesh = make_production_mesh()
    spec = VARIANTS[variant]
    record = {"cell": cell, "arch": arch, "shape": shape, "variant": variant,
              "spec": spec}
    t0 = time.time()
    try:
        fn, args, in_sh, out_sh, meta = build_cell(cfg, shape, mesh,
                                                   variant=spec)
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh).lower(*args).compile()
        ma = compiled.memory_analysis()
        record["memory_gib"] = round((ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes) / 2**30, 2)
        record["compile_seconds"] = round(time.time() - t0, 1)

        # Probe-corrected cost for the variant.
        vcfg = meta["cfg"]
        probes = dryrun.cost_probes(vcfg, shape, mesh,
                                    rules=spec.get("rules"))
        per = probes["per_step"]
        p2 = probes.get("probe_2group", {})
        per = {k: max(v, p2.get(k, 0.0)) for k, v in per.items()}
        record["per_step"] = per
        record["terms"] = {
            "compute_s": per.get("flops", 0.0) / rl.PEAK_FLOPS,
            "memory_s": per.get("bytes accessed", 0.0) / rl.HBM_BW,
            "collective_s": per.get("collective_bytes", 0.0) / rl.LINK_BW,
        }
        record["dominant"] = max(record["terms"], key=record["terms"].get)
        mode, seq, batch = SHAPES[shape]
        mf = rl.model_flops(vcfg, mode, seq, batch)
        step = max(record["terms"].values())
        record["roofline_fraction"] = (
            mf / 256 / rl.PEAK_FLOPS / step if step else 0.0)
        record["status"] = "ok"
    except Exception:
        record["status"] = "error"
        record["error"] = traceback.format_exc()[-2000:]
    if save:
        os.makedirs(ARTIFACT_DIR, exist_ok=True)
        with open(os.path.join(ARTIFACT_DIR,
                               f"{cell}__{variant}.json"), "w") as f:
            json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(CELLS))
    ap.add_argument("--variant", choices=list(VARIANTS))
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()
    if args.list:
        for f in sorted(os.listdir(ARTIFACT_DIR)):
            r = json.load(open(os.path.join(ARTIFACT_DIR, f)))
            print(f, r["status"], r.get("terms"), r.get("memory_gib"))
        return
    r = run_variant(args.cell, args.variant)
    print(json.dumps({k: v for k, v in r.items() if k != "per_step"},
                     indent=1, default=str))


if __name__ == "__main__":
    main()
