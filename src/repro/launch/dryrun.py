import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: the SPMD
partitioner must accept every sharding, the compiled module must fit the
per-device HBM budget, and the collective schedule is captured for the
roofline analysis (EXPERIMENTS.md §Dry-run / §Roofline).

Usage:
  python -m repro.launch.dryrun --arch minitron-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # run every missing cell
  python -m repro.launch.dryrun --all --mesh multi

Each cell writes experiments/artifacts/dryrun/<arch>__<shape>__<mesh>.json.
"""
import argparse
import json
import re
import subprocess
import sys
import time
import traceback

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__),
                            "../../../experiments/artifacts/dryrun")

COLLECTIVE_RE = re.compile(
    r"(\w[\w.-]*)\s*=\s*((?:\(.*?\))|(?:\S+))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|pred|s64|f64|u16|s16)"
                      r"\[([\d,]*)\]")

DTYPE_BYTES = {"f64": 8, "s64": 8, "f32": 4, "s32": 4, "u32": 4, "bf16": 2,
               "f16": 2, "u16": 2, "s16": 2, "s8": 1, "u8": 1, "pred": 1}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for m in SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum per-device output bytes of every collective op in the SPMD module."""
    per_kind: dict[str, dict[str, float]] = {}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(
            r"%?[\w.-]+\s*=\s*(.+?)\s*"
            r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
            r"collective-permute)(?:-start|-done)?\(", line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        if line.split("=")[1].lstrip().startswith(("all-", "reduce-",
                                                   "collective-")):
            # form: %x = all-gather-done(...) without a type annotation
            continue
        b = _shape_bytes(type_str)
        d = per_kind.setdefault(kind, {"count": 0, "bytes": 0})
        d["count"] += 1
        d["bytes"] += b
    total = sum(d["bytes"] for d in per_kind.values())
    return {"per_kind": per_kind, "total_bytes": total}


def _linear_combine(base: dict, delta: dict, n: int) -> dict:
    out = {}
    for k in set(base) | set(delta):
        b, d = base.get(k, 0.0), delta.get(k, 0.0)
        out[k] = b + n * d
    return out


def cost_probes(cfg, shape: str, mesh, rules=None) -> dict:
    """Extrapolated whole-step cost: HLO cost analysis counts while-loop
    bodies once, so we lower UNROLLED 1-group and 2-group variants (with
    single-block attention) and fit cost = a + groups * b.  The correction
    covers flops / bytes / transcendentals and per-kind collective bytes.
    The tiny mamba inter-chunk state recurrence remains undercounted
    (~1e-4 of total, noted in EXPERIMENTS.md)."""
    import dataclasses

    import jax

    from repro.launch.specs import SHAPES, build_cell
    from repro.models.transformer import period_pattern

    mode, seq, batch = SHAPES[shape]
    _, _, period = period_pattern(cfg)
    groups = cfg.n_layers // period
    enc_groups = cfg.n_enc_layers if cfg.family == "encdec" else 0

    def mk(dg, eg):
        c = dataclasses.replace(
            cfg, n_layers=period * dg, scan_layers=False,
            unroll_microbatches=True,
            attn_block=seq if mode != "decode" else cfg.attn_block,
            remat=cfg.remat)
        if cfg.family == "encdec":
            c = dataclasses.replace(c, n_enc_layers=eg)
        return c

    def run(c):
        fn, args, in_sh, out_sh, _ = build_cell(
            c, shape, mesh, variant={"rules": rules} if rules else None)
        with mesh:
            compiled = jax.jit(fn, in_shardings=in_sh,
                               out_shardings=out_sh).lower(*args).compile()
        ca = {k: float(v) for k, v in (compiled.cost_analysis() or {}).items()
              if k in ("flops", "bytes accessed", "transcendentals")}
        colls = parse_collectives(compiled.as_text())
        flat_colls = {f"{kind}_bytes": v["bytes"]
                      for kind, v in colls["per_kind"].items()}
        flat_colls["collective_bytes"] = colls["total_bytes"]
        return {**ca, **flat_colls}

    a = run(mk(1, 1))
    b = run(mk(2, 1))
    delta = {k: b.get(k, 0.0) - a.get(k, 0.0) for k in set(a) | set(b)}
    total = _linear_combine(a, delta, groups - 1)
    if enc_groups > 1:
        c = run(mk(1, 2))
        delta_e = {k: c.get(k, 0.0) - a.get(k, 0.0) for k in set(a) | set(c)}
        total = _linear_combine(total, delta_e, enc_groups - 1)
    return {"per_step": total, "probe_1group": a, "probe_2group": b}


def run_cell(arch: str, shape: str, mesh_kind: str, save: bool = True,
             probes: bool = True) -> dict:
    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_production_mesh
    from repro.launch.specs import SHAPES, build_cell, shape_applicable

    cfg = get_config(arch)
    ok, reason = shape_applicable(cfg, shape)
    record = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "status": None, "reason": reason,
    }
    if not ok:
        record["status"] = "skipped"
        if save:
            _save(record)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    fn, args, in_sh, out_sh, meta = build_cell(cfg, shape, mesh)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)

    record.update({
        "status": "ok",
        "mode": meta["mode"],
        "seq": meta["seq"],
        "global_batch": meta["batch"],
        "devices": int(len(mesh.devices.flatten())),
        "lower_seconds": round(t_lower, 2),
        "compile_seconds": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_per_device_bytes": (ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
        },
        "cost": {k: float(v) for k, v in ca.items()
                 if k in ("flops", "bytes accessed", "transcendentals")},
        "collectives": colls,
        "hlo_bytes": len(hlo),
    })
    if probes and mesh_kind == "single":
        t1 = time.time()
        try:
            record["cost_corrected"] = cost_probes(cfg, shape, mesh)
            record["probe_seconds"] = round(time.time() - t1, 2)
        except Exception:
            record["cost_corrected"] = {"error": traceback.format_exc()[-1500:]}
    if save:
        _save(record)
    return record


def _save(record: dict):
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    name = f"{record['arch']}__{record['shape']}__{record['mesh']}.json"
    with open(os.path.join(ARTIFACT_DIR, name), "w") as f:
        json.dump(record, f, indent=1)


def run_all(mesh_kinds: list[str], archs=None, shapes=None,
            force: bool = False):
    """Drive every missing cell in a subprocess (isolation + resumability)."""
    from repro.configs import ASSIGNED
    from repro.launch.specs import SHAPES

    archs = archs or ASSIGNED
    shapes = shapes or list(SHAPES)
    results = []
    for mesh_kind in mesh_kinds:
        for arch in archs:
            for shape in shapes:
                name = f"{arch}__{shape}__{mesh_kind}.json"
                path = os.path.join(ARTIFACT_DIR, name)
                if os.path.exists(path) and not force:
                    results.append(json.load(open(path)))
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh_kind]
                print(f"[dryrun] {arch} x {shape} x {mesh_kind} ...",
                      flush=True)
                t0 = time.time()
                proc = subprocess.run(cmd, capture_output=True, text=True)
                dt = time.time() - t0
                if proc.returncode != 0 or not os.path.exists(path):
                    print(proc.stdout[-2000:])
                    print(proc.stderr[-4000:])
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "status": "error",
                           "reason": proc.stderr[-1500:]}
                    _save(rec)
                    results.append(rec)
                else:
                    rec = json.load(open(path))
                    results.append(rec)
                    print(f"  ok in {dt:.1f}s  compile={rec.get('compile_seconds')}s "
                          f"temp={rec.get('memory', {}).get('temp_bytes', 0)/2**30:.2f}GiB",
                          flush=True)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.all:
        kinds = [args.mesh] if args.mesh else ["single", "multi"]
        results = run_all(kinds, force=args.force,
                          archs=[args.arch] if args.arch else None,
                          shapes=[args.shape] if args.shape else None)
        bad = [r for r in results if r["status"] == "error"]
        print(f"\n{len(results)} cells: "
              f"{sum(r['status'] == 'ok' for r in results)} ok, "
              f"{sum(r['status'] == 'skipped' for r in results)} skipped, "
              f"{len(bad)} errors")
        sys.exit(1 if bad else 0)

    record = run_cell(args.arch, args.shape, args.mesh)
    print(json.dumps({k: v for k, v in record.items() if k != "hlo"},
                     indent=1))
    if record["status"] == "ok":
        print(f"memory per device: "
              f"{record['memory']['peak_per_device_bytes']/2**30:.2f} GiB")


if __name__ == "__main__":
    main()
