"""AdamW with fp32 moments (optionally fp32 master weights for bf16 params).

API (optax-like but dependency-free):
  opt = adamw(schedule)
  state = opt.init(params)
  params, state = opt.update(grads, state, params)

Moments are stored fp32 and shard like their parameters (ZeRO-style when
the parameter itself is sharded over the full mesh).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]
    state_logical: Callable[[Any], Any]  # logical axes for the state tree


def adamw(lr_schedule, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          master_fp32=True):
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.int32(0),
        }
        if master_fp32:
            state["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return state

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_schedule(step)
        b1c = 1.0 - b1 ** step.astype(jnp.float32)
        b2c = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p, p_master):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            base = p_master if p_master is not None else p.astype(jnp.float32)
            new = base - lr * (mhat / (jnp.sqrt(vhat) + eps)
                               + weight_decay * base)
            return new, m, v

        master = state.get("master")
        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state["m"])
        flat_v = tdef.flatten_up_to(state["v"])
        flat_p = tdef.flatten_up_to(params)
        flat_mast = (tdef.flatten_up_to(master) if master is not None
                     else [None] * len(flat_g))
        outs = [upd(g, m, v, p, pm) for g, m, v, p, pm in
                zip(flat_g, flat_m, flat_v, flat_p, flat_mast)]
        new_p32 = tdef.unflatten([o[0] for o in outs])
        new_state = {
            "m": tdef.unflatten([o[1] for o in outs]),
            "v": tdef.unflatten([o[2] for o in outs]),
            "step": step,
        }
        if master is not None:
            new_state["master"] = new_p32
        new_params = jax.tree.map(
            lambda n, p: n.astype(p.dtype), new_p32, params)
        return new_params, new_state

    def state_logical(param_logical):
        out = {"m": param_logical, "v": param_logical, "step": ()}
        if master_fp32:
            out["master"] = param_logical
        return out

    return Optimizer(init, update, state_logical)
