"""Int8 gradient compression with error feedback.

At cluster scale this wraps the data-parallel gradient all-reduce: each
worker quantizes (grad + carried error) to int8 with a per-tensor scale,
the all-reduce runs on the 4x-smaller payload, and the quantization error
is fed back into the next step (Seide et al. / 1-bit SGD family, int8
variant).  The compression math is exact here; the collective itself is
XLA's. ``error`` state shards like the gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_gradients(grads, error):
    """Returns (dequantized int8 grads, new error feedback state)."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        amax = jnp.max(jnp.abs(g))
        scale = jnp.where(amax > 0, amax / 127.0, 1.0)
        q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq, g - deq
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (tdef.unflatten([o[0] for o in outs]),
            tdef.unflatten([o[1] for o in outs]))
