"""Adafactor (Shazeer & Stern 2018): factored second moments.

Used for the >=100B assigned archs: state is ~2 fp32 vectors per matrix
instead of two full fp32 tensors (O(n+m) vs O(nm)), keeping per-device
optimizer bytes within the v5e HBM budget at 256 chips (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.adamw import Optimizer


def adafactor(lr_schedule, decay=0.8, eps1=1e-30, eps2=1e-3,
              clip_threshold=1.0, weight_decay=0.0):
    def _factored(shape):
        return len(shape) >= 2

    def init(params):
        def one(p):
            if _factored(p.shape):
                row = jnp.zeros(p.shape[:-1], jnp.float32)
                col = jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)
                return {"vr": row, "vc": col}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"f": jax.tree.map(one, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.int32(0)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = lr_schedule(step)
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-decay)

        def one(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps1
            if _factored(p.shape):
                vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                u = (g / jnp.sqrt(jnp.maximum(vr[..., None] / denom[..., None],
                                              eps1))
                     / jnp.sqrt(jnp.maximum(vc[..., None, :], eps1)))
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                u = g / jnp.sqrt(jnp.maximum(v, eps1))
                new_st = {"v": v}
            rms_u = jnp.sqrt(jnp.mean(u * u) + eps1)
            u = u / jnp.maximum(1.0, rms_u / clip_threshold)
            pf = p.astype(jnp.float32)
            scale = jnp.maximum(eps2, jnp.sqrt(jnp.mean(pf * pf)))
            new_p = pf - lr * scale * u - lr * weight_decay * pf
            return new_p.astype(p.dtype), new_st

        flat_g, tdef = jax.tree.flatten(grads)
        flat_s = tdef.flatten_up_to(state["f"])
        flat_p = tdef.flatten_up_to(params)
        outs = [one(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_state = {"f": tdef.unflatten([o[1] for o in outs]), "step": step}
        return new_params, new_state

    def state_logical(param_logical):
        def one(axes):
            if isinstance(axes, tuple) and len(axes) >= 2:
                return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
            return {"v": axes}
        is_leaf = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        return {"f": jax.tree.map(one, param_logical, is_leaf=is_leaf),
                "step": ()}

    return Optimizer(init, update, state_logical)
