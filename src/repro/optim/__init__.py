"""Optimizers + schedules + gradient compression (no external deps)."""
from repro.optim.adamw import adamw  # noqa: F401
from repro.optim.adafactor import adafactor  # noqa: F401
from repro.optim.schedule import warmup_cosine  # noqa: F401
from repro.optim.compression import compress_gradients  # noqa: F401


def build_optimizer(cfg, lr_schedule):
    """Optimizer per the arch config (adafactor for the >=100B archs)."""
    if cfg.optimizer == "adafactor":
        return adafactor(lr_schedule)
    # fp32 master copies only when params are actually low precision
    # (an fp32 master of fp32 params would alias the donated param buffer).
    return adamw(lr_schedule, master_fp32=(cfg.param_dtype != "float32"))
