#!/usr/bin/env python
"""Docs-consistency check: every file path, `repro.*` module reference,
markdown link target, and CLI flag mentioned in README.md, ROADMAP.md,
and docs/*.md must actually exist in the tree.

Docs that drift from the code are worse than no docs - this runs in CI
(see .github/workflows/ci.yml) so a rename or flag removal that leaves
a stale reference behind fails the build with a precise list.

Checks, per scanned document:

  * repo-rooted paths (src/... tests/... benchmarks/... docs/...
    examples/... tools/... .github/...) with a file extension -> must
    exist as a file; rooted directory refs ending in "/" -> must exist
    as a directory;
  * dotted module refs (repro.foo.bar[.attr...]) -> the longest module
    prefix must resolve under src/, and any trailing attribute must
    appear by name in that module's source;
  * relative markdown link targets -> must resolve from the doc's
    directory;
  * `--flag` tokens -> must be defined by some argparse entry point
    (benchmarks/*.py, src/repro/launch/*.py, tools/*.py) or be on the
    allowlist of external flags (XLA/pytest flags we merely quote).

Usage: python tools/check_docs.py   (exit 0 = consistent)
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCANNED = ["README.md", "ROADMAP.md"]
DOCS_DIR = "docs"

PATH_ROOTS = ("src/", "tests/", "benchmarks/", "docs/", "examples/",
              "tools/", ".github/")
PATH_RE = re.compile(
    r"(?<![\w/.-])((?:src|tests|benchmarks|docs|examples|tools|\.github)"
    r"/[\w./-]+)")
MODULE_RE = re.compile(r"(?<![\w.])repro(?:\.\w+)+")
LINK_RE = re.compile(r"\]\(([^)\s]+)\)")
FLAG_RE = re.compile(r"(?<![\w-])(--[a-zA-Z][\w-]*)")
ARGPARSE_RE = re.compile(r"add_argument\(\s*[\"'](--[\w-]+)[\"']")

# Flags we quote but do not define: external tools' surface.
FLAG_ALLOWLIST = {
    "--xla_force_host_platform_device_count",   # XLA
    "--collect-only", "--ignore",               # pytest (quoted in docs)
}


def _defined_flags() -> set[str]:
    flags = set()
    scan = []
    for d in ("benchmarks", "tools",
              os.path.join("src", "repro", "launch")):
        full = os.path.join(REPO, d)
        scan += [os.path.join(full, f) for f in os.listdir(full)
                 if f.endswith(".py")]
    for path in scan:
        with open(path, encoding="utf-8") as fh:
            flags.update(ARGPARSE_RE.findall(fh.read()))
    return flags


def _check_module(ref: str) -> str | None:
    """Resolve repro.a.b[.attr...]: longest module prefix under src/,
    trailing attribute must appear in the module source."""
    parts = ref.split(".")
    base = os.path.join(REPO, "src")
    depth = 0
    mod_file = None
    for depth in range(len(parts), 0, -1):
        cand = os.path.join(base, *parts[:depth])
        if os.path.isfile(cand + ".py"):
            mod_file = cand + ".py"
            break
        if os.path.isdir(cand) and os.path.isfile(
                os.path.join(cand, "__init__.py")):
            mod_file = os.path.join(cand, "__init__.py")
            break
    if mod_file is None:
        return f"module {ref}: no repro package prefix resolves"
    if depth < len(parts):
        attr = parts[depth]
        with open(mod_file, encoding="utf-8") as fh:
            if not re.search(r"\b%s\b" % re.escape(attr), fh.read()):
                return (f"module {ref}: attribute {attr!r} not found in "
                        f"{os.path.relpath(mod_file, REPO)}")
    return None


def check_file(relpath: str) -> list[str]:
    errors = []
    path = os.path.join(REPO, relpath)
    with open(path, encoding="utf-8") as fh:
        text = fh.read()

    for ref in sorted(set(PATH_RE.findall(text))):
        ref_clean = ref.rstrip(".")          # sentence-final dot
        full = os.path.join(REPO, ref_clean)
        if ref_clean.endswith("/"):
            if not os.path.isdir(full):
                errors.append(f"{relpath}: directory {ref_clean} missing")
        elif "." in os.path.basename(ref_clean):
            if not os.path.isfile(full):
                errors.append(f"{relpath}: file {ref_clean} missing")
        elif not os.path.exists(full):
            errors.append(f"{relpath}: path {ref_clean} missing")

    for ref in sorted(set(MODULE_RE.findall(text))):
        err = _check_module(ref.rstrip("."))
        if err:
            errors.append(f"{relpath}: {err}")

    doc_dir = os.path.dirname(path)
    for target in sorted(set(LINK_RE.findall(text))):
        if target.startswith(("http://", "https://", "#", "mailto:")):
            continue
        rel = target.split("#")[0]
        if rel and not os.path.exists(os.path.join(doc_dir, rel)):
            errors.append(f"{relpath}: markdown link {target} dangling")

    defined = _defined_flags() | FLAG_ALLOWLIST
    for flag in sorted(set(FLAG_RE.findall(text))):
        if flag not in defined:
            errors.append(f"{relpath}: flag {flag} not defined by any "
                          f"entry point")
    return errors


def main() -> int:
    docs = list(SCANNED)
    docs_dir = os.path.join(REPO, DOCS_DIR)
    if os.path.isdir(docs_dir):
        docs += [os.path.join(DOCS_DIR, f)
                 for f in sorted(os.listdir(docs_dir))
                 if f.endswith(".md")]
    errors = []
    for doc in docs:
        if not os.path.isfile(os.path.join(REPO, doc)):
            errors.append(f"{doc}: scanned document itself is missing")
            continue
        errors += check_file(doc)
    if errors:
        print(f"docs-consistency: {len(errors)} stale reference(s):")
        for e in errors:
            print("  " + e)
        return 1
    print(f"docs-consistency: OK ({len(docs)} documents checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
