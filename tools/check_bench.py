#!/usr/bin/env python
"""CI perf-trajectory gate: fresh benchmark smokes vs the committed
``BENCH_serving.json`` baseline.

Runs the table of ``benchmarks/serving.py --smoke --json`` invocations
below (one per workload section), merges their metric dicts, and
compares every metric against the committed baseline under a per-key
tolerance rule:

  * structural metrics (token/page/step/fork counts, accept-rate,
    shared-page fraction, cancellation counts) are *deterministic* for
    the pinned workload seeds -> compared exactly.  A structural drift
    is a behavior change and must be justified by regenerating the
    baseline in the same PR (``--update``);
  * wall-clock metrics (tok/s, TTFT/TPOT percentiles, open-loop step
    counts) vary across runner hardware -> compared under a loose
    multiplicative factor (plus an absolute slack for sub-second
    latencies), one-sided in the direction that means "got worse";
  * ``smoke_ok`` must simply be true - the smoke's own gate already
    failed the run otherwise.

Usage:
  python tools/check_bench.py                 # compare vs baseline
  python tools/check_bench.py --update        # regenerate the baseline
  python tools/check_bench.py --fresh-out f.json   # also keep the fresh
                                                   # run (CI artifact)

Exit 0 = within tolerance.  The committed baseline records the perf
trajectory across PRs: regenerate it (and eyeball the diff) whenever a
change legitimately moves a structural metric.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
BASELINE = os.path.join(REPO, "BENCH_serving.json")
BENCH = os.path.join(REPO, "benchmarks", "serving.py")

# One row per baseline section: (section, extra benchmark args).
# Every row runs `python benchmarks/serving.py --smoke --json <tmp>`.
RUNS = [
    ("shared_prefix", []),
    ("spec_greedy", ["--spec-k", "4"]),
    ("parallel_sample", ["--workload", "parallel-sample", "--n", "4"]),
    ("kv_int8", ["--kv-codec", "int8"]),
    ("open_loop", ["--workload", "open-loop"]),
    ("http_open_loop", ["--workload", "open-loop", "--transport", "http"]),
    ("disagg", ["--disagg"]),
]

# Wall-clock factor: a metric may be this many times worse than the
# committed baseline before the gate trips - wide enough for the spread
# of CI runner hardware, tight enough to catch a real cliff (an
# accidental recompile-per-step, a lost fast path).
TIME_FACTOR = 5.0
ABS_SLACK = 0.5          # seconds, absorbs scheduler jitter on tiny runs
# Open-loop TTFT/TPOT percentiles are tens of ms at smoke scale, so a
# single jit retrace (~1-2s; adaptive-prefill chunk shapes depend on
# wall-clock timing, so the warm run cannot cover them all) landing in
# one request dominates a percentile.  A recompile-per-step cliff still
# trips this comfortably.
OPEN_LOOP_SLACK = 3.0


def rule_for(section: str, key: str):
    """Tolerance rule for one metric: ("exact",) |
    ("latency", factor, slack) - higher is worse |
    ("throughput", factor) - lower is worse |
    ("true",) - must be truthy."""
    if key == "smoke_ok":
        return ("true",)
    if key.startswith(("ttft_", "tpot_")):
        slack = OPEN_LOOP_SLACK \
            if section in ("open_loop", "http_open_loop") else ABS_SLACK
        return ("latency", TIME_FACTOR, slack)
    if key.endswith("_tok_s"):
        return ("throughput", TIME_FACTOR)
    if section in ("open_loop", "http_open_loop") \
            and key in ("steps", "adaptive_budget_last",
                        "preemptions", "cancelled"):
        # Step/cancel interleaving depends on wall-clock arrival timing.
        return ("latency", TIME_FACTOR, ABS_SLACK) if key == "steps" \
            else ("any",)
    return ("exact",)


def check_metric(section, key, base, fresh) -> str | None:
    """None = within tolerance, else a human-readable failure."""
    rule = rule_for(section, key)
    kind = rule[0]
    if kind == "any":
        return None
    if kind == "true":
        return None if fresh else f"{section}.{key}: smoke gate failed"
    if base is None or fresh is None:
        if base is None and fresh is None:
            return None
        return (f"{section}.{key}: baseline={base!r} fresh={fresh!r} "
                f"(one side missing)")
    if kind == "exact":
        if fresh != base:
            return (f"{section}.{key}: {fresh!r} != baseline {base!r} "
                    f"(structural metric - regenerate with --update if "
                    f"intended)")
        return None
    if kind == "latency":
        _, factor, slack = rule
        if fresh > base * factor + slack:
            return (f"{section}.{key}: {fresh:.3f} > {factor:.0f}x "
                    f"baseline {base:.3f} (+{slack}s slack)")
        return None
    if kind == "throughput":
        _, factor = rule
        if fresh < base / factor:
            return (f"{section}.{key}: {fresh:.1f} < baseline "
                    f"{base:.1f} / {factor:.0f}")
        return None
    raise AssertionError(rule)


def run_fresh(tmpdir: str) -> dict:
    """Run every benchmark row, returning {section: metrics}."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    fresh = {}
    for section, extra in RUNS:
        out = os.path.join(tmpdir, f"bench_{section}.json")
        cmd = [sys.executable, BENCH, "--smoke", "--json", out] + extra
        print(f"[check_bench] {section}: {' '.join(cmd[1:])}", flush=True)
        proc = subprocess.run(cmd, env=env, cwd=REPO)
        if proc.returncode != 0:
            raise SystemExit(
                f"check_bench: benchmark row {section!r} exited "
                f"{proc.returncode}")
        with open(out, encoding="utf-8") as fh:
            fresh[section] = json.load(fh)
    return fresh


def compare(baseline: dict, fresh: dict) -> list[str]:
    errors = []
    for section in baseline:
        if section not in fresh:
            errors.append(f"{section}: missing from fresh run")
            continue
        base_m, fresh_m = baseline[section], fresh[section]
        for key in sorted(set(base_m) | set(fresh_m)):
            err = check_metric(section, key, base_m.get(key),
                               fresh_m.get(key))
            if err:
                errors.append(err)
    return errors


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--update", action="store_true",
                    help="regenerate the committed baseline from a "
                         "fresh run instead of comparing")
    ap.add_argument("--fresh-out", default=None, metavar="PATH",
                    help="also write the fresh merged metrics (the CI "
                         "build artifact)")
    args = ap.parse_args()

    import tempfile
    with tempfile.TemporaryDirectory() as tmp:
        fresh = run_fresh(tmp)
    if args.fresh_out:
        with open(args.fresh_out, "w", encoding="utf-8") as fh:
            json.dump(fresh, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"fresh metrics -> {args.fresh_out}")

    if args.update:
        with open(args.baseline, "w", encoding="utf-8") as fh:
            json.dump(fresh, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"baseline regenerated -> {args.baseline}")
        return 0

    if not os.path.isfile(args.baseline):
        print(f"check_bench: no baseline at {args.baseline} "
              f"(run with --update to create it)")
        return 1
    with open(args.baseline, encoding="utf-8") as fh:
        baseline = json.load(fh)
    errors = compare(baseline, fresh)
    if errors:
        print(f"check_bench: {len(errors)} metric(s) out of tolerance:")
        for e in errors:
            print("  " + e)
        return 1
    n = sum(len(m) for m in baseline.values())
    print(f"check_bench: OK ({n} metrics across {len(baseline)} "
          f"sections within tolerance)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
