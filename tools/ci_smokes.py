#!/usr/bin/env python
"""Table-driven CI smoke runner: every end-to-end serve / benchmark
gate is one row in ``SMOKES`` below, not one copy-pasted YAML block in
.github/workflows/ci.yml.  Adding a gate = adding a row.

Each row is (key, description, argv-after-python).  All rows run with
the repo root as cwd and ``src`` on PYTHONPATH; the entry points set
any XLA device-count flags they need themselves (see
repro.launch.serve.ensure_host_devices - a pre-existing XLA_FLAGS is
merged, not clobbered), so no row needs a per-step env block.

Usage:
  python tools/ci_smokes.py                 # run everything
  python tools/ci_smokes.py --list          # show the table
  python tools/ci_smokes.py --only serve-async,bench-open-loop
  python tools/ci_smokes.py --keep-going    # don't stop at first failure
"""
from __future__ import annotations

import argparse
import os
import subprocess
import sys
import time

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SERVE = ["-m", "repro.launch.serve", "--arch", "qwen3-1.7b", "--reduced"]
ASYNC = ["-m", "repro.launch.serve_async", "--arch", "qwen3-1.7b",
         "--reduced"]
BENCH = [os.path.join("benchmarks", "serving.py")]

SMOKES = [
    ("serve",
     "Paged continuous-batching serve smoke",
     SERVE + ["--batch", "2", "--steps", "4"]),
    ("serve-chunked",
     "Chunked-prefill serve smoke (bounded prefill budget)",
     SERVE + ["--batch", "2", "--steps", "4", "--prefill-budget", "8"]),
    ("serve-adaptive",
     "Adaptive-prefill-budget serve smoke (SLA-headroom-derived)",
     SERVE + ["--batch", "2", "--steps", "4",
              "--prefill-budget", "adaptive"]),
    ("serve-sampled-spec",
     "Sampled + speculative serve smoke",
     SERVE + ["--batch", "2", "--steps", "8", "--spec-k", "4",
              "--temperature", "0.8", "--top-k", "4"]),
    ("serve-dense",
     "Dense fallback serve smoke",
     SERVE + ["--batch", "2", "--steps", "4", "--dense"]),
    ("serve-async",
     "Async streaming smoke (Poisson open loop + mid-stream cancels)",
     ASYNC + ["--smoke", "--cancel-every", "3"]),
    ("serve-http",
     "HTTP/SSE transport smoke (real-socket streams + disconnect cancel)",
     ["-m", "repro.launch.serve_http", "--arch", "qwen3-1.7b",
      "--reduced", "--smoke"]),
    ("bench-shared-prefix",
     "Shared-prefix + chunked-prefill benchmark smoke",
     BENCH + ["--smoke"]),
    ("bench-spec-greedy",
     "Speculative greedy gate (accept-rate > 0, tokens/step >= 1.1)",
     BENCH + ["--spec-k", "4", "--smoke"]),
    ("bench-spec-sampled",
     "Speculative sampling gate (accept-rate > 0, tokens/step >= 1)",
     BENCH + ["--spec-k", "4", "--temperature", "0.8", "--smoke"]),
    ("bench-parallel-sample",
     "Parallel-sampling gate (shared pages > 50%, refcounts clean)",
     BENCH + ["--workload", "parallel-sample", "--n", "4", "--smoke"]),
    ("bench-beam",
     "Beam-search gate (shared pages > 50%, refcounts clean)",
     BENCH + ["--workload", "parallel-sample", "--beam-width", "4",
              "--smoke"]),
    ("bench-open-loop",
     "Open-loop SLA gate (streams resolve, cancels refcount-clean)",
     BENCH + ["--workload", "open-loop", "--smoke"]),
    ("bench-kv-int8",
     "int8 page-codec gate (>= 2x concurrent slots at equal pool bytes)",
     BENCH + ["--kv-codec", "int8", "--smoke"]),
    ("serve-tp",
     "Tensor-parallel serve smoke (2-shard simulated mesh)",
     SERVE + ["--batch", "2", "--steps", "4", "--tp", "2"]),
    ("bench-tp",
     "Tensor-parallel gate (token parity + pool/shard halved)",
     BENCH + ["--tp", "2", "--smoke"]),
    ("bench-tp-spec",
     "Tensor-parallel speculative gate (spec-k parity under TP)",
     BENCH + ["--tp", "2", "--spec-k", "4", "--smoke"]),
    ("serve-disagg",
     "Disaggregated 2-replica router smoke (tp x dp mesh, prefix-aware "
     "placement, refcount-clean)",
     ["-m", "repro.launch.serve_http", "--arch", "qwen3-1.7b",
      "--reduced", "--replicas", "2", "--dp", "2", "--batch", "4",
      "--smoke"]),
    ("bench-disagg",
     "Prefill/decode disaggregation gate (token parity, zero page "
     "leaks, handoffs committed)",
     BENCH + ["--disagg", "--smoke"]),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--list", action="store_true",
                    help="print the smoke table and exit")
    ap.add_argument("--only", default=None,
                    help="comma-separated row keys to run")
    ap.add_argument("--keep-going", action="store_true",
                    help="run every row even after a failure")
    args = ap.parse_args()

    rows = SMOKES
    if args.only:
        want = [k.strip() for k in args.only.split(",") if k.strip()]
        by_key = {k: (k, d, c) for k, d, c in SMOKES}
        unknown = [k for k in want if k not in by_key]
        if unknown:
            ap.error(f"unknown smoke key(s) {unknown}; have "
                     f"{[k for k, _, _ in SMOKES]}")
        rows = [by_key[k] for k in want]
    if args.list:
        for key, desc, cmd in rows:
            print(f"{key:<22} {desc}")
            print(f"{'':<22} python {' '.join(cmd)}")
        return 0

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    results: list[tuple[str, int, float]] = []
    for key, desc, cmd in rows:
        print(f"\n=== [{key}] {desc}", flush=True)
        t0 = time.perf_counter()
        rc = subprocess.run([sys.executable] + cmd, env=env,
                            cwd=REPO).returncode
        dt = time.perf_counter() - t0
        results.append((key, rc, dt))
        if rc != 0 and not args.keep_going:
            break

    print("\n=== smoke summary")
    failed = [k for k, rc, _ in results if rc != 0]
    for key, rc, dt in results:
        print(f"  {'PASS' if rc == 0 else 'FAIL':<5} {key:<22} {dt:6.1f}s")
    skipped = len(rows) - len(results)
    if skipped:
        print(f"  (stopped early: {skipped} row(s) not run)")
    if failed:
        print(f"smokes: FAIL ({len(failed)}/{len(results)} failed)")
        return 1
    print(f"smokes: OK ({len(results)} gates)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
