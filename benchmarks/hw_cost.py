"""Paper Figs. 6/7 + Table IV: 28nm area/power model FA-2 vs H-FA."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.analysis import hw_model as H


def run():
    rows = H.savings_table()
    for r in rows:
        emit(f"fig7/area_power/d{r['d']}", 0.0,
             f"fa2={r['fa2_area_mm2']:.2f}mm2;hfa={r['hfa_area_mm2']:.2f}mm2;"
             f"area_saving={r['area_saving_%']:.1f}%;"
             f"power_saving={r['power_saving_%']:.1f}%")
    a = np.mean([r["area_saving_%"] for r in rows])
    p = np.mean([r["power_saving_%"] for r in rows])
    emit("fig7/average", 0.0,
         f"area_saving={a:.1f}%(paper 26.5%);power_saving={p:.1f}%"
         f"(paper 23.4%)")
    dp = H.savings_table(ds=(32,))[0]["dp_area_saving_%"]
    emit("fig6/datapath_only_d32", 0.0,
         f"datapath_saving={dp:.1f}%(paper 36.1%)")
    for r in H.throughput_table():
        emit(f"tableIV/{r['config']}", 0.0,
             f"area={r['area_mm2']:.2f}mm2(paper 1.14/3.34);"
             f"power={r['power_w']:.2f}W(paper 0.22/0.62);"
             f"bf16={r['bf16_tflops']:.3f}TFLOPs(paper 0.256/1.64);"
             f"fix16={r['fix16_tops']:.2f}TOPs(paper 0.91/5.84)")


if __name__ == "__main__":
    run()
