"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Mapping to the paper:

  tableI_II/*   - LLM fidelity proxy (logit divergence FA-2 vs H-FA)
  tableIII/*    - error-source decomposition (quant / Mitchell / PWL)
  fig5/*        - Mitchell input distribution + error bound
  fig6,fig7/*   - 28nm area/power savings model
  fig8/*        - KV-block scaling (time/area)
  tableIV/*     - accelerator throughput configs
  kernels/*     - attention implementation microbenches
  roofline/*    - dry-run derived roofline per (arch x shape)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import (accuracy, block_scaling, error_sources, hw_cost,
                            kernels, mitchell_hist, roofline_bench)
    modules = [
        ("tableI_II", accuracy),
        ("tableIII", error_sources),
        ("fig5", mitchell_hist),
        ("fig7+tableIV", hw_cost),
        ("fig8", block_scaling),
        ("kernels", kernels),
        ("roofline", roofline_bench),
    ]
    failed = []
    for name, mod in modules:
        try:
            mod.run()
        except Exception:
            failed.append(name)
            print(f"{name}/ERROR,0.0,{traceback.format_exc().splitlines()[-1]}")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
