"""Paper Tables I/II proxy: model-level fidelity of H-FA vs FA-2.

Offline stand-in for the MMLU/GPQA/... evaluations (no pretrained weights
in this container, documented in DESIGN.md §7): we measure how much the
H-FA numerics perturb the *logits* of models from the paper's own family
(Phi-3.5-mini-like) and an assigned arch, plus attention-output error
under realistic (concentrated) score distributions.  The paper's claim
maps to: logit correlation ~ 1 and top-1 agreement >> chance.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.configs import get_config
from repro.core import hfa, reference
from repro.models.model import build_model


def logit_divergence(arch: str, seed: int = 0):
    cfg = dataclasses.replace(get_config(arch).reduced(), attn_impl="fa2")
    cfg_h = dataclasses.replace(cfg, attn_impl="hfa_pallas")
    model_f = build_model(cfg)
    model_h = build_model(cfg_h)
    params = model_f.init(jax.random.PRNGKey(seed))
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 64)))}
    lf = np.asarray(model_f.apply(params, batch)[0].astype(jnp.float32))
    lh = np.asarray(model_h.apply(params, batch)[0].astype(jnp.float32))
    corr = np.corrcoef(lf.ravel(), lh.ravel())[0, 1]
    top1 = (lf.argmax(-1) == lh.argmax(-1)).mean()
    # symmetric KL over softmax distributions
    def _sm(x):
        x = x - x.max(-1, keepdims=True)
        e = np.exp(x)
        return e / e.sum(-1, keepdims=True)
    pf, ph = _sm(lf), _sm(lh)
    kl = 0.5 * np.sum(pf * np.log((pf + 1e-9) / (ph + 1e-9)), -1) \
        + 0.5 * np.sum(ph * np.log((ph + 1e-9) / (pf + 1e-9)), -1)
    return corr, top1, float(kl.mean())


def attention_error_profile():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((2, 4, 16, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((2, 4, 1024, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((2, 4, 1024, 64)), jnp.bfloat16)
    out = {}
    for name, scale in [("flat", None), ("peaked", 0.5)]:
        ref = np.asarray(reference.exact_attention(q, k, v, scale=scale))
        got = np.asarray(hfa.hfa_attention(q, k, v, scale=scale)
                         .astype(jnp.float32))
        out[name] = float(np.abs(got - ref).mean()
                          / (np.abs(ref).mean() + 1e-9))
    return out


def run():
    for arch in ("hfa-paper-mini", "qwen3-1.7b"):
        us = timeit(lambda a=arch: logit_divergence(a), repeats=1, warmup=0)
        corr, top1, kl = logit_divergence(arch)
        emit(f"tableI_II/logits/{arch}", us,
             f"corr={corr:.4f};top1_agree={top1:.3f};symKL={kl:.4f}")
    prof = attention_error_profile()
    emit("tableI_II/attn_rel_err", 0.0,
         f"flat_softmax={prof['flat']:.3f};peaked_softmax={prof['peaked']:.3f}")


if __name__ == "__main__":
    run()
