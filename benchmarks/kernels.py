"""Kernel microbenchmarks: wall time of each attention implementation.

CPU wall times (interpret-mode Pallas) are NOT TPU predictions - the
roofline artifacts carry the performance story - but they verify the jnp
paths are usable and give a relative-cost sanity signal.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.kernels import ops


def run():
    rng = np.random.default_rng(0)
    b, lq, lkv, h, d = 1, 256, 512, 4, 64
    q = jnp.asarray(rng.standard_normal((b, lq, h, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, lkv, h, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, lkv, h, d)), jnp.bfloat16)
    gold = None
    for impl in ("exact", "fa2", "fa2_pallas", "hfa_pallas"):
        fn = jax.jit(functools.partial(ops.multihead_attention, impl=impl))
        us = timeit(fn, q, k, v)
        out = np.asarray(fn(q, k, v).astype(jnp.float32))
        if gold is None:
            gold = out
            err = 0.0
        else:
            err = float(np.abs(out - gold).max())
        emit(f"kernels/prefill/{impl}", us,
             f"shape=({b}x{lq}x{lkv}x{h}x{d});max_err_vs_exact={err:.4f}")

    qd = jnp.asarray(rng.standard_normal((4, 1, 8, 64)), jnp.bfloat16)
    kc = jnp.asarray(rng.standard_normal((4, 2048, 2, 64)), jnp.bfloat16)
    vc = jnp.asarray(rng.standard_normal((4, 2048, 2, 64)), jnp.bfloat16)
    for impl in ("fa2", "fa2_pallas", "hfa_pallas"):
        fn = jax.jit(functools.partial(ops.decode_attention, impl=impl,
                                       kv_len=2000))
        us = timeit(fn, qd, kc, vc)
        emit(f"kernels/decode/{impl}", us, "cache=4x2048x2x64")


if __name__ == "__main__":
    run()
