"""Paper Table III: contribution of each approximation to the total error.

Methodology mirrors the paper: run the same attention with one error
source eliminated at a time (exact quantization / exact Mitchell /
exact PWL), average |error| vs the float reference, and report each
source's share of the total.  Paper finds Mitchell > 90%, others < 10%.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import hfa, lns, reference


def error_shares(seed=0, b=2, h=2, lq=8, lkv=512, d=64, scale=0.5):
    """scale=0.5 gives the concentrated softmax of trained LLM layers."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, lq, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, h, lkv, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, h, lkv, d)), jnp.bfloat16)
    ref = np.asarray(reference.exact_attention(q, k, v, scale=scale))

    def err(cfg):
        out = np.asarray(hfa.hfa_attention(q, k, v, cfg=cfg, scale=scale)
                         .astype(jnp.float32))
        return np.abs(out - ref).mean()

    e_full = err(lns.DEFAULT)
    contrib = {
        "BF16-to-FIX16": e_full - err(lns.LNSConfig(exact_quant=True)),
        "Mitchell": e_full - err(lns.LNSConfig(exact_mitchell=True)),
        "PWL": e_full - err(lns.LNSConfig(exact_pwl=True)),
    }
    contrib = {k: max(v, 0.0) for k, v in contrib.items()}
    total = sum(contrib.values()) or 1.0
    return {k: 100.0 * v / total for k, v in contrib.items()}, e_full


def run():
    shares, e_full = error_shares()
    emit("tableIII/error_sources", 0.0,
         ";".join(f"{k}={v:.1f}%" for k, v in shares.items())
         + f";total_abs_err={e_full:.4f}"
         + ";paper=quant<10%,mitchell>90%,pwl<3%")


if __name__ == "__main__":
    run()
