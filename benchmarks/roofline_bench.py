"""Roofline summary from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

from benchmarks.common import emit
from repro.analysis import roofline


def run():
    rows = roofline.analyze()
    ok = [r for r in rows if r["status"] == "ok"]
    skipped = [r for r in rows if r["status"] == "skipped"]
    errors = [r for r in rows if r["status"] not in ("ok", "skipped")]
    emit("dryrun/summary", 0.0,
         f"ok={len(ok)};skipped={len(skipped)};errors={len(errors)}")
    for r in ok:
        emit(f"roofline/{r['arch']}/{r['shape']}", 0.0,
             f"dominant={r['dominant']};compute_s={r['compute_s']:.3f};"
             f"memory_s={r['memory_s']:.3f};collective_s={r['collective_s']:.3f};"
             f"useful_ratio={r['useful_ratio']:.2f};"
             f"roofline_frac={r['roofline_fraction']*100:.1f}%")


if __name__ == "__main__":
    run()
