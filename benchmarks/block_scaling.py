"""Paper Fig. 8: execution time + area vs number of parallel KV blocks."""
from __future__ import annotations

from benchmarks.common import emit
from repro.analysis import hw_model as H


def run():
    for r in H.exec_time_model():
        emit(f"fig8/blocks{r['blocks']}", 0.0,
             f"cycles={r['cycles']:.0f};time_norm={r['time_norm']:.3f};"
             f"speedup={r['speedup']:.2f}x;area_norm={r['area_norm']:.2f}x")
    s8 = [r for r in H.exec_time_model() if r["blocks"] == 8][0]
    emit("fig8/summary", 0.0,
         f"speedup_at_8_blocks={s8['speedup']:.2f}x(paper ~6x)")


if __name__ == "__main__":
    run()
