"""Benchmark harness helpers: timing + CSV emission."""
from __future__ import annotations

import time

import jax


def timeit(fn, *args, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time per call in microseconds (CPU; jit-compiled fns)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
