"""Paper Fig. 5: distribution of Mitchell-approximation inputs + error bound.

Instruments a realistic H-FA attention run and records every input x on
which Mitchell's log2(1 +- x) ~= +-x is applied: (a) 2^{-|A-B|} inside the
LNS adds, (b) the BF16 mantissae of the V conversion (Eq. 18).  The paper
observes the vast majority below 0.1 where E(x) < 0.02, with the hard
bound max E(x) = 0.086 (paper rounds to 0.08).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import lns
from repro.core.numerics import FRAC_ONE, LOG_ZERO, bf16_bits


def collect_inputs(seed=0, b=2, h=2, lq=8, lkv=1024, d=64, scale=0.5):
    """Re-run the streaming update capturing |A-B| per step."""
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, h, lq, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((b, h, lkv, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((b, h, lkv, d)), jnp.bfloat16)

    # Mantissa inputs of the Blinn conversion:
    mant = (np.asarray(bf16_bits(v)) & 0x7F) / 128.0

    # |A-B| stream: patch lns_add to record (host-side replay, small sizes).
    xs = []
    orig = lns.lns_add

    def spy(sa, ra, sb, rb, cfg=lns.DEFAULT):
        d_raw = np.asarray(jnp.abs(ra - rb))
        live = (np.asarray(ra) > LOG_ZERO) & (np.asarray(rb) > LOG_ZERO)
        xs.append(2.0 ** (-(d_raw[live] / FRAC_ONE)))
        return orig(sa, ra, sb, rb, cfg)

    lns.lns_add = spy
    try:
        from repro.core import hfa
        with jax.disable_jit():
            hfa.hfa_attention(q[:1, :1, :2], k[:1, :1, :256],
                              v[:1, :1, :256], scale=scale)
    finally:
        lns.lns_add = orig
    adds = np.concatenate(xs) if xs else np.zeros(1)
    return mant.ravel(), adds


def run():
    mant, adds = collect_inputs()
    err_a = np.abs(np.log2(1 + adds) - adds)
    err_m = np.abs(np.log2(1 + mant) - mant)
    emit("fig5/mitchell_inputs/lns_adds", 0.0,
         f"n={adds.size};frac_below_0.1={float((adds < 0.1).mean()):.3f};"
         f"mean_E={err_a.mean():.4f};max_E={err_a.max():.4f};bound=0.0861")
    emit("fig5/mitchell_inputs/v_mantissa", 0.0,
         f"n={mant.size};frac_below_0.1={float((mant < 0.1).mean()):.3f};"
         f"mean_E={err_m.mean():.4f};max_E={err_m.max():.4f};"
         f"paper=majority<0.1,maxE~0.08")


if __name__ == "__main__":
    run()
