"""Serving throughput benchmark: paged continuous batching vs dense
fixed-batch, on a churn workload (staggered arrivals, variable output
lengths, retirements every few steps).

The dense baseline processes requests in fixed batches of ``--batch``:
every batch runs until its *longest* request finishes, so short requests
hold slots idle (head-of-line blocking).  The paged engine refills slots
the step they free up and allocates KV by the page, so the same hardware
budget serves the same requests in fewer steps.  Both paths run the
identical model + greedy decode; tok/s counts useful generated tokens.

  PYTHONPATH=src python benchmarks/serving.py [--arch qwen3-1.7b] [--n 16]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def make_workload(n, prompt_len, vocab, seed=0):
    """n requests, fixed prompt length, variable decode budgets."""
    rng = np.random.default_rng(seed)
    prompts = rng.integers(1, vocab, (n, prompt_len)).astype(np.int32)
    budgets = rng.integers(4, 24, n).astype(int)
    return prompts, budgets


def _dense_jits(model):
    """One jit wrapper pair per model, so the timed run reuses the
    warmup run's compile cache (mirrors the engine's shared jits)."""
    jits = getattr(model, "_dense_bench_jits", None)
    if jits is None:
        jits = (jax.jit(model.prefill), jax.jit(model.decode_step))
        model._dense_bench_jits = jits
    return jits


def run_dense(model, params, prompts, budgets, batch, max_seq):
    """Fixed-batch greedy loop: each batch runs to its longest budget."""
    prefill, decode = _dense_jits(model)
    n = len(prompts)
    useful = 0
    t0 = time.perf_counter()
    for start in range(0, n, batch):
        p = prompts[start:start + batch]
        b = budgets[start:start + batch]
        if len(p) < batch:     # ragged tail still occupies a full batch
            pad = batch - len(p)
            p = np.concatenate([p, np.repeat(p[-1:], pad, 0)])
            b = np.concatenate([b, np.zeros(pad, int)])
        cache = model.init_cache(params, batch, max_seq)
        logits, cache = prefill(params, cache, jnp.asarray(p))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        useful += int(np.sum(b >= 1))
        for step in range(1, int(b.max())):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            useful += int(np.sum(b >= step + 1))
        jax.block_until_ready(tok)
    return useful, time.perf_counter() - t0


def run_paged(model, params, prompts, budgets, batch, max_seq, page_size):
    from repro.serving import Request, ServingEngine
    engine = ServingEngine(model, params, max_batch=batch,
                           page_size=page_size, max_seq=max_seq)
    arrivals = [(i, Request(rid=i, prompt=prompts[i].tolist(),
                            max_new_tokens=int(budgets[i])))
                for i in range(len(prompts))]
    t0 = time.perf_counter()
    finished = engine.run(arrivals)
    dt = time.perf_counter() - t0
    engine.cache.check_invariants()
    assert len(finished) == len(prompts)
    return engine.stats["generated_tokens"], dt, engine.stats


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--full", action="store_true",
                    help="full-size config (default: reduced smoke scale)")
    ap.add_argument("--n", type=int, default=16, help="total requests")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256,
                    help="dense reserves this per slot up front; paged "
                         "allocates pages on demand - the gap is the win")
    ap.add_argument("--page-size", type=int, default=8)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.models.model import build_model

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts, budgets = make_workload(args.n, args.prompt_len,
                                     cfg.vocab_size)

    # Warm both paths with the identical workload so every jit shape
    # (prefill group sizes, resumed lengths) compiles outside the timed
    # region; engines share one compile cache via the model.
    run_dense(model, params, prompts, budgets, args.batch, args.max_seq)
    run_paged(model, params, prompts, budgets, args.batch, args.max_seq,
              args.page_size)

    d_tok, d_dt = run_dense(model, params, prompts, budgets, args.batch,
                            args.max_seq)
    p_tok, p_dt, stats = run_paged(model, params, prompts, budgets,
                                   args.batch, args.max_seq,
                                   args.page_size)
    d_tps = d_tok / d_dt
    p_tps = p_tok / p_dt
    print(f"dense fixed-batch:  {d_tok} tok in {d_dt:.2f}s -> "
          f"{d_tps:.1f} tok/s")
    print(f"paged continuous:   {p_tok} tok in {p_dt:.2f}s -> "
          f"{p_tps:.1f} tok/s  (steps={stats['steps']}, "
          f"preemptions={stats['preemptions']})")
    print(f"speedup paged/dense: {p_tps / d_tps:.2f}x")
    return p_tps >= d_tps


if __name__ == "__main__":
    import sys
    sys.exit(0 if main() else 1)
